"""Resident cluster loop vs. a naive restart-per-event rescan oracle.

The oracle below restates the documented resident semantics (the
``repro.core.resident`` module docstring) with none of the calendar's
machinery: flow rates recomputed from scratch at every event, full
``SimNode`` profile walks instead of cursors, list scans instead of the
version-skipped heap, and — crucially — **no whole-job fast path and no
tail fast-forward**: the oracle always grinds through its own event loop,
so the calendar's ``run_job`` delegations (entry fast path, resumable
splice) are pinned against first-principles mechanics at 1e-9.

Randomized differential suites cover: concurrent jobs (>= 2) under fault
traces AND elastic resizes, weighted fair shares with shedding/rescue,
per-job retry budgets, adaptive re-splits across spliced barriers, pull
and static stages sharing datanode uplinks across jobs, and the
``recovery="restart"`` baseline.  Crafted scenarios pin exact numbers for
shed/rescue, SLO attainment, splice-beats-restart, and validation.
"""
import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    AdaptivePlan, PullSpec, StageSummary, StaticSpec, run_job,
    run_job_cache_clear,
)
from repro.core.faults import (
    DEAD, DRAINING, FaultTrace, NodeCrash, RetryPolicy, SpotPreemption,
    lost_work,
)
from repro.core.partitioner import hemt_split_floats
from repro.core.resident import (
    JobOutcome, ResidentCalendar, ResidentJob, ResidentResult, ResizeEvent,
    fair_shares,
)
from repro.core.simulator import SimNode, SimTask

REL = ABS = 1e-9
_EPS = 1e-9
_RANK = {"recover": 0, "drain": 1, "kill": 2, "resize": 3, "arrive": 4}


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# the oracle: full-rescan resident loop per the documented semantics
# --------------------------------------------------------------------------

class _OJob:
    def __init__(self, job, cold):
        self.job = job
        self.status = "idle"
        self.arrived = job.arrival <= 0.0
        self.admitted_at = None
        self.nodes = []
        self.stage_idx = 0
        self.stage_start = 0.0
        self.stage_total = 0.0
        self.carry = 0.0
        self.pending = True
        self.open = 0
        self.overflow = []
        self.shared = []
        self.exec_work = {}
        self.counts = {}
        self.fin = {}
        self.planned_dict = None
        self.requeues = {}
        self.penalty = {}
        self.task_seq = 0
        self.cold = list(cold)
        self.summaries = []
        self.planned = []
        self.completion = math.inf
        self.lost = 0.0
        self.retries = 0
        self.sheds = 0

    def rank(self):
        return (self.job.priority, self.job.arrival, self.job.name)

    def active(self):
        return self.arrived and self.status != "done"

    def next_tid(self):
        self.task_seq += 1
        return self.task_seq


def oracle_resident(nodes, jobs, uplink_bw=None, faults=None, resizes=(),
                    recovery="splice"):
    """Naive resident oracle: rescan everything at every event."""
    nodes = list(nodes)
    names = [nd.name for nd in nodes]
    bw = uplink_bw if uplink_bw else None
    ckpt = faults.checkpoint_grain if faults is not None else 0.0
    if faults is not None and not faults.events:
        faults = None
    n0 = len(nodes)
    dead = [faults.state_at(i, 0.0) == DEAD if faults else False
            for i in range(n0)]
    drain = [faults.state_at(i, 0.0) == DRAINING if faults else False
             for i in range(n0)]
    owner = [None] * n0
    busy = [False] * n0
    tid = [0] * n0
    t_started = [0.0] * n0
    launch = [0.0] * n0
    att_work = [0.0] * n0
    att_io = [0.0] * n0
    io_left = [0.0] * n0
    cpu_done = [0.0] * n0
    dn = [-1] * n0

    cold = faults.cold_restarts() if faults else []
    jst = [_OJob(j, cold) for j in jobs]

    ext = []
    if faults is not None:
        for (tt, node, kind) in faults.sub_events(0.0):
            ext.append((tt, _RANK[kind], (node,), kind, node))
    for seq, rz in enumerate(sorted(resizes, key=lambda r: r.at)):
        ext.append((rz.at, _RANK["resize"], (seq,), "resize", rz))
    for js in jst:
        if not js.arrived:
            ext.append((js.job.arrival, _RANK["arrive"],
                        (js.job.priority, js.job.name), "arrive", js))
    ext.sort(key=lambda e: (e[0], e[1], e[2]))
    pend = list(range(len(ext)))

    def usable(i):
        return not dead[i] and not drain[i]

    def free_nodes():
        return [i for i in range(len(nodes))
                if usable(i) and owner[i] is None]

    def permits(js, i):
        return js.job.allowed is None or names[i] in js.job.allowed

    def ranked():
        return sorted((js for js in jst if js.active()), key=_OJob.rank)

    def remaining(i, now):
        if now < launch[i]:
            return att_work[i]
        return nodes[i].work_between(now, cpu_done[i])

    def flow_active(i):
        return busy[i] and bw is not None and dn[i] >= 0 and io_left[i] > _EPS

    def rates():
        cnt = {}
        for i in range(len(nodes)):
            if flow_active(i):
                cnt[dn[i]] = cnt.get(dn[i], 0) + 1
        return {d: bw / c for d, c in cnt.items()}

    def release(i):
        js = owner[i]
        if js is not None:
            js.nodes.remove(i)
            owner[i] = None

    def start_attempt(i, js, tk, now):
        busy[i] = True
        tid[i] = tk.task_id
        t_started[i] = now
        launch[i] = now + nodes[i].task_overhead \
            + js.penalty.pop(tk.task_id, 0.0)
        att_work[i] = tk.cpu_work
        cpu_done[i] = nodes[i].finish_time(tk.cpu_work, launch[i])
        if bw is not None and tk.datanode >= 0 and tk.io_mb > _EPS:
            att_io[i] = tk.io_mb
            io_left[i] = tk.io_mb
            dn[i] = tk.datanode
        else:
            att_io[i] = 0.0
            io_left[i] = 0.0
            dn[i] = -1

    def refill(i, now):
        js = owner[i]
        if js is None or busy[i] or dead[i] or drain[i]:
            return
        if js.overflow:
            start_attempt(i, js, js.overflow.pop(0), now)
        elif js.shared:
            start_attempt(i, js, js.shared.pop(0), now)

    def wake(js, now):
        for i in js.nodes:
            if not busy[i]:
                refill(i, now)

    def record(js, name, w, now):
        js.exec_work[name] = js.exec_work.get(name, 0.0) + w
        js.counts[name] = js.counts.get(name, 0) + 1
        js.fin[name] = now

    def cancel(i, now, checkpoint, charge):
        js, t_id = owner[i], tid[i]
        if js is None or not busy[i]:
            return
        executed = att_work[i] - remaining(i, now)
        saved = 0.0
        if checkpoint and ckpt > 0.0 and executed > 0.0:
            saved = min(math.floor((executed + _EPS) / ckpt) * ckpt,
                        att_work[i])
        if saved > _EPS:
            record(js, names[i], saved, now)
        busy[i] = False
        was_dn = dn[i]
        io_left[i] = 0.0
        dn[i] = -1
        rem = att_work[i] - saved
        if rem <= _EPS:
            js.open -= 1
            return
        if charge:
            k = js.requeues.get(t_id, 0)
            if k >= js.job.retry.max_attempts - 1:
                js.open -= 1
                return
            js.requeues[t_id] = k + 1
            js.retries += 1
            p = js.job.retry.penalty(k + 1)
            if p > 0.0:
                js.penalty[t_id] = p
        if att_io[i] > _EPS and att_work[i] > _EPS:
            io = att_io[i] * rem / att_work[i]
        else:
            io = 0.0
        js.overflow.append(SimTask(rem, io, was_dn if io > _EPS else -1,
                                   task_id=t_id))

    def shed(js, now):
        js.sheds += 1
        for i in list(js.nodes):
            if not usable(i):
                continue
            cancel(i, now, True, False)
            release(i)
        if not js.nodes:
            js.status = "idle"
        if js.open == 0 and not js.pending:
            barrier(js, now)

    def base_split(js, spec, total, nms):
        if js.job.proportions is not None:
            return hemt_split_floats(
                total, [js.job.proportions.get(nm, 1.0) for nm in nms])
        if (isinstance(spec, StaticSpec) and len(spec.works) == len(nms)
                and js.carry == 0.0):
            return list(spec.works)
        return [total / len(nms)] * len(nms)

    def materialize(js, now, total_override=None):
        spec = js.job.stages[js.stage_idx]
        if js.job.adaptive is not None:
            while js.cold and js.cold[0][0] <= now + _EPS:
                _, node = js.cold.pop(0)
                if node < len(names):
                    js.job.adaptive.estimator.forget(names[node])
        nms = [names[i] for i in js.nodes]
        js.exec_work, js.counts, js.fin = {}, {}, {}
        js.stage_start = now
        js.pending = False
        js.status = "running"
        if isinstance(spec, StaticSpec):
            if total_override is None:
                total = sum(spec.works) + js.carry
            else:
                total = total_override
            base = base_split(js, spec, total, nms)
            js.carry = 0.0
            if js.job.adaptive is not None:
                works = list(js.job.adaptive.replan(
                    nms, StaticSpec(works=tuple(base), io_mb=spec.io_mb,
                                    datanode=spec.datanode)).works)
            else:
                works = base
            js.stage_total = sum(works)
            js.planned_dict = dict(zip(nms, works))
            wsum = js.stage_total
            for i, w in zip(js.nodes, works):
                if spec.io_mb > 0.0 and spec.datanode >= 0:
                    io = spec.io_mb * (w / wsum if wsum > 0.0
                                       else 1.0 / len(works))
                else:
                    io = 0.0
                js.open += 1
                start_attempt(i, js, SimTask(
                    w, io, spec.datanode if io > _EPS else -1,
                    task_id=js.next_tid()), now)
        else:
            w = spec.work_array()
            wtot = float(w.sum())
            if total_override is not None:
                carry = total_override - wtot
            else:
                carry = js.carry
            js.carry = 0.0
            if carry > 0.0:
                if wtot > 0.0:
                    w = w * (1.0 + carry / wtot)
                else:
                    w = w + carry / len(w)
            js.stage_total = float(w.sum())
            js.planned_dict = None
            js.shared = [SimTask(float(x), spec.io_mb, spec.datanode,
                                 task_id=js.next_tid()) for x in w]
            js.open += len(js.shared)
            wake(js, now)

    def restart_stage(js, now):
        for i in list(js.nodes):
            if busy[i]:
                busy[i] = False
                io_left[i] = 0.0
                dn[i] = -1
            if not usable(i):
                release(i)
        js.overflow = []
        js.shared = []
        js.open = 0
        total = js.stage_total
        if js.nodes:
            materialize(js, now, total_override=total)
        else:
            js.carry = 0.0
            js.stage_total = total
            js.pending = True
            js.status = "idle"

    def rebalance(now, barrier_job=None):
        rk = ranked()
        capacity = sum(usable(i) for i in range(len(nodes)))
        shares = fair_shares([(js.job.name, js.job.weight) for js in rk],
                             capacity)
        for js in rk:
            if shares[js.job.name] == 0 \
                    and any(usable(i) for i in js.nodes):
                shed(js, now)
        if barrier_job is not None:
            share = shares.get(barrier_job.job.name, 0)
            if share > 0:
                held = sorted(i for i in barrier_job.nodes if usable(i))
                for i in held[share:]:
                    release(i)
                fr = [i for i in free_nodes() if permits(barrier_job, i)]
                for i in fr[:share - len(barrier_job.nodes)]:
                    owner[i] = barrier_job
                    barrier_job.nodes.append(i)
                barrier_job.nodes.sort()
        for js in rk:
            if js.status == "done" or js.nodes or shares[js.job.name] == 0:
                continue
            fr = [i for i in free_nodes() if permits(js, i)]
            if not fr:
                continue
            for i in fr[:shares[js.job.name]]:
                owner[i] = js
                js.nodes.append(i)
            js.nodes.sort()
            if js.admitted_at is None:
                js.admitted_at = now
            js.status = "running"
            if js.pending:
                materialize(js, now)
            else:
                wake(js, now)
        for js in jst:
            if js.status == "running" and js.nodes and not js.pending:
                wake(js, now)

    def barrier(js, now):
        nms = list(names)
        offs = [js.fin.get(nm, js.stage_start) - js.stage_start
                for nm in nms]
        ran = [o for nm, o in zip(nms, offs) if js.counts.get(nm, 0)]
        idle = (max(ran) - min(ran)) if ran else 0.0
        summ = StageSummary(
            js.stage_start, now, idle,
            {nm: js.stage_start + o for nm, o in zip(nms, offs)},
            {nm: js.counts.get(nm, 0) for nm in nms},
            {nm: js.exec_work.get(nm, 0.0) for nm in nms})
        js.summaries.append(summ)
        js.planned.append(dict(js.planned_dict)
                          if js.planned_dict is not None else None)
        if js.job.adaptive is not None:
            js.job.adaptive.observe(nms, summ)
        lost = lost_work(js.stage_total, sum(js.exec_work.values()))
        js.stage_total = 0.0
        js.stage_idx += 1
        last = js.stage_idx >= len(js.job.stages)
        if lost > 0.0:
            if js.job.fold_lost and not last:
                js.carry = lost
            else:
                js.lost += lost
        js.requeues.clear()
        js.penalty.clear()
        if last:
            js.status = "done"
            js.completion = now
            for i in list(js.nodes):
                release(i)
            rebalance(now)
            return
        js.pending = True
        rebalance(now, barrier_job=js)
        if not js.nodes:
            js.status = "idle"
            return
        materialize(js, now)

    def complete(i, now):
        js = owner[i]
        record(js, names[i], att_work[i], now)
        busy[i] = False
        io_left[i] = 0.0
        dn[i] = -1
        js.open -= 1
        if drain[i]:
            release(i)
        else:
            refill(i, now)
        if js.open == 0:
            barrier(js, now)

    def handle_ext(kind, payload, now):
        if kind == "kill":
            i = payload
            if i < len(nodes):
                dead[i] = True
                drain[i] = False
                js = owner[i]
                cancel(i, now, True, True)
                release(i)
                if js is not None and js.open == 0 and not js.pending:
                    barrier(js, now)
                elif js is not None and not js.nodes:
                    js.status = "idle"
        elif kind == "drain":
            i = payload
            if i < len(nodes):
                drain[i] = True
                if not busy[i]:
                    release(i)
        elif kind == "recover":
            i = payload
            if i < len(nodes):
                dead[i] = False
                drain[i] = False
                if owner[i] is not None and not busy[i]:
                    release(i)
        elif kind == "resize":
            for i in payload.drop:
                if i >= len(nodes) or dead[i]:
                    continue
                js = owner[i]
                cancel(i, now, True, False)
                release(i)
                dead[i] = True
                drain[i] = False
                if js is not None and js.open == 0 and not js.pending:
                    barrier(js, now)
                elif js is not None and not js.nodes:
                    js.status = "idle"
            for nd in payload.add:
                names.append(nd.name)
                nodes.append(nd)
                for arr, z in ((dead, False), (drain, False), (owner, None),
                               (busy, False), (tid, 0), (dn, -1)):
                    arr.append(z)
                for arr in (t_started, launch, att_work, att_io, io_left,
                            cpu_done):
                    arr.append(0.0)
        else:
            payload.arrived = True
        rebalance(now)
        if recovery == "restart" and kind != "arrive":
            for js in ranked():
                if js.status == "running":
                    restart_stage(js, now)

    rebalance(0.0)
    t = 0.0
    guard = 0
    while pend or any(busy):
        guard += 1
        assert guard < 200_000, "resident oracle runaway"
        cur = rates()
        cands = [(ext[idx][0], 0, idx, "ext") for idx in pend]
        for i in range(len(nodes)):
            if not busy[i]:
                continue
            if flow_active(i):
                cands.append((t + io_left[i] / cur[dn[i]], 1, i, "io"))
            else:
                cands.append((max(t, cpu_done[i]), 1, i, "done"))
        if not cands:
            break
        tn, _, key, kind = min(cands, key=lambda e: (e[0], e[1], e[2]))
        for j in range(len(nodes)):
            if flow_active(j):
                io_left[j] = max(0.0, io_left[j] - cur[dn[j]] * (tn - t))
        t = tn
        if kind == "ext":
            pend.remove(key)
            _, _, _, k2, payload = ext[key]
            handle_ext(k2, payload, t)
        elif kind == "io":
            io_left[key] = 0.0
            if t + _EPS >= cpu_done[key]:
                complete(key, t)
        else:
            complete(key, t)

    outcomes = {}
    makespan = 0.0
    for js in jst:
        done = js.status == "done"
        comp = js.completion if done else math.inf
        if done:
            makespan = max(makespan, comp)
        elif js.stage_total:
            js.lost += lost_work(js.stage_total,
                                 sum(js.exec_work.values()))
        dl = js.job.deadline
        outcomes[js.job.name] = JobOutcome(
            js.job.name, comp, dl,
            done and (dl is None or comp <= dl + _EPS),
            "done" if done else "stranded", js.admitted_at,
            js.summaries, js.planned, js.lost, js.retries, js.sheds)
    alive = [names[i] for i in range(len(nodes)) if usable(i)]
    return ResidentResult(outcomes, makespan, alive)


def assert_resident_match(oracle, got):
    assert set(got.outcomes) == set(oracle.outcomes)
    assert set(got.alive) == set(oracle.alive)
    assert got.makespan == _approx(oracle.makespan)
    for name, oo in oracle.outcomes.items():
        go = got.outcomes[name]
        assert go.status == oo.status, name
        if math.isinf(oo.completion):
            assert math.isinf(go.completion), name
        else:
            assert go.completion == _approx(oo.completion), name
        assert go.attained == oo.attained, name
        assert (go.admitted_at is None) == (oo.admitted_at is None), name
        if oo.admitted_at is not None:
            assert go.admitted_at == _approx(oo.admitted_at), name
        assert go.retries == oo.retries, name
        assert go.sheds == oo.sheds, name
        assert go.lost == _approx(oo.lost), name
        assert len(go.stages) == len(oo.stages), name
        for os_, gs in zip(oo.stages, go.stages):
            assert gs.start == _approx(os_.start)
            assert gs.completion == _approx(os_.completion)
            assert gs.idle_time == _approx(os_.idle_time)
            # fast-forwarded summaries carry the surviving sub-cluster's
            # names only; the oracle's carry every cluster name — compare
            # on the union with zero defaults
            for nm in set(os_.counts) | set(gs.counts):
                assert gs.counts.get(nm, 0) == os_.counts.get(nm, 0)
                assert gs.work.get(nm, 0.0) == _approx(
                    os_.work.get(nm, 0.0))
                if os_.counts.get(nm, 0):
                    assert gs.node_finish[nm] == _approx(
                        os_.node_finish[nm])
        assert len(go.planned) == len(oo.planned), name
        for op, gp in zip(oo.planned, go.planned):
            assert (gp is None) == (op is None)
            if op is not None:
                assert set(gp) == set(op)
                for nm in op:
                    assert gp[nm] == _approx(op[nm])


# --------------------------------------------------------------------------
# randomized generators
# --------------------------------------------------------------------------

N_DATANODES = 3


def random_cluster(rng, max_nodes=4, constant=True):
    n = int(rng.integers(2, max_nodes + 1))
    nodes = []
    for i in range(n):
        if constant or rng.random() < 0.6:
            prof = [(0.0, float(rng.uniform(0.3, 3.0)))]
        else:
            n_seg = int(rng.integers(2, 4))
            breaks = np.concatenate(
                [[0.0], np.cumsum(rng.uniform(0.5, 5.0, n_seg - 1))])
            prof = [(float(tb), float(rng.uniform(0.3, 3.0)))
                    for tb in breaks]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.2))))
    return nodes


def random_job_specs(rng, n_jobs=None):
    """Serializable job descriptions — built fresh (own AdaptivePlan) for
    the calendar and the oracle so estimator state is never shared."""
    n_jobs = n_jobs if n_jobs is not None else int(rng.integers(1, 4))
    specs = []
    for j in range(n_jobs):
        stages = []
        for _ in range(int(rng.integers(1, 4))):
            io = float(rng.uniform(0.5, 5.0)) if rng.random() < 0.4 else 0.0
            d = int(rng.integers(0, N_DATANODES)) if io else -1
            if rng.random() < 0.6:
                width = int(rng.integers(1, 5))
                stages.append(("static",
                               tuple(float(w) for w in
                                     rng.uniform(0.2, 5.0, width)), io, d))
            else:
                k = int(rng.integers(1, 6))
                stages.append(("pull",
                               tuple(float(w) for w in
                                     rng.uniform(0.2, 3.0, k)), io, d))
        props = None
        if rng.random() < 0.2:
            props = {f"n{i}": float(rng.uniform(0.5, 3.0))
                     for i in range(int(rng.integers(1, 4)))}
        specs.append(dict(
            name=f"j{j}",
            stages=tuple(stages),
            arrival=(0.0 if rng.random() < 0.6
                     else float(rng.uniform(0.1, 6.0))),
            priority=int(rng.integers(0, 3)),
            weight=float(rng.uniform(0.5, 3.0)),
            deadline=(None if rng.random() < 0.5
                      else float(rng.uniform(2.0, 30.0))),
            retry=dict(max_attempts=int(rng.integers(1, 4)),
                       relaunch_overhead=float(rng.choice([0.0, 0.3])),
                       backoff=float(rng.choice([1.0, 2.0]))),
            adaptive=rng.random() < 0.4,
            proportions=props,
            fold_lost=rng.random() < 0.7,
        ))
    return specs


def build_jobs(specs):
    jobs = []
    for s in specs:
        stages = []
        for kind, works, io, d in s["stages"]:
            if kind == "static":
                stages.append(StaticSpec(works=works, io_mb=io, datanode=d))
            else:
                stages.append(PullSpec(works=works, io_mb=io, datanode=d))
        jobs.append(ResidentJob(
            s["name"], tuple(stages), arrival=s["arrival"],
            priority=s["priority"], weight=s["weight"],
            deadline=s["deadline"], retry=RetryPolicy(**s["retry"]),
            adaptive=AdaptivePlan() if s["adaptive"] else None,
            proportions=s["proportions"], fold_lost=s["fold_lost"]))
    return jobs


def random_trace(rng, n, t_hi=10.0):
    if rng.random() < 0.25:
        return None
    events = []
    hit = rng.permutation(n)[:int(rng.integers(1, min(n, 3) + 1))]
    for nd in hit:
        at = float(rng.uniform(0.1, t_hi))
        u = rng.random()
        if u < 0.35:
            events.append(NodeCrash(int(nd), at))
        elif u < 0.75:
            events.append(NodeCrash(
                int(nd), at, recover_at=at + float(rng.uniform(0.5, 5.0)),
                cold_restart=rng.random() < 0.3))
        else:
            events.append(SpotPreemption(
                int(nd), at, warning=float(rng.choice([0.0, 0.5, 1.5]))))
    return FaultTrace(tuple(events),
                      checkpoint_grain=float(rng.choice([0.0, 0.25, 1.0])))


def random_resizes(rng, t_hi=10.0):
    out = []
    for r in range(int(rng.integers(0, 3))):
        add = tuple(
            SimNode(f"x{r}{k}", [(0.0, float(rng.uniform(0.3, 2.5)))],
                    float(rng.uniform(0.0, 0.2)))
            for k in range(int(rng.integers(0, 3))))
        drop = tuple(int(i) for i in
                     rng.permutation(4)[:int(rng.integers(0, 2))])
        if not add and not drop:
            continue
        out.append(ResizeEvent(float(rng.uniform(0.2, t_hi)),
                               add=add, drop=drop))
    return tuple(out)


# --------------------------------------------------------------------------
# randomized differential suites (calendar vs. oracle at 1e-9)
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_differential_single_job_clean(seed):
    """One clean job: the calendar's whole-job run_job fast path (closed
    forms + solve LRU) against the oracle's first-principles loop."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=False)
    specs = random_job_specs(rng, n_jobs=1)
    specs[0]["arrival"] = 0.0
    bw = None if rng.random() < 0.3 else float(rng.uniform(0.5, 4.0))
    run_job_cache_clear()
    got = ResidentCalendar(nodes, uplink_bw=bw).run(build_jobs(specs))
    oracle = oracle_resident(nodes, build_jobs(specs), uplink_bw=bw)
    assert_resident_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_multi_job_fair_share(seed):
    """>= 2 concurrent jobs, no externals: weighted fair shares, staggered
    arrivals, barrier trim/grow, shedding under admission pressure, and
    cross-job datanode flow sharing."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    specs = random_job_specs(rng, n_jobs=int(rng.integers(2, 4)))
    bw = None if rng.random() < 0.3 else float(rng.uniform(0.5, 4.0))
    run_job_cache_clear()
    got = ResidentCalendar(nodes, uplink_bw=bw).run(build_jobs(specs))
    oracle = oracle_resident(nodes, build_jobs(specs), uplink_bw=bw)
    assert_resident_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_faults_resizes_multi_job(seed):
    """The acceptance scenario: faults AND elastic resizes over >= 2
    concurrent jobs — splice-in recovery, retry budgets, rescue passes,
    tail fast-forward — pinned against the rescan oracle at 1e-9."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    specs = random_job_specs(rng, n_jobs=int(rng.integers(2, 4)))
    bw = None if rng.random() < 0.3 else float(rng.uniform(0.5, 4.0))
    trace = random_trace(rng, len(nodes))
    resizes = random_resizes(rng)
    run_job_cache_clear()
    got = ResidentCalendar(nodes, uplink_bw=bw, faults=trace,
                           resizes=resizes).run(build_jobs(specs))
    oracle = oracle_resident(nodes, build_jobs(specs), uplink_bw=bw,
                             faults=trace, resizes=resizes)
    assert_resident_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_restart_baseline(seed):
    """recovery='restart': every capacity event aborts and re-materializes
    running stages from scratch — the benchmarked baseline must match the
    oracle running the same abort rule."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    specs = random_job_specs(rng, n_jobs=int(rng.integers(1, 3)))
    bw = None if rng.random() < 0.5 else float(rng.uniform(0.5, 4.0))
    trace = random_trace(rng, len(nodes))
    resizes = random_resizes(rng)
    run_job_cache_clear()
    got = ResidentCalendar(nodes, uplink_bw=bw, faults=trace,
                           resizes=resizes,
                           recovery="restart").run(build_jobs(specs))
    oracle = oracle_resident(nodes, build_jobs(specs), uplink_bw=bw,
                             faults=trace, resizes=resizes,
                             recovery="restart")
    assert_resident_match(oracle, got)


# --------------------------------------------------------------------------
# crafted scenarios: exact numbers per the documented semantics
# --------------------------------------------------------------------------

def _two_nodes():
    return [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]


def test_fast_path_matches_run_job_exactly():
    """A single clean job must ride run_job wholesale — bitwise, not just
    1e-9: same completion, same summaries."""
    nodes = [SimNode.constant("a", 2.0), SimNode.constant("b", 1.0)]
    spec = StaticSpec(works=(4.0, 2.0))
    run_job_cache_clear()
    res = ResidentCalendar(nodes).run(
        [ResidentJob("only", (spec, spec))])
    run_job_cache_clear()
    sched = run_job(nodes, [spec, spec])
    out = res.outcomes["only"]
    assert out.completion == sched.completion
    assert [s.completion for s in out.stages] \
        == [s.completion for s in sched.stages]
    assert out.planned == [{"a": 4.0, "b": 2.0}] * 2
    assert out.status == "done" and out.admitted_at == 0.0


def test_fair_shares_policy():
    assert fair_shares([("a", 2.0), ("b", 1.0), ("c", 1.0)], 4) \
        == {"a": 2, "b": 1, "c": 1}
    # capacity below job count: tail jobs shed to zero
    assert fair_shares([("a", 1.0), ("b", 1.0), ("c", 1.0)], 2) \
        == {"a": 1, "b": 1, "c": 0}
    assert fair_shares([("a", 1.0)], 0) == {"a": 0}
    assert fair_shares([], 3) == {}


def test_shed_and_rescue_cycle():
    """Three equal jobs on two nodes: the lowest-priority job is shed at
    admission (one shed event), stalls, and is rescued the moment a
    higher-priority job finishes and frees its node."""
    nodes = _two_nodes()
    jobs = [ResidentJob("hi", (StaticSpec(works=(2.0,)),), priority=0),
            ResidentJob("mid", (StaticSpec(works=(3.0,)),), priority=1),
            ResidentJob("lo", (StaticSpec(works=(1.0,)),), priority=2)]
    res = ResidentCalendar(nodes).run(jobs)
    assert res.outcomes["hi"].completion == _approx(2.0)
    assert res.outcomes["mid"].completion == _approx(3.0)
    # lo admitted only when hi's node frees at t=2
    lo = res.outcomes["lo"]
    assert lo.admitted_at == _approx(2.0)
    assert lo.completion == _approx(3.0)
    assert lo.status == "done"
    assert_resident_match(oracle_resident(_two_nodes(), [
        ResidentJob("hi", (StaticSpec(works=(2.0,)),), priority=0),
        ResidentJob("mid", (StaticSpec(works=(3.0,)),), priority=1),
        ResidentJob("lo", (StaticSpec(works=(1.0,)),), priority=2)]), res)


def test_mid_stage_shed_checkpoints_without_retry_charge():
    """A higher-priority arrival sheds the running low-priority job: its
    attempt checkpoints at the grain boundary, no retry is charged, and
    the residual resumes when capacity returns."""
    nodes = [SimNode.constant("a", 1.0)]
    trace = FaultTrace((), checkpoint_grain=1.0)
    lo = ResidentJob("lo", (StaticSpec(works=(10.0,)),), priority=1)
    hi = ResidentJob("hi", (StaticSpec(works=(2.0,)),), priority=0,
                     arrival=3.0)
    res = ResidentCalendar(nodes, faults=trace).run([lo, hi])
    # lo runs [0,3), sheds with 3 units checkpointed; hi runs [3,5];
    # lo's 7-unit residual resumes at 5 and finishes at 12
    assert res.outcomes["hi"].completion == _approx(5.0)
    out = res.outcomes["lo"]
    assert out.completion == _approx(12.0)
    assert out.sheds == 1 and out.retries == 0
    assert out.lost == _approx(0.0)


def test_splice_strictly_beats_restart_per_event():
    """The tentpole ordering: under the same kill+recover trace the
    splicing calendar keeps checkpointed progress while the restart
    baseline re-runs the stage from scratch."""
    nodes = _two_nodes()
    trace = FaultTrace((NodeCrash(1, 2.0, recover_at=3.0),),
                       checkpoint_grain=1.0)
    job = dict(name="j", stages=(StaticSpec(works=(4.0, 4.0)),),
               retry=RetryPolicy(max_attempts=3))
    splice = ResidentCalendar(_two_nodes(), faults=trace).run(
        [ResidentJob(job["name"], job["stages"], retry=job["retry"])])
    restart = ResidentCalendar(_two_nodes(), faults=trace,
                               recovery="restart").run(
        [ResidentJob(job["name"], job["stages"], retry=job["retry"])])
    s = splice.outcomes["j"].completion
    r = restart.outcomes["j"].completion
    assert s < r - 1e-6, (s, r)
    # splice: b's 2 checkpointed units survive, only the 2-unit residual
    # re-runs on a after its own macrotask -> a finishes 4+2 at t=6
    assert s == _approx(6.0)
    assert nodes is not None


def test_deadline_slo_attainment():
    nodes = _two_nodes()
    jobs = [ResidentJob("meets", (StaticSpec(works=(2.0,)),),
                        priority=0, deadline=2.5),
            ResidentJob("misses", (StaticSpec(works=(4.0,)),),
                        priority=1, deadline=1.0)]
    res = ResidentCalendar(nodes).run(jobs)
    assert res.outcomes["meets"].attained is True
    assert res.outcomes["misses"].attained is False
    assert res.attainment() == _approx(0.5)
    # a job with no deadline never counts against attainment
    res2 = ResidentCalendar(_two_nodes()).run(
        [ResidentJob("free", (StaticSpec(works=(1.0, 1.0)),))])
    assert res2.attainment() == 1.0


def test_stranded_job_reports_inf_and_lost_work():
    """The fleet's only node dies with retry budget left: the residual
    waits in the overflow queue forever — stranded, not done."""
    nodes = [SimNode.constant("a", 1.0)]
    trace = FaultTrace((NodeCrash(0, 1.0),), checkpoint_grain=1.0)
    res = ResidentCalendar(nodes, faults=trace).run(
        [ResidentJob("j", (StaticSpec(works=(5.0,)),),
                     retry=RetryPolicy(max_attempts=3))])
    out = res.outcomes["j"]
    assert out.status == "stranded"
    assert math.isinf(out.completion)
    assert out.attained is False
    assert out.lost == _approx(4.0)       # 1 checkpointed, 4 stranded
    assert res.alive == []
    assert_resident_match(oracle_resident(
        [SimNode.constant("a", 1.0)],
        [ResidentJob("j", (StaticSpec(works=(5.0,)),),
                     retry=RetryPolicy(max_attempts=3))],
        faults=trace), res)

    # retries EXHAUSTED on the last stage instead: the barrier fires at
    # the kill, the loss is eaten, and the job counts as done
    res2 = ResidentCalendar([SimNode.constant("a", 1.0)],
                            faults=trace).run(
        [ResidentJob("j", (StaticSpec(works=(5.0,)),),
                     retry=RetryPolicy(max_attempts=1))])
    out2 = res2.outcomes["j"]
    assert out2.status == "done"
    assert out2.completion == _approx(1.0)
    assert out2.lost == _approx(4.0)


def test_elastic_resize_splices_in_new_capacity():
    """A resize that doubles the fleet mid-job: the running stage keeps
    its width (lazy assignment), the next barrier grows onto the new
    nodes."""
    nodes = [SimNode.constant("a", 1.0)]
    rz = ResizeEvent(1.0, add=(SimNode.constant("b", 1.0),))
    spec = StaticSpec(works=(4.0,))
    res = ResidentCalendar(nodes, resizes=(rz,)).run(
        [ResidentJob("j", (spec, spec))])
    out = res.outcomes["j"]
    # stage 0 finishes on a alone at t=4; stage 1 splits 4 units evenly
    # over {a, b} -> completion 6
    assert out.stages[0].completion == _approx(4.0)
    assert out.planned[1] == {"a": _approx(2.0), "b": _approx(2.0)}
    assert out.completion == _approx(6.0)
    assert set(res.alive) == {"a", "b"}


def test_allowed_mask_restricts_grants():
    """A job masked to node b never touches a: it waits for b even while
    a idles, its fair share is unchanged, and unmasked competitors soak
    up the capacity it cannot hold."""
    nodes = _two_nodes()
    jobs = [ResidentJob("open", (StaticSpec(works=(4.0,)),), priority=0),
            ResidentJob("pinned", (StaticSpec(works=(2.0,)),), priority=1,
                        allowed={"b"})]
    res = ResidentCalendar(nodes).run(jobs)
    # fair share gives each job one node; 'open' (ranked first) takes a,
    # 'pinned' can and does take b
    assert res.outcomes["open"].planned[0] == {"a": _approx(4.0)}
    assert res.outcomes["pinned"].planned[0] == {"b": _approx(2.0)}
    assert res.outcomes["pinned"].completion == _approx(2.0)
    assert_resident_match(oracle_resident(_two_nodes(), [
        ResidentJob("open", (StaticSpec(works=(4.0,)),), priority=0),
        ResidentJob("pinned", (StaticSpec(works=(2.0,)),), priority=1,
                    allowed={"b"})]), res)

    # the masked node busy: 'pinned' stalls while a sits free
    jobs2 = [ResidentJob("hog", (StaticSpec(works=(3.0,)),), priority=0,
                         allowed={"b"}),
             ResidentJob("pinned", (StaticSpec(works=(2.0,)),),
                         priority=1, allowed={"b"})]
    res2 = ResidentCalendar(_two_nodes()).run(jobs2)
    pinned = res2.outcomes["pinned"]
    assert pinned.admitted_at == _approx(3.0)   # waited for b, not a
    assert pinned.completion == _approx(5.0)
    assert_resident_match(oracle_resident(_two_nodes(), [
        ResidentJob("hog", (StaticSpec(works=(3.0,)),), priority=0,
                    allowed={"b"}),
        ResidentJob("pinned", (StaticSpec(works=(2.0,)),), priority=1,
                    allowed={"b"})]), res2)


def test_allowed_mask_whole_fleet_uses_fast_path():
    """A mask covering every node is a no-op: the single-job whole-fleet
    fast path still applies and matches run_job bitwise."""
    nodes = [SimNode.constant("a", 2.0), SimNode.constant("b", 1.0)]
    spec = StaticSpec(works=(4.0, 2.0))
    run_job_cache_clear()
    res = ResidentCalendar(nodes).run(
        [ResidentJob("j", (spec,), allowed={"a", "b"})])
    run_job_cache_clear()
    sched = run_job(nodes, [spec])
    assert res.outcomes["j"].completion == sched.completion


def test_resident_validation():
    nodes = _two_nodes()
    with pytest.raises(ValueError):
        ResidentJob("j", ())
    with pytest.raises(ValueError):       # empty mask would strand silently
        ResidentJob("j", (StaticSpec(works=(1.0,)),), allowed=())
    with pytest.raises(ValueError):
        ResidentJob("j", (StaticSpec(works=(1.0,)),), weight=0.0)
    with pytest.raises(ValueError):
        ResidentJob("j", (object(),))
    with pytest.raises(ValueError):       # mitigation belongs to run_job
        from repro.core.speculation import WorkStealing
        ResidentJob("j", (PullSpec(works=(1.0,),
                                   mitigation=WorkStealing(grain=0.5)),))
    with pytest.raises(ValueError):
        ResizeEvent(-1.0)
    with pytest.raises(ValueError):
        ResidentCalendar(nodes, recovery="magic")
    with pytest.raises(ValueError):       # trace names a node never added
        ResidentCalendar(nodes, faults=FaultTrace((NodeCrash(5, 1.0),)))
    with pytest.raises(ValueError):       # duplicate job names
        ResidentCalendar(nodes).run(
            [ResidentJob("j", (StaticSpec(works=(1.0,)),)),
             ResidentJob("j", (StaticSpec(works=(2.0,)),))])
    cal = ResidentCalendar(_two_nodes())
    cal.run([ResidentJob("j", (StaticSpec(works=(1.0, 1.0)),))])
    with pytest.raises(RuntimeError):     # single-use
        cal.run([ResidentJob("k", (StaticSpec(works=(1.0, 1.0)),))])
    assert ResidentCalendar(_two_nodes()).run([]).outcomes == {}


def test_bench_resident_orderings():
    """Acceptance rows: splice strictly beats restart-per-event on the
    same event sequence, and SLO attainment orders OA-HeMT >= HomT >=
    stale (proportions-pinned) HeMT with OA-HeMT strictly ahead of
    stale."""
    from benchmarks.bench_resident import scenario_completions

    c = scenario_completions()
    assert c["splice_makespan"] < c["restart_makespan"], c
    assert c["slo_oa_hemt"] >= c["slo_homt"], c
    assert c["slo_homt"] >= c["slo_stale"], c
    assert c["slo_oa_hemt"] > c["slo_stale"], c
