"""Per-arch reduced smoke tests + model math invariants.

Every assigned architecture: instantiate the REDUCED config, run one
forward + one train step on CPU, assert output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_bundle, get_reduced
from repro.configs.base import MoEConfig, padded_vocab_size
from repro.models import forward, init_params, loss_fn
from repro.models.attention import (
    chunked_attention, dot_product_attention, _mask_bias,
)
from repro.models.frontends import stub_feature_shape
from repro.models.model import decode_step, init_decode_state, prefill
from repro.runtime.train_loop import make_train_step, train_state_init

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch_for(cfg):
    batch = {"labels": jnp.zeros((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["input_embeds"] = jnp.ones(stub_feature_shape(cfg, B, S),
                                         jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)
    if cfg.encoder_layers > 0:
        batch["enc_feats"] = jnp.ones(stub_feature_shape(cfg, B, 16),
                                      jnp.float32) * 0.05
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    bundle = get_bundle(arch).replace(model=cfg)
    params = init_params(KEY, cfg)
    batch = _batch_for(cfg)

    logits, aux = forward(params, batch.get("tokens"), cfg,
                          input_embeds=batch.get("input_embeds"),
                          enc_feats=batch.get("enc_feats"))
    assert logits.shape == (B, S, padded_vocab_size(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    state = train_state_init(KEY, cfg, bundle)
    step = make_train_step(cfg, bundle)
    state2, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.step) == 1
    # params actually moved
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ["granite-3-8b", "gemma3-12b",
                                  "mamba2-2.7b", "jamba-1.5-large-398b",
                                  "whisper-medium"])
def test_prefill_matches_stepwise_decode(arch):
    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))  # no drops
    params = init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 10), 1,
                              cfg.vocab_size)
    kw = {}
    enc_out = None
    if cfg.encoder_layers > 0:
        kw["enc_feats"] = jnp.ones(stub_feature_shape(cfg, B, 16),
                                   jnp.float32) * 0.1
        from repro.models.model import encode
        enc_out = encode(params, kw["enc_feats"], cfg)
    logits_pf, state_pf = prefill(params, toks, cfg, 32, **kw)
    state = init_decode_state(cfg, B, 32)
    for t in range(10):
        logits_dec, state = decode_step(params, state, toks[:, t], cfg,
                                        enc_out=enc_out)
    np.testing.assert_allclose(np.asarray(logits_pf), np.asarray(logits_dec),
                               atol=5e-4)
    cache_err = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        state_pf["cache"], state["cache"])
    assert max(jax.tree.leaves(cache_err)) < 5e-4


def test_chunked_attention_equals_dense():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 80, 4, 16))
    k = jax.random.normal(ks[1], (2, 80, 2, 16))
    v = jax.random.normal(ks[2], (2, 80, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(80)[None], (2, 80))
    for causal, win in [(True, 0), (True, 17), (False, 0)]:
        want = dot_product_attention(q, k, v,
                                     _mask_bias(pos, pos, causal, win), 0.25)
        got = chunked_attention(q, k, v, causal=causal, window=win,
                                scale=0.25, block_q=32, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)


def test_chunked_attention_gradients_match():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (1, 64))

    def f_dense(q, k, v):
        return jnp.sum(dot_product_attention(
            q, k, v, _mask_bias(pos, pos, True, 0), 0.25) ** 2)

    def f_chunk(q, k, v):
        return jnp.sum(chunked_attention(
            q, k, v, causal=True, window=0, scale=0.25,
            block_q=16, block_k=32) ** 2)

    g1 = jax.grad(f_dense)(q, k, v)
    g2 = jax.grad(f_chunk)(q, k, v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=3e-4)


def test_moe_capacity_skew_shifts_tokens():
    """HeMT-EP: skewed shard capacities change per-expert slot budgets."""
    from repro.models.moe import expert_capacities
    cfg = MoEConfig(n_experts=4, top_k=2)
    even = expert_capacities(cfg, tokens_per_group=64)
    assert len(set(even.tolist())) == 1
    skew_cfg = MoEConfig(n_experts=4, top_k=2,
                         shard_capacities=(1.0, 1.0, 1.0, 0.4))
    skew = expert_capacities(skew_cfg, tokens_per_group=64)
    assert skew.sum() == even.sum()      # fixed total buffer
    assert skew[3] < skew[0]             # slow shard gets fewer slots
    ratio = skew[3] / skew[0]
    assert abs(ratio - 0.4) < 0.15


def test_moe_sort_dispatch_matches_dense_oracle():
    from repro.models.moe import moe_apply, moe_apply_dense_fallback, moe_init
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)
    p = moe_init(KEY, 32, 64, cfg, glu=True, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 16, 32))
    o1, a1 = moe_apply(p, x, cfg)
    o2, a2 = moe_apply_dense_fallback(p, x, cfg)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    assert float(a1) == pytest.approx(float(a2))


def test_pad_vocab_loss_exactness():
    """Pad-vocab logits must not leak probability mass into the loss."""
    arch = "granite-3-8b"          # 49155 -> padded 49408
    cfg = dataclasses.replace(get_reduced(arch), vocab_size=49155 % 997 + 130)
    assert padded_vocab_size(cfg) != cfg.vocab_size
    params = init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (B, S), 1, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 1, cfg.vocab_size)}
    loss = loss_fn(params, batch, cfg)
    logits, _ = forward(params, batch["tokens"], cfg)
    # manual loss over the TRUE vocab slice only
    lg = np.asarray(logits, np.float32)[..., :cfg.vocab_size]
    lp = lg - np.log(np.exp(lg - lg.max(-1, keepdims=True)).sum(-1,
                     keepdims=True)) - lg.max(-1, keepdims=True)
    nll = -np.take_along_axis(lp, np.asarray(batch["labels"])[..., None],
                              -1).mean()
    assert float(loss) == pytest.approx(nll, rel=1e-3)


def test_rope_styles():
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    full = apply_rope(x, pos, 10_000.0, "full")
    half = apply_rope(x, pos, 10_000.0, "half")
    none = apply_rope(x, pos, 10_000.0, "none")
    assert (np.asarray(none) == np.asarray(x)).all()
    # half-style passes the second half of head dims through untouched
    np.testing.assert_array_equal(np.asarray(half[..., 8:]),
                                  np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(full[..., 8:]), np.asarray(x[..., 8:]))
    # norm preserved (rotations)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(full), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)


@pytest.mark.parametrize("arch,kinds", [
    ("jamba-1.5-large-398b", ["ssm"] * 4 + ["attn"] + ["ssm"] * 3),
    ("mamba2-2.7b", ["ssm"] * 4),
    ("granite-3-8b", ["attn"] * 4),
])
def test_layer_kind_patterns(arch, kinds):
    cfg = get_reduced(arch)
    got = [cfg.layer_kind(i) for i in range(len(kinds))]
    assert got == kinds


def test_gemma3_local_global_pattern():
    cfg = get_reduced("gemma3-12b")
    pattern = [cfg.layer_is_global_attn(i) for i in range(6)]
    assert pattern == [False] * 5 + [True]


def test_chunked_xent_matches_dense():
    """Memory-lean vocab-chunked cross-entropy == dense loss, value + grad."""
    import os
    from repro.models.model import chunked_softmax_xent, hidden_states

    cfg = dataclasses.replace(get_reduced("granite-3-8b"), vocab_size=1234,
                              dtype="float32")
    prm = init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 12), 1, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (2, 12), 1, cfg.vocab_size)}

    def f_dense(p):
        os.environ["REPRO_DENSE_XENT"] = "1"
        try:
            return loss_fn(p, batch, cfg)
        finally:
            del os.environ["REPRO_DENSE_XENT"]

    def f_chunk(p):
        x, aux = hidden_states(p, batch["tokens"], cfg)
        nll = chunked_softmax_xent(x, p["embed"]["table"], batch["labels"],
                                   cfg.vocab_size, chunk=256)
        return jnp.mean(nll) + aux

    assert float(f_dense(prm)) == pytest.approx(float(f_chunk(prm)), abs=1e-4)
    g1, g2 = jax.grad(f_dense)(prm), jax.grad(f_chunk)(prm)
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2)))
    assert err < 1e-4
