"""Sharding rule engine (pure logic via a stub mesh) + data pipeline."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_bundle, get_reduced
from repro.data.pipeline import FeederPlacement, SyntheticCorpus
from repro.runtime.sharding import _spec_for, axis_rules


class StubMesh:
    """Duck-typed mesh for the pure PartitionSpec logic."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.devices = np.empty(tuple(shape.values()))


MESH = StubMesh({"data": 16, "model": 16})
MESH3 = StubMesh({"pod": 2, "data": 16, "model": 16})
RULES = {"embed": ("data",), "heads": ("model",), "vocab": ("model",),
         "batch": ("pod", "data"), "layers": None}


def test_spec_basic():
    spec = _spec_for((4096, 6144), ("embed", "heads"), MESH, RULES, None)
    assert spec == P("data", "model")


def test_spec_divisibility_fallback():
    # 49155 not divisible by 16 -> replicated on that dim
    spec = _spec_for((49155, 4096), ("vocab", "embed"), MESH, RULES, None)
    assert spec == P(None, "data")


def test_spec_duplicate_axis_dropped():
    rules = {"a": ("model",), "b": ("model",)}
    spec = _spec_for((64, 64), ("a", "b"), MESH, rules, None)
    assert spec == P("model", None)      # model axis used once only


def test_spec_multi_axis_prefix_fallback():
    # batch=16 divisible by pod(2) but not pod*data(32) -> prefix ("pod",)
    spec = _spec_for((16, 128), ("batch", None), MESH3, RULES, None)
    assert spec == P("pod", None)


def test_axis_rules_kv_fallback():
    cfg = get_bundle("granite-3-8b").model     # kv=8 < model 16
    rules = axis_rules(cfg, MESH, get_bundle("granite-3-8b").mesh)
    assert rules["kv_heads_cache"] is None
    assert rules["cache_seq"] == ("model",)
    cfg_w = get_bundle("whisper-medium").model  # kv=16 == model 16
    rules_w = axis_rules(cfg_w, MESH, get_bundle("whisper-medium").mesh)
    assert rules_w["kv_heads_cache"] == ("model",)


def test_shardings_for_on_host_mesh():
    """End-to-end sharding build on the 1-device host mesh — the same code
    path the 256/512-chip dry-run uses."""
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.sharding import param_shardings
    mesh = make_host_mesh()
    cfg = get_reduced("granite-3-8b")
    sh = param_shardings(cfg, mesh, get_bundle("granite-3-8b").mesh)
    leaves = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert all(hasattr(s, "spec") for s in leaves)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 1000), idx=st.integers(0, 10_000))
def test_corpus_index_addressable(seed, idx):
    c = SyntheticCorpus(256, 8, seed=seed)
    a, b = c.sample(idx), c.sample(idx)
    assert (a["tokens"] == b["tokens"]).all()
    assert a["tokens"].min() >= 1 and a["tokens"].max() < 256


def test_feeder_placement_balances_readers():
    fp = FeederPlacement(n_feeders=4, n_shards=16, replica=2, seed=0)
    # 16 concurrent grains on distinct shards: least-loaded replica choice
    # keeps the max-readers-per-feeder near ceil(16/4)
    assert fp.max_concurrent_readers(list(range(16))) <= 6
    # all on ONE shard: only its r=2 replicas can serve (paper's p1 case)
    assert fp.max_concurrent_readers([3] * 16) >= 8


def test_feeder_contention_probabilities_match_model():
    fp = FeederPlacement(4, 8, replica=2)
    assert fp.expected_collision_prob(same_shard=True) == pytest.approx(0.5)
    assert fp.expected_collision_prob(same_shard=False) == pytest.approx(0.25)


def test_batch_block_matches_batch_and_reuses_buffer():
    """The grain fast path: batch_block fills a preallocated [G, B, seq]
    buffer with exactly the samples batch() would stack, and reuses the
    same buffer for same-shape requests (no per-step reallocation)."""
    c = SyntheticCorpus(256, 8, seed=4)
    idx = np.arange(12).reshape(3, 4)
    block = c.batch_block(idx)
    assert block["tokens"].shape == (3, 4, 8)
    for g in range(3):
        ref = c.batch(list(idx[g]))
        assert (block["tokens"][g] == ref["tokens"]).all()
        assert (block["labels"][g] == ref["labels"]).all()
    again = c.batch_block(idx + 100)
    assert again["tokens"] is block["tokens"]          # buffer reuse
    other = c.batch_block(np.arange(8).reshape(2, 4))
    assert other["tokens"] is not block["tokens"]      # per-shape buffers


def test_load_stacked_matches_per_grain_loads():
    from repro.data.grains import Grain, GrainSource
    c = SyntheticCorpus(256, 8, seed=5)
    src = GrainSource(c, grain_batch=4)
    grains = [Grain(0, i * 4, 4) for i in range(3)]
    stacked = src.load_stacked(grains)
    for g_i, g in enumerate(grains):
        ref = src.load(g)
        assert (stacked["tokens"][g_i] == ref["tokens"]).all()
        assert (stacked["labels"][g_i] == ref["labels"]).all()
    with pytest.raises(ValueError):
        src.load_stacked([Grain(0, 0, 3)])             # ragged grain
