"""I/O-aware mitigation vs. a naive full-rescan oracle (tentpole suite).

The oracle below restates the documented I/O-mitigation semantics
(``repro.core.speculation`` module docstring) as a rescan-everything loop:
per-datanode fair-share rates recomputed from scratch at every event, every
flow advanced between consecutive event instants, full ``SimNode`` profile
walks — none of the engine's cursors, checkpoints, or version-skipped
incremental repricing.  Randomized differential suites pin
``run_stage_events(mitigation=...)`` on stages with effective I/O — and the
``run_job`` threading of mitigated-I/O specs — against it at 1e-9, covering
duplicate-fetch sharing, loser-cancel repricing, and the no-op case where
the copy never wins.
"""
import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear, run_stage_events,
)
from repro.core.hdfs_model import DuplicatePlacement
from repro.core.simulator import (
    SimNode, SimTask, TaskRecord, _stage_result,
)
from repro.core.speculation import (
    RunningAttempt, Speculate, SpeculativeCopies, WorkStealing,
)

REL = ABS = 1e-9
_EPS = 1e-9


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# the oracle: naive rescan loop with flows, per the documented semantics
# --------------------------------------------------------------------------

def oracle_stage_io(nodes, queues, pull, uplink_bw=None, mitigation=None,
                    start_time=0.0):
    """Full-rescan I/O + mitigation oracle: rates recomputed globally at
    every event, all flows advanced between events, no incremental state."""
    n = len(nodes)
    bw = uplink_bw if uplink_bw else None
    shared = list(queues[0]) if pull else None
    private = None if pull else [list(q) for q in queues]
    busy = [False] * n
    tid = [0] * n
    start = [0.0] * n
    launch = [0.0] * n
    task_work = [0.0] * n        # the attempt task's cpu_work field
    task_io = [0.0] * n          # the attempt task's io_mb field (raw)
    task_dn = [-1] * n           # the attempt task's datanode field (raw)
    att_work = [0.0] * n         # attempt work (shrinks on steal)
    att_io = [0.0] * n           # effective attempt bytes (shrinks on steal)
    io_left = [0.0] * n
    cpu_done = [0.0] * n
    twin = [-1] * n
    copied = set()
    done = []
    rechecks = {}
    records = []
    node_finish = {nd.name: start_time for nd in nodes}
    placement = getattr(mitigation, "placement", None)

    def dup_dn(d):
        return d if placement is None else placement.choose(d)

    def flow_active(i):
        return (busy[i] and bw is not None and task_dn[i] >= 0
                and io_left[i] > _EPS)

    def rates():
        cnt = {}
        for i in range(n):
            if flow_active(i):
                cnt[task_dn[i]] = cnt.get(task_dn[i], 0) + 1
        return {d: bw / c for d, c in cnt.items()}

    def start_attempt(i, task_id, w, io, d, now):
        busy[i] = True
        tid[i] = task_id
        start[i] = now
        launch[i] = now + nodes[i].task_overhead
        task_work[i] = att_work[i] = w
        task_io[i] = io
        task_dn[i] = d
        cpu_done[i] = nodes[i].finish_time(w, launch[i])
        if bw is not None and d >= 0 and io > _EPS:
            att_io[i] = io
            io_left[i] = io
        else:
            att_io[i] = 0.0
            io_left[i] = 0.0
        rechecks.pop(i, None)

    def refill(i, now):
        if pull:
            if shared:
                tk = shared.pop(0)
                start_attempt(i, tk.task_id, tk.cpu_work, tk.io_mb,
                              tk.datanode, now)
        elif private[i]:
            tk = private[i].pop(0)
            start_attempt(i, tk.task_id, tk.cpu_work, tk.io_mb,
                          tk.datanode, now)

    def remaining(k, now):
        if now < launch[k]:
            return att_work[k]
        return nodes[k].work_between(now, cpu_done[k])

    def queue_empty(i):
        return not shared if pull else not private[i]

    def offer_all(now):
        while True:
            running = [RunningAttempt(k, tid[k], start[k], att_work[k],
                                      remaining(k, now), tid[k] in copied,
                                      att_io[k])
                       for k in range(n) if busy[k]]
            if not running:
                return
            by_node = {r.node: r for r in running}
            acted = False
            for k in range(n):
                if busy[k] or not queue_empty(k):
                    continue
                act = mitigation.offer(done, running, now)
                if act is None:
                    continue
                victim = by_node[act.victim]
                j = act.victim
                if isinstance(act, Speculate):
                    # duplicate: the attempt task's full work and bytes,
                    # re-fetched from the placement-chosen datanode
                    copied.add(victim.task_id)
                    start_attempt(k, victim.task_id, task_work[j],
                                  task_io[j], dup_dn(task_dn[j]), now)
                    twin[k] = j
                    twin[j] = k
                else:                  # Steal
                    moved = 0.0
                    if att_io[j] > _EPS and victim.work > 0.0:
                        moved = att_io[j] * act.amount / victim.work
                        att_io[j] -= moved
                    att_work[j] -= act.amount
                    cpu_done[j] = nodes[j].finish_time(
                        victim.remaining - act.amount, max(now, launch[j]))
                    if moved > 0.0:
                        # the victim stops fetching the stolen range
                        # (already-streamed bytes are not refunded)
                        io_left[j] = max(0.0, io_left[j] - moved)
                    start_attempt(k, victim.task_id, act.amount, moved,
                                  dup_dn(task_dn[j]) if moved > _EPS
                                  else -1, now)
                acted = True
                break
            if not acted:
                for k in range(n):
                    if busy[k] or not queue_empty(k):
                        continue
                    nc = mitigation.next_check(done, running, now)
                    if nc is not None:
                        rechecks[k] = nc
                return

    def complete(i, now):
        records.append(TaskRecord(tid[i], nodes[i].name, start[i], now,
                                  att_work[i]))
        node_finish[nodes[i].name] = now
        busy[i] = False
        io_left[i] = 0.0
        if mitigation is None:
            refill(i, now)
            return
        done.append(now - start[i])
        loser = twin[i]
        if loser >= 0:
            # first finisher wins: the loser's in-flight flow is freed at
            # this instant (survivors reprice causally — the next rescan
            # simply sees one reader fewer)
            twin[i] = twin[loser] = -1
            busy[loser] = False
            io_left[loser] = 0.0
        refill(i, now)
        if loser >= 0:
            refill(loser, now)
        offer_all(now)

    for i in range(n):
        refill(i, start_time)
    if mitigation is not None:
        offer_all(start_time)

    t = start_time
    guard = 0
    while any(busy) or rechecks:
        guard += 1
        assert guard < 1_000_000, "oracle runaway"
        cur = rates()
        events = []
        for i in range(n):
            if not busy[i]:
                continue
            if flow_active(i):
                r = cur[task_dn[i]]
                events.append((t + io_left[i] / r, i, "io"))
            else:
                # causal completion: a flow that drained exactly when a
                # co-reader left completes no earlier than now
                events.append((max(t, cpu_done[i]), i, "done"))
        events += [(tc, i, "recheck") for i, tc in rechecks.items()
                   if not busy[i]]
        t_next, i, kind = min(events, key=lambda e: (e[0], e[1]))
        for j in range(n):
            if flow_active(j):
                io_left[j] = max(0.0,
                                 io_left[j] - cur[task_dn[j]] * (t_next - t))
        t = t_next
        if kind == "recheck":
            del rechecks[i]
            offer_all(t)
        elif kind == "io":
            io_left[i] = 0.0
            if t + _EPS >= cpu_done[i]:
                complete(i, t)
        else:
            complete(i, t)

    return _stage_result(records, node_finish, start_time)


def assert_stage_match(oracle, got):
    assert got.completion == _approx(oracle.completion)
    assert got.idle_time == _approx(oracle.idle_time)
    assert set(got.node_finish) == set(oracle.node_finish)
    for name, tt in oracle.node_finish.items():
        assert got.node_finish[name] == _approx(tt)
    ra = sorted(oracle.records, key=lambda r: (r.task_id, r.node, r.start))
    rb = sorted(got.records, key=lambda r: (r.task_id, r.node, r.start))
    assert len(ra) == len(rb)
    for a, b in zip(ra, rb):
        assert b.task_id == a.task_id and b.node == a.node
        assert b.start == _approx(a.start)
        assert b.end == _approx(a.end)
        assert b.cpu_work == _approx(a.cpu_work)


# --------------------------------------------------------------------------
# randomized generators
# --------------------------------------------------------------------------

N_DATANODES = 3


def random_cluster(rng, max_nodes=4, constant=False):
    n = int(rng.integers(2, max_nodes + 1))
    nodes = []
    for i in range(n):
        if constant:
            prof = [(0.0, float(rng.uniform(0.2, 3.0)))]
        else:
            n_seg = int(rng.integers(1, 4))
            breaks = np.concatenate(
                [[0.0], np.cumsum(rng.uniform(0.5, 5.0, n_seg - 1))])
            prof = [(float(tb), float(rng.uniform(0.2, 3.0)))
                    for tb in breaks]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.3))))
    return nodes


def random_placement(rng):
    u = rng.random()
    if u < 0.4:
        return None
    if u < 0.7:
        return DuplicatePlacement("same")
    return DuplicatePlacement("replica", N_DATANODES)


def random_policy(rng):
    if rng.random() < 0.5:
        return WorkStealing(grain=float(rng.choice([0.1, 0.25, 0.5, 1.0])),
                            placement=random_placement(rng))
    return SpeculativeCopies(
        quantile=float(rng.choice([0.5, 0.75, 0.9])),
        factor=float(rng.uniform(1.05, 3.0)),
        min_completed=int(rng.integers(1, 4)),
        io_cost_per_mb=float(rng.choice([0.0, 0.05, 0.2])),
        placement=random_placement(rng))


def random_io_tasks(rng, lo=1, hi=18):
    n_tasks = int(rng.integers(lo, hi))
    tasks = []
    for i in range(n_tasks):
        if rng.random() < 0.75:
            io = float(rng.uniform(0.3, 6.0))
            dn = int(rng.integers(0, N_DATANODES))
        else:
            io, dn = 0.0, -1
        tasks.append(SimTask(float(rng.uniform(0.01, 5.0)), io, dn,
                             task_id=i))
    return tasks


def random_uplink(rng):
    return None if rng.random() < 0.15 else float(rng.uniform(0.5, 4.0))


# --------------------------------------------------------------------------
# randomized differential suites (engine vs. oracle at 1e-9)
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_differential_io_mitigated_pull(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_io_tasks(rng)
    pol = random_policy(rng)
    bw = random_uplink(rng)
    start = float(rng.uniform(0.0, 2.0))
    oracle = oracle_stage_io(nodes, [list(tasks)], pull=True, uplink_bw=bw,
                             mitigation=pol, start_time=start)
    got = run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw,
                           start_time=start, mitigation=pol)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_io_mitigated_static(seed):
    """HeMT macrotasks reading skewed shares from shared uplinks (the
    Claim 2 x mitigation cross setting), random policies and profiles."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    n = len(nodes)
    queues = []
    for i in range(n):
        if rng.random() < 0.9:
            io = float(rng.uniform(0.3, 8.0)) if rng.random() < 0.8 else 0.0
            dn = int(rng.integers(0, N_DATANODES)) if io else -1
            queues.append([SimTask(float(rng.uniform(0.0, 8.0)), io, dn,
                                   task_id=i)])
        else:
            queues.append([])
    pol = random_policy(rng)
    bw = random_uplink(rng)
    oracle = oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                             uplink_bw=bw, mitigation=pol)
    got = run_stage_events(nodes, queues, pull=False, uplink_bw=bw,
                           mitigation=pol)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_io_unmitigated_oracle_agrees(seed):
    """Sanity on the oracle itself: with mitigation=None it must agree
    with the engine's (already differential-tested) unmitigated I/O event
    path — anchoring the mitigated comparisons above."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_io_tasks(rng)
    bw = random_uplink(rng)
    oracle = oracle_stage_io(nodes, [list(tasks)], pull=True, uplink_bw=bw)
    got = run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_run_job_mitigated_io(seed):
    """run_job threading mitigated-I/O specs (cached, shifted solves on
    constant clusters; absolute-time solves otherwise) == per-stage oracle
    runs with barriers carried by hand."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=bool(rng.random() < 0.7))
    n = len(nodes)
    pol = random_policy(rng)
    bw = float(rng.uniform(0.5, 4.0))
    specs = []
    for _ in range(int(rng.integers(1, 4))):
        if rng.random() < 0.5:
            specs.append(StaticSpec(
                works=tuple(rng.uniform(0.0, 5.0, n)), mitigation=pol,
                io_mb=float(rng.uniform(0.0, 10.0)),
                datanode=int(rng.integers(0, N_DATANODES))))
        else:
            specs.append(PullSpec(
                works=tuple(rng.uniform(0.01, 3.0,
                                        int(rng.integers(1, 12)))),
                io_mb=float(rng.uniform(0.0, 2.0)),
                datanode=int(rng.integers(0, N_DATANODES)),
                mitigation=pol))
    run_job_cache_clear()
    sched = run_job(nodes, specs, uplink_bw=bw)
    t = 0.0
    for spec, summ in zip(specs, sched.stages):
        if isinstance(spec, StaticSpec):
            ios = spec.io_split()
            queues = [[SimTask(w, ios[i], spec.datanode if ios[i] > 0
                               else -1, task_id=i)]
                      for i, w in enumerate(spec.works)]
            res = oracle_stage_io(nodes, queues, pull=False, uplink_bw=bw,
                                  mitigation=pol, start_time=t)
        else:
            tasks = [SimTask(w, spec.io_mb, spec.datanode, task_id=i)
                     for i, w in enumerate(spec.works)]
            res = oracle_stage_io(nodes, [tasks], pull=True, uplink_bw=bw,
                                  mitigation=pol, start_time=t)
        assert summ.completion == _approx(res.completion)
        assert summ.idle_time == _approx(res.idle_time)
        for nd in nodes:
            assert summ.node_finish[nd.name] == _approx(
                res.node_finish[nd.name])
        t = res.completion
    assert sched.completion == _approx(t)


# --------------------------------------------------------------------------
# crafted scenarios: fetch sharing, cancel repricing, no-op copies
# --------------------------------------------------------------------------

def test_duplicate_fetch_shares_uplink_and_copy_wins():
    """The Claim 2 x mitigation scenario: a CPU-bound straggler's copy on
    a fast node re-fetches its input through the SAME uplink and wins;
    the loser's completion never happens and the copy's fetch time
    reflects fair sharing while the primary flow is still live."""
    nodes = [SimNode.constant("fast", 1.0),
             SimNode.constant("slow", 0.1)]
    # slow: 5 units of work (50s), 4 MB input; fast: short warmup task
    queues = [[SimTask(1.0, task_id=0)],
              [SimTask(5.0, 4.0, 0, task_id=1)]]
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1)
    res = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=1.0, mitigation=pol)
    # fast done at 1.0 -> threshold 2.0 -> recheck at t=2; slow has
    # fetched 2 MB by then.  Copy launches on fast at t=2: both flows
    # share datanode 0 at rate 0.5 -> slow drains its last 2 MB at t=6
    # with the copy at 2 of its 4 MB; the copy's survivor flow reprices
    # to the full 1.0 rate and drains its last 2 MB at t=8; copy CPU
    # (5u at speed 1, launched t=2) done at t=7 -> the copy completes at
    # max(8, 7) = 8 and wins (slow's CPU would run to t=50).
    winners = [r for r in res.records if r.task_id == 1]
    assert len(winners) == 1
    assert winners[0].node == "fast"
    assert winners[0].end == _approx(8.0)
    assert res.completion == _approx(8.0)
    assert_stage_match(
        oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                        uplink_bw=1.0, mitigation=pol), res)


def test_replica_placement_dodges_contended_uplink():
    """Same scenario, replica placement: the copy reads datanode (0+1)%2
    with its own free uplink -> 4 MB at full rate, fetch done at t=6,
    CPU at t=7 -> the copy wins 3s earlier than the same-datanode copy."""
    nodes = [SimNode.constant("fast", 1.0),
             SimNode.constant("slow", 0.1)]
    queues = [[SimTask(1.0, task_id=0)],
              [SimTask(5.0, 4.0, 0, task_id=1)]]
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1,
                            placement=DuplicatePlacement("replica", 2))
    res = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=1.0, mitigation=pol)
    winners = [r for r in res.records if r.task_id == 1]
    assert winners[0].node == "fast"
    assert winners[0].end == _approx(7.0)
    assert res.completion == _approx(7.0)
    assert_stage_match(
        oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                        uplink_bw=1.0, mitigation=pol), res)


def test_loser_cancel_frees_flow_and_reprices_survivors():
    """Three flows on one uplink; when the copy wins, the cancelled
    loser's flow leaves the reader set and the surviving primary reader
    speeds up from that instant — causally, never retroactively."""
    nodes = [SimNode.constant("fast", 10.0),
             SimNode.constant("slow", 0.05),
             SimNode.constant("other", 10.0)]
    queues = [[SimTask(0.1, task_id=0)],
              [SimTask(4.0, 3.0, 0, task_id=1)],    # straggler, reading
              [SimTask(0.5, 30.0, 0, task_id=2)]]   # long co-reader
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1)
    res = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=3.0, mitigation=pol)
    oracle = oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                             uplink_bw=3.0, mitigation=pol)
    assert_stage_match(oracle, res)
    # the copy won on the fast node and the straggler produced no record
    winners = [r for r in res.records if r.task_id == 1]
    assert len(winners) == 1 and winners[0].node == "fast"
    # survivor repricing is causal: the co-reader's finish must beat the
    # constant-3-readers schedule (its flow sped up when the loser left)
    other = [r for r in res.records if r.task_id == 2][0]
    assert other.end < 30.0 / (3.0 / 3.0) - 1e-6


def test_noop_copy_never_wins_matches_oracle_and_unmitigated_when_off():
    """No-op coverage: (a) a copy that can never win (the straggler is
    I/O-bound and the copy contends on the same uplink) — the original
    still produces the only record; (b) a threshold never crossed — the
    mitigated run is bit-identical to the unmitigated one."""
    nodes = [SimNode.constant("fast", 2.0), SimNode.constant("slow", 1.0)]
    queues = [[SimTask(0.5, 1.0, 0, task_id=0)],
              [SimTask(0.5, 10.0, 0, task_id=1)]]
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1)
    res = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=1.0, mitigation=pol)
    oracle = oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                             uplink_bw=1.0, mitigation=pol)
    assert_stage_match(oracle, res)
    winners = [r for r in res.records if r.task_id == 1]
    assert len(winners) == 1 and winners[0].node == "slow"

    # (b) huge factor: nothing ever triggers -> identical to unmitigated
    off = SpeculativeCopies(quantile=0.5, factor=100.0, min_completed=1)
    base = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                            uplink_bw=1.0)
    got = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=1.0, mitigation=off)
    assert got.records == base.records
    assert got.completion == base.completion


def test_io_cost_term_delays_copy_launch():
    """The policy's re-fetch cost term: with io_cost_per_mb the trigger
    threshold rises by cost * attempt bytes, so the copy launches later
    (or never) for byte-heavy attempts."""
    nodes = [SimNode.constant("fast", 1.0), SimNode.constant("slow", 0.1)]
    queues = [[SimTask(1.0, task_id=0)], [SimTask(5.0, 4.0, 0, task_id=1)]]
    free = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1)
    priced = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1,
                               io_cost_per_mb=1.0)
    r_free = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                              uplink_bw=1.0, mitigation=free)
    r_priced = run_stage_events(nodes, [list(q) for q in queues],
                                pull=False, uplink_bw=1.0,
                                mitigation=priced)
    assert_stage_match(
        oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                        uplink_bw=1.0, mitigation=priced), r_priced)
    start_free = min(r.start for r in r_free.records
                     if r.task_id == 1 and r.node == "fast")
    start_priced = min(r.start for r in r_priced.records
                       if r.task_id == 1 and r.node == "fast")
    # threshold shifted by io_cost_per_mb * 4 MB = 4s
    assert start_priced == _approx(start_free + 4.0)


def test_steal_moves_unfetched_bytes_with_the_work():
    """Stealing on an I/O stage: the thief re-fetches the stolen range's
    byte share as a new flow and the victim stops fetching that range."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 0.25)]
    queues = [[SimTask(1.0, task_id=0)], [SimTask(8.0, 8.0, 0, task_id=1)]]
    pol = WorkStealing(grain=1.0)
    res = run_stage_events(nodes, [list(q) for q in queues], pull=False,
                           uplink_bw=4.0, mitigation=pol)
    oracle = oracle_stage_io(nodes, [list(q) for q in queues], pull=False,
                             uplink_bw=4.0, mitigation=pol)
    assert_stage_match(oracle, res)
    pieces = {r.node: r for r in res.records if r.task_id == 1}
    assert set(pieces) == {"a", "b"}
    # mitigation helped: without it b alone runs 8u at 0.25 = 32s
    base = run_stage_events(nodes, [[SimTask(1.0, task_id=0)],
                                    [SimTask(8.0, 8.0, 0, task_id=1)]],
                            pull=False, uplink_bw=4.0)
    assert res.completion < base.completion


# --------------------------------------------------------------------------
# run_job solve caching: start-invariance, no poisoning
# --------------------------------------------------------------------------

def test_run_job_mitigated_io_cache_no_poisoning():
    """Mitigated-I/O solves are start-invariant on constant clusters, so
    the solve LRU may cache them — pinned here: repeated and interleaved
    mitigated-I/O stages (within one job and across warm-cache re-runs)
    must equal fresh absolute-time event solves, and a different
    uplink_bw must not reuse the entry."""
    nodes = [SimNode.constant(f"n{i}", s, 0.1)
             for i, s in enumerate([1.0, 1.0, 0.3])]
    pol = SpeculativeCopies(quantile=0.5, factor=1.3, min_completed=1)
    spec_a = StaticSpec(works=(3.0, 3.0, 3.0), mitigation=pol, io_mb=6.0,
                        datanode=0)
    spec_b = PullSpec(works=(1.0,) * 6, io_mb=0.5, datanode=1,
                      mitigation=WorkStealing(grain=0.25))
    specs = [spec_a, spec_b, spec_a, spec_a]
    run_job_cache_clear()
    sched = run_job(nodes, specs, uplink_bw=2.0)
    warm = run_job(nodes, specs, uplink_bw=2.0)   # warm module-level LRU

    t = 0.0
    from repro.core.engine import _spec_tasks
    for spec, summ, wsumm in zip(specs, sched.stages, warm.stages):
        res = run_stage_events(nodes, _spec_tasks(spec),
                               pull=isinstance(spec, PullSpec),
                               uplink_bw=2.0, start_time=t,
                               mitigation=spec.mitigation)
        assert summ.completion == _approx(res.completion)
        for nd in nodes:
            assert summ.node_finish[nd.name] == _approx(
                res.node_finish[nd.name])
            assert wsumm.node_finish[nd.name] == _approx(
                res.node_finish[nd.name])
        t = res.completion
    # a different uplink_bw keys a different solve: no stale reuse
    other = run_job(nodes, [spec_a], uplink_bw=0.5)
    fresh = run_stage_events(nodes, _spec_tasks(spec_a), pull=False,
                             uplink_bw=0.5, mitigation=pol)
    assert other.completion == _approx(fresh.completion)


def test_static_spec_io_split_and_unmitigated_routing():
    """StaticSpec I/O semantics: io_mb splits proportionally to works
    (evenly when all-zero), and an unmitigated static stage with
    effective I/O routes to the event calendar inside run_job."""
    spec = StaticSpec(works=(1.0, 3.0), io_mb=8.0, datanode=0)
    assert spec.io_split() == _approx((2.0, 6.0))
    assert StaticSpec(works=(0.0, 0.0), io_mb=8.0,
                      datanode=0).io_split() == _approx((4.0, 4.0))
    assert StaticSpec(works=(1.0, 3.0)).io_split() == (0.0, 0.0)

    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    run_job_cache_clear()
    sched = run_job(nodes, [spec], uplink_bw=1.0)
    oracle = oracle_stage_io(
        nodes, [[SimTask(1.0, 2.0, 0, task_id=0)],
                [SimTask(3.0, 6.0, 0, task_id=1)]], pull=False,
        uplink_bw=1.0)
    assert sched.completion == _approx(oracle.completion)
    # without uplink the closed static form applies: max(works) = 3
    run_job_cache_clear()
    assert run_job(nodes, [spec]).completion == _approx(3.0)


def test_scheduler_surfaces_thread_io_mitigation():
    """MultiStageJob and AdaptiveHeMTScheduler expose the cross
    experiment: stale HeMT on a network-fed cluster recovers with an
    I/O-aware policy."""
    from repro.core.scheduler import AdaptiveHeMTScheduler, MultiStageJob

    nodes = [SimNode.constant(f"e{i}", s, 0.05)
             for i, s in enumerate([1.0, 1.0, 0.25])]
    job = MultiStageJob(stage_works=[6.0] * 3, stage_io_mb=[6.0] * 3,
                        datanode=0)
    weights = [1.0, 1.0, 1.0]                     # stale: even skew
    total_plain, _ = job.run(nodes, weights, uplink_bw=4.0)
    pol = SpeculativeCopies(quantile=0.5, factor=1.3, min_completed=1)
    total_spec, _ = job.run(nodes, weights, mitigation=pol, uplink_bw=4.0)
    assert total_spec < total_plain
    # records mode agrees with the spec path
    total_rec, results = job.run(nodes, weights, records=True,
                                 mitigation=pol, uplink_bw=4.0)
    assert total_rec == _approx(total_spec)
    assert all(res.records for res in results)

    def factory(_k):
        return [SimNode.constant(f"e{i}", v, 0.05)
                for i, v in enumerate([1.0, 1.0, 0.25])]

    plain = AdaptiveHeMTScheduler([f"e{i}" for i in range(3)])
    plain.run_simulated_sequence(factory, 3, total_work=9.0,
                                 io_mb_total=9.0, uplink_bw=6.0)
    mit = AdaptiveHeMTScheduler([f"e{i}" for i in range(3)],
                                mitigation=pol)
    mit.run_simulated_sequence(factory, 3, total_work=9.0,
                               io_mb_total=9.0, uplink_bw=6.0)
    assert mit.history[0].completion < plain.history[0].completion
    # the estimator still converges near the balanced optimum
    opt = 9.0 / sum([1.0, 1.0, 0.25])
    assert mit.history[-1].completion == pytest.approx(opt, rel=0.3)


def test_bench_speculation_io_reproduces_claim2_cross_ordering():
    """Acceptance row: on the network-governed shuffle with stale
    estimates, HeMT rescued by an I/O-aware duplicate reader beats the
    unmitigated stale split, which in turn beats overhead-taxed HomT —
    the Claim 2 x mitigation cross the paper predicts."""
    from benchmarks.bench_speculation_io import scenario_completions

    c = scenario_completions()
    best = min(c["hemt_io_spec"], c["hemt_io_spec_replica"],
               c["hemt_io_steal"])
    assert best < c["hemt_io"] < c["homt_io"], c
    assert c["hemt_io_spec"] < c["hemt_io"]
    assert c["hemt_io_spec_replica"] <= c["hemt_io_spec"] + 1e-9
    assert c["hemt_io_steal"] < c["hemt_io"]


@given(seed=st.integers(0, 2_000))
def test_oracle_has_no_infinite_rates(seed):
    """Guard on the oracle's own soundness: rates stay finite whenever a
    flow is active (bw None disables flows entirely)."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=True)
    tasks = random_io_tasks(rng, hi=8)
    res = oracle_stage_io(nodes, [list(tasks)], pull=True, uplink_bw=None)
    assert math.isfinite(res.completion)
