"""Runtime: HeMT trainer modes, grain-accumulation exactness, planner,
elasticity, fault tolerance, serve batching, compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchBundle, TrainConfig, get_reduced
from repro.core.planner import GrainPlanner, WorkStealingQueue
from repro.data.grains import plan_grain_ranges
from repro.data.pipeline import SyntheticCorpus
from repro.optim.compression import (
    compress_decompress, compression_init, wire_bytes,
)
from repro.runtime.elastic import replan, scale_event_log
from repro.runtime.ft import FleetMonitor, Heartbeat
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.serve_loop import HeMTBatcher, make_serve_step
from repro.runtime.train_loop import (
    grain_acc_init, make_apply_step, make_grain_step, make_train_step,
    train_state_init,
)

KEY = jax.random.PRNGKey(0)


def _tiny():
    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=50))
    return cfg, bundle


# --------------------------------------------------------------------------
# grain accumulation == monolithic step
# --------------------------------------------------------------------------

def test_grain_accumulation_matches_full_batch():
    cfg, bundle = _tiny()
    corpus = SyntheticCorpus(cfg.vocab_size, 32, seed=1)
    full = corpus.batch(range(8))
    batch = {k: jnp.asarray(v) for k, v in full.items()}

    state0 = train_state_init(KEY, cfg, bundle)
    full_step = make_train_step(cfg, bundle)
    s_full, m_full = jax.jit(full_step)(state0, batch)

    grain_step = make_grain_step(cfg, bundle)
    apply_step = make_apply_step(cfg, bundle)
    acc = grain_acc_init(state0.params)
    for lo in range(0, 8, 2):
        grain = {k: v[lo:lo + 2] for k, v in batch.items()}
        acc = grain_step(state0.params, acc, grain)
    s_acc, m_acc = apply_step(state0, acc, jnp.asarray(4))

    # same loss (mean of grain means == full-batch mean: equal grain sizes)
    assert float(m_acc["loss"]) == pytest.approx(float(m_full["loss"]),
                                                 rel=1e-5)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s_full.params, s_acc.params)
    assert max(jax.tree.leaves(err)) < 5e-2  # bf16 params, fp32 math


def test_training_descends():
    cfg, bundle = _tiny()
    slices = [SliceSpec("s0"), SliceSpec("s1")]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=8,
                     seq_len=32, mode="hemt")
    st = train_state_init(KEY, cfg, bundle)
    losses = []
    for _ in range(12):
        st, rep = tr.run_step(st)
        losses.append(rep.loss)
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


# --------------------------------------------------------------------------
# the paper's completion-time ordering, on real training
# --------------------------------------------------------------------------

def test_mode_ordering_under_heterogeneity():
    cfg, bundle = _tiny()
    slices = [SliceSpec("fast", [(0.0, 1.0)], 0.05),
              SliceSpec("slow", [(0.0, 0.4)], 0.05)]
    results = {}
    for mode in ("hemt", "homt", "static-even"):
        tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                         seq_len=16, mode=mode, grain_cost=1.0)
        st = train_state_init(KEY, cfg, bundle)
        st = tr.run(st, 6)
        steady = tr.reports[2:]
        results[mode] = float(np.mean([r.makespan for r in steady]))
    # HeMT <= HomT <= static-even (paper's core claim)
    assert results["hemt"] < results["homt"] < results["static-even"]


def test_identical_math_across_modes():
    cfg, bundle = _tiny()
    slices = [SliceSpec("fast", [(0.0, 1.0)]), SliceSpec("slow", [(0.0, 0.4)])]
    finals = {}
    for mode in ("hemt", "homt", "static-even"):
        tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=8,
                         seq_len=16, mode=mode)
        st = train_state_init(KEY, cfg, bundle)
        st = tr.run(st, 3)
        finals[mode] = float(tr.reports[-1].loss)
    assert finals["hemt"] == pytest.approx(finals["homt"], abs=1e-6)
    assert finals["hemt"] == pytest.approx(finals["static-even"], abs=1e-6)


def test_run_step_issues_one_accumulate_dispatch_per_step():
    """The batched fast path folds all grains of a step with one jitted
    lax.scan call — O(1) dispatches per step, not O(grains)."""
    cfg, bundle = _tiny()
    slices = [SliceSpec("s0"), SliceSpec("s1")]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                     seq_len=16, mode="hemt")
    st = train_state_init(KEY, cfg, bundle)
    st = tr.run(st, 3)                  # 3 steps x 8 grains each
    assert tr.grain_dispatches == 3


def test_batched_accumulate_matches_per_grain_loop():
    """lax.scan fold == the per-grain python loop, grain for grain."""
    import numpy as np
    from repro.data.pipeline import SyntheticCorpus
    from repro.runtime.train_loop import make_grain_accumulate
    cfg, bundle = _tiny()
    corpus = SyntheticCorpus(cfg.vocab_size, 16, seed=3)
    batches = [corpus.batch(range(i * 2, i * 2 + 2)) for i in range(4)]

    state = train_state_init(KEY, cfg, bundle)
    grain_step = make_grain_step(cfg, bundle)
    acc_loop = grain_acc_init(state.params)
    for b in batches:
        acc_loop = grain_step(state.params, acc_loop,
                              {k: jnp.asarray(v) for k, v in b.items()})

    accumulate = make_grain_accumulate(cfg, bundle)
    stacked = {k: jnp.asarray(np.stack([b[k] for b in batches]))
               for k in batches[0]}
    acc_scan = accumulate(state.params, grain_acc_init(state.params), stacked)

    assert int(acc_scan.n) == int(acc_loop.n) == 4
    assert float(acc_scan.loss_sum) == pytest.approx(
        float(acc_loop.loss_sum), rel=1e-5)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                       acc_loop.grads, acc_scan.grads)
    assert max(jax.tree.leaves(err)) < 1e-4


def test_interference_triggers_reskew():
    """Paper Fig 7 in the training loop: slice slows mid-run, plan adapts."""
    cfg, bundle = _tiny()
    slices = [SliceSpec("a", [(0.0, 1.0)], 0.02),
              SliceSpec("b", [(0.0, 1.0), (30.0, 0.25)], 0.02)]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                     seq_len=16, mode="hemt", alpha=0.0, grain_cost=1.0)
    st = train_state_init(KEY, cfg, bundle)
    st = tr.run(st, 10)
    early = tr.reports[2]
    late = tr.reports[-1]
    assert abs(early.grain_counts["a"] - early.grain_counts["b"]) <= 1
    assert late.grain_counts["a"] >= 6   # ~1.0 : 0.25 -> 6/7 : 2/1


# --------------------------------------------------------------------------
# planner + elasticity + FT
# --------------------------------------------------------------------------

def test_planner_modes_and_resize():
    p = GrainPlanner(["a", "b", "c"], alpha=0.0)
    plan = p.plan(12)
    assert plan.grains == [4, 4, 4]          # cold start = even
    p.observe_step({"a": {"grains": 4, "elapsed": 1.0},
                    "b": {"grains": 4, "elapsed": 2.0},
                    "c": {"grains": 4, "elapsed": 4.0}})
    plan = p.plan(14)
    assert plan.grains[0] > plan.grains[1] > plan.grains[2] >= 1
    # elastic: c dies; newcomer d cold-starts at survivor mean
    new = replan(p, ["a", "b"], ["d"])
    assert new == ["a", "b", "d"]
    plan = p.plan(12)
    assert sum(plan.grains) == 12
    assert len(scale_event_log(p)) == 3


def test_work_stealing_queue():
    q = WorkStealingQueue()
    q.seed(10)
    got = q.pull(3)
    assert got == [0, 1, 2] and len(q) == 7 and q.steals == 1


def test_fleet_monitor_death_and_recovery():
    m = FleetMonitor(["a", "b"], timeout=2.0)
    m.heartbeat(Heartbeat("a", 1.0, 4, 1.0))
    m.heartbeat(Heartbeat("b", 1.0, 4, 1.0))
    dead, _ = m.check(1.5)
    assert dead == []
    dead, _ = m.check(3.5)                 # both last seen at 1.0
    assert set(dead) == {"a", "b"}
    m.heartbeat(Heartbeat("a", 4.0, 4, 1.0))
    assert m.alive() == ["a"]
    assert any(e.kind == "recovered" for e in m.events)


def test_fleet_monitor_straggler_signal():
    m = FleetMonitor(["a", "b", "c", "d"], timeout=100.0)
    for name, rate in zip("abcd", [4.0, 4.2, 3.9, 0.5]):
        m.heartbeat(Heartbeat(name, 1.0, int(rate * 10), 10.0))
    _, stragglers = m.check(1.0)
    assert len(stragglers) == 1


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def test_hemt_batcher_learns_replica_speeds():
    b = HeMTBatcher(["r0", "r1"], alpha=0.0, min_share=1)
    first = b.dispatch(10)
    assert first == {"r0": 5, "r1": 5}
    b.observe("r0", 100, 1.0)
    b.observe("r1", 100, 2.5)              # 0.4x replica
    second = b.dispatch(14)
    assert second == {"r0": 10, "r1": 4}
    assert b.predicted_sync_delay(second) < b.predicted_sync_delay(first)


def test_hemt_batcher_min_share_floor_under_extreme_skew():
    """A 100:1 replica must still receive its floor — starving it would
    stop the AR(1) loop from ever observing a recovery (paper §5.1's
    averaging argument needs every executor fed)."""
    b = HeMTBatcher(["fast", "crawl"], alpha=0.0, min_share=1)
    b.observe("fast", 1000, 1.0)
    b.observe("crawl", 10, 1.0)
    shares = b.dispatch(20)
    assert shares["crawl"] == 1 and shares["fast"] == 19
    # without the floor the crawler is starved outright
    b0 = HeMTBatcher(["fast", "crawl"], alpha=0.0)
    b0.observe("fast", 1000, 1.0)
    b0.observe("crawl", 10, 1.0)
    assert b0.dispatch(20)["crawl"] == 0


def test_hemt_batcher_full_forget_tracks_drift():
    """alpha=0 keeps only the latest sample (the estimator's full-forget
    convention — alpha is the weight on history, and 1.0 is rejected),
    so a throttled replica's share collapses within one round."""
    b = HeMTBatcher(["a", "b"], alpha=0.0)
    b.observe("a", 100, 1.0)
    b.observe("b", 100, 1.0)
    assert b.dispatch(12) == {"a": 6, "b": 6}
    b.observe("a", 100, 1.0)
    b.observe("b", 25, 1.0)               # credit exhaustion: 4x slower
    assert b.dispatch(10) == {"a": 8, "b": 2}
    # sticky history (alpha=0.9) barely moves after the same drift
    s = HeMTBatcher(["a", "b"], alpha=0.9)
    s.observe("a", 100, 1.0)
    s.observe("b", 100, 1.0)
    s.observe("a", 100, 1.0)
    s.observe("b", 25, 1.0)
    sticky = s.dispatch(10)
    assert sticky["b"] >= 4
    with pytest.raises(ValueError):
        HeMTBatcher(["a"], alpha=1.0)     # estimator rejects alpha=1


def test_hemt_batcher_resize_mid_stream():
    """Removing a replica drops its estimate for good; a later re-add
    cold-starts at the survivors' mean instead of resurrecting the stale
    speed."""
    b = HeMTBatcher(["a", "b", "c"], alpha=0.0)
    b.observe("a", 200, 1.0)
    b.observe("b", 100, 1.0)
    b.observe("c", 10, 1.0)               # the replica about to die
    b.resize(["a", "b"])
    assert b.replicas == ["a", "b"]
    assert b.dispatch(12) == {"a": 8, "b": 4}
    b.resize(["a", "b", "c"])             # replacement with the old name
    shares = b.dispatch(12)
    # cold c is filled with mean(200, 100) = 150: 200:100:150 over 12
    assert shares == {"a": 5, "b": 3, "c": 4}


def test_hemt_batcher_deterministic_split_under_ties():
    """Equal-speed replicas tie on every fractional remainder; the split
    must still be a pure function of the inputs (largest-remainder with
    a stable order), so repeated dispatches agree exactly."""
    b = HeMTBatcher([f"r{i}" for i in range(4)], alpha=0.0)
    for r in b.replicas:
        b.observe(r, 100, 1.0)
    first = b.dispatch(10)
    assert all(b.dispatch(10) == first for _ in range(5))
    assert sum(first.values()) == 10
    assert sorted(first.values()) == [2, 2, 3, 3]
    # even-mode ties resolve identically
    e = HeMTBatcher([f"r{i}" for i in range(4)], mode="even")
    assert e.dispatch(10) == {"r0": 3, "r1": 3, "r2": 2, "r3": 2}


def test_hemt_batcher_plan_shares_estimator_state():
    b = HeMTBatcher(["a", "b"], alpha=0.0)
    plan = b.plan()
    assert plan.estimator is b.estimator
    b.observe("a", 100, 1.0)
    b.observe("b", 50, 1.0)
    assert plan.estimator.speeds(["a", "b"]) == [100.0, 50.0]


def test_hemt_batcher_straggling_flags_below_median():
    b = HeMTBatcher(["a", "b", "c"], alpha=0.0)
    assert b.straggling() == []           # cold estimator: no signal
    b.observe("a", 100, 1.0)
    b.observe("b", 90, 1.0)
    b.observe("c", 30, 1.0)               # 3x below the median (90)
    assert b.straggling(factor=2.0) == ["c"]
    assert b.straggling(factor=4.0) == []
    with pytest.raises(ValueError):
        b.straggling(factor=0.5)


def test_serve_step_generates():
    cfg, bundle = _tiny()
    from repro.models.model import init_decode_state, init_params
    params = init_params(KEY, cfg)
    step = jax.jit(make_serve_step(cfg))
    state = init_decode_state(cfg, 2, 8)
    tok = jnp.ones((2,), jnp.int32)
    toks = []
    for _ in range(5):
        tok, logits, state = step(params, state, tok)
        toks.append(np.asarray(tok))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        assert int(tok.min()) >= 0 and int(tok.max()) < cfg.vocab_size
    assert int(state["length"]) == 5


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback_converges(scheme):
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(128,)),
                          jnp.float32)}
    cs = compression_init(g)
    total = jnp.zeros((128,))
    n = 30
    for _ in range(n):
        sent, cs = compress_decompress(g, cs, scheme=scheme, topk_frac=0.05)
        total = total + sent["w"]
    # EF: cumulative sent + residual error == cumulative true gradient
    resid = float(jnp.max(jnp.abs(total + cs.error["w"] - n * g["w"])))
    assert resid < 1e-3


def test_wire_bytes_ordering():
    g = {"w": jnp.zeros((1000,), jnp.float32)}
    assert wire_bytes(g, "topk", 0.01) < wire_bytes(g, "int8") \
        < wire_bytes(g, "none")


# --------------------------------------------------------------------------
# grain planning / data determinism
# --------------------------------------------------------------------------

def test_grain_ranges_cover_step_batch():
    ga = plan_grain_ranges(3, 32, 4, ["a", "b"], [5, 3])
    idx = [i for grains in ga.per_slice.values()
           for g in grains for i in g.indices()]
    assert sorted(idx) == list(range(96, 128))


def test_corpus_determinism_and_batch():
    c = SyntheticCorpus(512, 16, seed=9)
    assert (c.sample(5)["tokens"] == c.sample(5)["tokens"]).all()
    b = c.batch([1, 2, 3])
    assert b["tokens"].shape == (3, 16)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_training_descends_under_dcn_compression(scheme):
    """EF-compressed gradients (the DCN all-reduce payload) still learn.

    Descent is measured on a fixed probe batch before vs. after training:
    the running loss is evaluated on a *different* synthetic batch each step,
    and its ~0.1-nat inter-batch difficulty spread swamps the few-step trend.
    """
    import dataclasses as dc
    from repro.data.pipeline import SyntheticCorpus
    from repro.models.model import loss_fn
    cfg, bundle = _tiny()
    bundle = bundle.replace(train=dc.replace(bundle.train,
                                             compression=scheme))
    corpus = SyntheticCorpus(cfg.vocab_size, 24, seed=2)
    probe = {k: jnp.asarray(v) for k, v in corpus.batch(range(8)).items()}
    eval_loss = jax.jit(lambda p: loss_fn(p, probe, cfg))
    step = jax.jit(make_train_step(cfg, bundle))
    state = train_state_init(KEY, cfg, bundle)
    before = float(eval_loss(state.params))
    for s in range(10):
        batch = {k: jnp.asarray(v)
                 for k, v in corpus.batch(range(s * 8, s * 8 + 8)).items()}
        state, _ = step(state, batch)
    assert float(eval_loss(state.params)) < before - 0.05


def test_speculative_copies():
    from repro.core.straggler import speculative_copies
    done = {0: 1.0, 1: 1.2, 2: None}
    running = {2: 0.5}
    # at t=1.5, task 2 has run 1.0 < 2x median(1.1) -> no speculation yet
    assert speculative_copies(done, 1.5, running) == []
    # at t=3.0 it exceeds the timeout factor -> relaunch
    assert speculative_copies(done, 3.0, running) == [2]


# --------------------------------------------------------------------------
# elastic resize + fault-recovery loop (repro.runtime.elastic / faults)
# --------------------------------------------------------------------------

def test_elastic_replan_with_no_survivors_raises():
    p = GrainPlanner(["a", "b"], alpha=0.0)
    with pytest.raises(RuntimeError, match="no slices left"):
        replan(p, [], [])


def test_elastic_newcomer_cold_starts_at_survivor_mean():
    """Paper §5.1's L_k^o replacement rule: a slice that joins after a
    resize starts at the mean of the survivors' AR(1) estimates."""
    p = GrainPlanner(["a", "b", "c"], alpha=0.0)
    p.observe_step({"a": {"grains": 4, "elapsed": 1.0},     # 4 grains/s
                    "b": {"grains": 4, "elapsed": 2.0},     # 2 grains/s
                    "c": {"grains": 4, "elapsed": 4.0}})    # 1 grain/s
    replan(p, ["a", "b"], ["d"])                            # c died, d joins
    assert p.estimator.speed("c") is None                   # forgotten
    sp = p.estimator.speeds(["a", "b", "d"])
    assert sp[0] == pytest.approx(4.0)                      # survivors keep
    assert sp[1] == pytest.approx(2.0)
    assert sp[2] == pytest.approx(3.0)                      # mean of (4, 2)


def test_reshard_restore_requires_a_checkpoint():
    from repro.runtime.elastic import reshard_restore

    class _Empty:
        def restore_latest(self, state_like):
            return None

    class _Full:
        def restore_latest(self, state_like):
            return 7, {"w": jnp.ones(2)}, {}

    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        reshard_restore(_Empty(), {"w": jnp.zeros(2)})
    step, state = reshard_restore(_Full(), {"w": jnp.zeros(2)})
    assert step == 7
    assert float(state["w"].sum()) == pytest.approx(2.0)


def test_fleet_monitor_straggler_episode_events():
    """Straggler events carry the stable slice name and fire once per
    episode: one "straggler" on entry, one "recovered" on exit, nothing
    on repeated checks in between."""
    m = FleetMonitor(["a", "b", "c", "d"], timeout=100.0)
    for name, rate in zip("abcd", [4.0, 4.2, 3.9, 0.5]):
        m.heartbeat(Heartbeat(name, 1.0, int(rate * 10), 10.0))
    _, reports = m.check(1.0)
    assert [r.name for r in reports] == ["d"]
    m.check(2.0)                           # same episode: no new event
    strag = [e for e in m.events if e.kind == "straggler"]
    assert [(e.slice_name, e.at) for e in strag] == [("d", 1.0)]
    m.heartbeat(Heartbeat("d", 3.0, 40, 10.0))   # back to 4 grains/s
    m.check(3.0)
    rec = [e for e in m.events if e.kind == "recovered"]
    assert [(e.slice_name, e.detail) for e in rec] == \
        [("d", "straggler episode ended")]
    # a second episode re-arms the event
    m.heartbeat(Heartbeat("d", 4.0, 5, 10.0))
    m.check(4.0)
    strag = [e for e in m.events if e.kind == "straggler"]
    assert [e.at for e in strag] == [1.0, 4.0]


def test_trainer_window_detects_crash_and_replans_survivors():
    """The detection->recovery loop inside one oa-hemt driver window: a
    fault trace kills a slice mid-window, its heartbeats stop (an
    alive-masked barrier hands it zero grains), the FleetMonitor declares
    it dead, and the window's remaining barriers re-schedule over the
    survivor via elastic.replan — all in one run_window call."""
    from repro.core.faults import FaultTrace, NodeCrash

    cfg, bundle = _tiny()
    slices = [SliceSpec("fast", [(0.0, 1.0)], 0.05),
              SliceSpec("slow", [(0.0, 1.0)], 0.05)]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                     seq_len=16, mode="oa-hemt", grain_cost=1.0)
    trace = FaultTrace((NodeCrash(1, 6.0),))    # permanent, mid-step-1
    m = FleetMonitor(["fast", "slow"], timeout=4.0)
    st = train_state_init(KEY, cfg, bundle)
    st = tr.run_window(st, 6, faults=trace, monitor=m)
    assert int(st.step) == 6                    # every barrier executed
    assert len(tr.reports) == 6
    assert [s.name for s in tr.slices] == ["fast"]
    assert m.alive() == ["fast"]
    dead = [e for e in m.events if e.kind == "dead"]
    assert [e.slice_name for e in dead] == ["slow"]
    # each step still processes the whole global batch, in whole grains
    for rep in tr.reports:
        assert sum(rep.grain_counts.values()) == tr.n_grains
        assert np.isfinite(rep.loss)
    # after the elastic replan the survivor carries the full batch
    assert tr.reports[-1].grain_counts == {"fast": 8}


def test_trainer_per_step_mode_rejects_fault_wiring():
    from repro.core.faults import FaultTrace, NodeCrash

    cfg, bundle = _tiny()
    tr = HeMTTrainer(cfg, bundle, [SliceSpec("a", [(0.0, 1.0)], 0.05)],
                     grain_batch=2, global_batch=4, seq_len=16, mode="hemt")
    st = train_state_init(KEY, cfg, bundle)
    with pytest.raises(ValueError, match="windowed scheduling"):
        tr.run_window(st, 1, faults=FaultTrace((NodeCrash(0, 1.0),)))


def test_fleet_exhausted_error_carries_estimates():
    """replan on an empty fleet raises the typed FleetExhaustedError with
    the planner's last-known speeds; legacy except-RuntimeError callers
    (and message matchers) keep working."""
    from repro.runtime.elastic import FleetExhaustedError

    p = GrainPlanner(["a", "b"], alpha=0.0)
    p.observe_step({"a": {"grains": 4, "elapsed": 2.0},
                    "b": {"grains": 4, "elapsed": 4.0}})
    with pytest.raises(FleetExhaustedError) as ei:
        replan(p, [], [])
    err = ei.value
    assert isinstance(err, RuntimeError)
    assert str(err) == "no slices left after resize"
    assert err.estimates == pytest.approx({"a": 2.0, "b": 1.0})
    # a planner that never observed anything still raises, with no payload
    with pytest.raises(FleetExhaustedError) as ei:
        replan(GrainPlanner(["x"]), [])
    assert ei.value.estimates == {}
    # legacy pattern: message-matching RuntimeError handlers
    try:
        replan(p, [])
    except RuntimeError as e:
        assert "no slices left" in str(e)
    else:
        raise AssertionError("replan on empty fleet must raise")


def test_trainer_window_exhausted_fleet_halts_gracefully():
    """The whole fleet dies mid-window: the stranded tail is abandoned,
    elastic.replan's FleetExhaustedError is caught (not propagated), the
    trainer records the last-known estimates on self.exhausted, and the
    monitor logs the terminal 'exhausted' event."""
    from repro.core.faults import FaultTrace, NodeCrash

    cfg, bundle = _tiny()
    tr = HeMTTrainer(cfg, bundle, [SliceSpec("solo", [(0.0, 1.0)], 0.05)],
                     grain_batch=2, global_batch=4, seq_len=16,
                     mode="oa-hemt", grain_cost=1.0)
    m = FleetMonitor(["solo"], timeout=4.0)
    st = train_state_init(KEY, cfg, bundle)
    assert tr.exhausted is None
    # step 0 finishes (~2.05s); the permanent crash at 3.0 strands the rest
    st = tr.run_window(st, 3, faults=FaultTrace((NodeCrash(0, 3.0),)),
                       monitor=m)
    assert int(st.step) == 1                 # only the pre-crash barrier ran
    assert len(tr.reports) == 1
    assert tr.slices == []                   # the stranded slice was dropped
    assert tr.exhausted is not None and "solo" in tr.exhausted
    assert m.exhausted
    term = [e for e in m.events if e.kind == "exhausted"]
    assert len(term) == 1 and term[0].slice_name == "*"
    assert "solo" in term[0].detail
