"""Fast-path engine vs. the legacy ``_run_stage`` oracle.

Differential property tests: randomized clusters (multi-segment speed
profiles, per-task overheads, flow-shared I/O) run through both the event
calendar and the public auto-selecting entry points must agree with the
oracle on completion, idle time, per-node finishes and per-task records to
1e-9.  Plus closed-form/event-path equivalence, tie-breaking, cursor
exactness, and the idle-time accounting fix.
"""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    ProfileCursor, PullSpec, StaticSpec, plan_path, run_job,
    run_stage_events, simulate_stage,
)
from repro.core.scheduler import MultiStageJob
from repro.core.simulator import (
    SimNode, SimTask, _run_stage, run_pull_stage, run_static_stage,
)

REL = ABS = 1e-9


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


def assert_results_match(oracle, got):
    assert got.completion == _approx(oracle.completion)
    assert got.idle_time == _approx(oracle.idle_time)
    assert set(got.node_finish) == set(oracle.node_finish)
    for name, t in oracle.node_finish.items():
        assert got.node_finish[name] == _approx(t)
    ra = {r.task_id: r for r in oracle.records}
    rb = {r.task_id: r for r in got.records}
    assert ra.keys() == rb.keys()
    for tid, a in ra.items():
        b = rb[tid]
        assert b.node == a.node, f"task {tid}: {b.node} != {a.node}"
        assert b.start == _approx(a.start)
        assert b.end == _approx(a.end)
        assert b.cpu_work == _approx(a.cpu_work)


def random_cluster(rng, max_nodes=4, constant=False):
    n = int(rng.integers(1, max_nodes + 1))
    nodes = []
    for i in range(n):
        if constant:
            prof = [(0.0, float(rng.uniform(0.2, 3.0)))]
        else:
            n_seg = int(rng.integers(1, 4))
            breaks = np.concatenate(
                [[0.0], np.cumsum(rng.uniform(0.5, 5.0, n_seg - 1))])
            prof = [(float(t), float(rng.uniform(0.2, 3.0))) for t in breaks]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.3))))
    return nodes


def random_tasks(rng, with_io, uniform=False):
    n_tasks = int(rng.integers(1, 26))
    work = float(rng.uniform(0.01, 5.0))
    tasks = []
    for i in range(n_tasks):
        io = float(rng.uniform(0.1, 30.0)) if with_io and rng.random() < 0.7 \
            else 0.0
        tasks.append(SimTask(work if uniform else float(rng.uniform(0.01, 5.0)),
                             io, int(rng.integers(0, 3)), task_id=i))
    return tasks


def split_static(rng, tasks, n):
    queues = [[] for _ in range(n)]
    for t in tasks:
        queues[int(rng.integers(0, n))].append(t)
    return queues


# --------------------------------------------------------------------------
# differential properties vs. the oracle
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_differential_pull_cpu_only(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_tasks(rng, with_io=False)
    start = float(rng.uniform(0.0, 2.0))
    oracle = _run_stage(nodes, [list(tasks)], pull=True, start_time=start)
    assert_results_match(
        oracle, run_stage_events(nodes, [tasks], pull=True, start_time=start))
    assert_results_match(
        oracle, simulate_stage(nodes, [tasks], pull=True, start_time=start))


@given(seed=st.integers(0, 10_000))
def test_differential_pull_with_io(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_tasks(rng, with_io=True)
    bw = float(rng.uniform(5.0, 50.0))
    oracle = _run_stage(nodes, [list(tasks)], pull=True, uplink_bw=bw)
    assert_results_match(
        oracle, run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw))
    assert_results_match(
        oracle, simulate_stage(nodes, [tasks], pull=True, uplink_bw=bw))


@given(seed=st.integers(0, 10_000))
def test_differential_static_cpu_only(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    queues = split_static(rng, random_tasks(rng, with_io=False), len(nodes))
    start = float(rng.uniform(0.0, 2.0))
    oracle = _run_stage(nodes, [list(q) for q in queues], pull=False,
                        start_time=start)
    assert_results_match(
        oracle, run_stage_events(nodes, queues, pull=False, start_time=start))
    assert_results_match(
        oracle, simulate_stage(nodes, queues, pull=False, start_time=start))


@given(seed=st.integers(0, 10_000))
def test_differential_static_with_io(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    queues = split_static(rng, random_tasks(rng, with_io=True), len(nodes))
    bw = float(rng.uniform(5.0, 50.0))
    oracle = _run_stage(nodes, [list(q) for q in queues], pull=False,
                        uplink_bw=bw)
    assert_results_match(
        oracle, run_stage_events(nodes, queues, pull=False, uplink_bw=bw))
    assert_results_match(
        oracle, simulate_stage(nodes, queues, pull=False, uplink_bw=bw))


# --------------------------------------------------------------------------
# closed-form fast paths == event path == oracle
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_closed_form_pull_matches_event_and_oracle(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=5, constant=True)
    tasks = random_tasks(rng, with_io=False, uniform=True)
    assert plan_path(nodes, [tasks], pull=True) == "closed-pull"
    oracle = _run_stage(nodes, [list(tasks)], pull=True)
    assert_results_match(oracle, run_pull_stage(nodes, tasks))
    assert_results_match(oracle,
                         run_stage_events(nodes, [tasks], pull=True))


@given(seed=st.integers(0, 10_000))
def test_closed_form_static_matches_event_and_oracle(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=5, constant=True)
    queues = split_static(rng, random_tasks(rng, with_io=False), len(nodes))
    assert plan_path(nodes, queues, pull=False) == "closed-static"
    oracle = _run_stage(nodes, [list(q) for q in queues], pull=False)
    assert_results_match(oracle, run_static_stage(nodes, queues))
    assert_results_match(oracle,
                         run_stage_events(nodes, queues, pull=False))


@given(seed=st.integers(0, 10_000))
def test_closed_form_pull_hetero_matches_event_and_oracle(seed):
    """Heterogeneous task sizes on constant-speed clusters take the
    merged-grid scan; it must match the oracle and the event calendar."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=5, constant=True)
    tasks = random_tasks(rng, with_io=False)          # continuous draws
    # guarantee >= 2 distinct sizes (a single task is trivially uniform)
    tasks.append(SimTask(tasks[-1].cpu_work * 1.5 + 0.1,
                         task_id=len(tasks)))
    start = float(rng.uniform(0.0, 2.0))
    assert plan_path(nodes, [tasks], pull=True) == "closed-pull-hetero"
    oracle = _run_stage(nodes, [list(tasks)], pull=True, start_time=start)
    assert_results_match(oracle,
                         run_pull_stage(nodes, tasks, start_time=start))
    assert_results_match(
        oracle, run_stage_events(nodes, [tasks], pull=True, start_time=start))


def _random_io_sym(rng, max_nodes=4):
    """Symmetric co-reader stage guaranteed network-governed: CPU spans are
    drawn well inside the smallest round's drain time."""
    n = int(rng.integers(1, max_nodes + 1))
    speeds = rng.uniform(0.2, 3.0, n)
    io_mb = float(rng.uniform(10.0, 50.0))
    bw = float(rng.uniform(5.0, 50.0))
    n_tasks = int(rng.integers(1, 41))
    q = n_tasks % n
    d_min = (q if q else n) * io_mb / bw
    nodes = [SimNode.constant(f"n{i}", float(s),
                              float(rng.uniform(0.0, 0.1 * d_min)))
             for i, s in enumerate(speeds)]
    works = rng.uniform(0.0, 0.5 * d_min * speeds.min(), n_tasks)
    tasks = [SimTask(float(w), io_mb=io_mb, datanode=0, task_id=i)
             for i, w in enumerate(works)]
    return nodes, tasks, bw


@given(seed=st.integers(0, 10_000))
def test_closed_form_io_sym_matches_event_path(seed):
    """Symmetric co-reader rounds are all exact ties (where the legacy
    oracle is unsound — see the module docstring's tie note), so the
    closed form is pinned against the causal event calendar."""
    rng = np.random.default_rng(seed)
    nodes, tasks, bw = _random_io_sym(rng)
    assert plan_path(nodes, [tasks], pull=True, uplink_bw=bw) \
        == "closed-pull-io-sym"
    event = run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw)
    assert_results_match(
        event, simulate_stage(nodes, [tasks], pull=True, uplink_bw=bw))


def test_io_sym_round_structure():
    """2 co-readers x 100 MB/s shared uplink: rounds of n tasks drain
    simultaneously every n*io_mb/bw seconds; a trailing partial round of q
    readers drains after q*io_mb/bw."""
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(2)]
    tasks = [SimTask(0.1, io_mb=100.0, datanode=0, task_id=i)
             for i in range(5)]
    res = run_pull_stage(nodes, tasks, uplink_bw=100.0)
    ends = {r.task_id: r.end for r in res.records}
    assert ends[0] == ends[1] == pytest.approx(2.0)
    assert ends[2] == ends[3] == pytest.approx(4.0)
    assert ends[4] == pytest.approx(5.0)          # lone reader at full rate
    assert res.completion == pytest.approx(5.0)
    assert_results_match(
        run_stage_events(nodes, [tasks], pull=True, uplink_bw=100.0), res)


def test_pull_tie_breaking_identical_nodes():
    """Equal-speed nodes produce exactly tied events; both paths must break
    ties like the oracle's lowest-index scan (task m -> node m mod n)."""
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.1) for i in range(4)]
    tasks = [SimTask(0.5, task_id=i) for i in range(101)]
    oracle = _run_stage(nodes, [list(tasks)], pull=True)
    for got in (run_pull_stage(nodes, tasks),
                run_stage_events(nodes, [tasks], pull=True)):
        assert_results_match(oracle, got)
    by_node = {nd.name: 0 for nd in nodes}
    for r in oracle.records:
        by_node[r.node] += 1
    assert by_node == {"n0": 26, "n1": 25, "n2": 25, "n3": 25}


def test_path_selection_rules():
    const = [SimNode.constant("a", 1.0)]
    multi = [SimNode("a", [(0.0, 1.0), (5.0, 0.5)])]
    uniform = [SimTask(1.0, task_id=0), SimTask(1.0, task_id=1)]
    ragged = [SimTask(1.0, task_id=0), SimTask(2.0, task_id=1)]
    io = [SimTask(1.0, io_mb=5.0, datanode=0, task_id=0)]
    assert plan_path(const, [uniform], pull=True) == "closed-pull"
    assert plan_path(const, [ragged], pull=True) == "closed-pull-hetero"
    assert plan_path(multi, [uniform], pull=True) == "event"
    assert plan_path(multi, [ragged], pull=True) == "event"
    # cpu-governed I/O (cpu span 1.0 > io round 0.5) -> event calendar
    assert plan_path(const, [io], pull=True, uplink_bw=10.0) == "event"
    # infinite uplink can never delay a completion -> closed form stays on
    assert plan_path(const, [io], pull=True, uplink_bw=None) == "closed-pull"
    assert plan_path(const, [ragged], pull=False) == "closed-static"
    assert plan_path(multi, [ragged], pull=False) == "event"
    assert plan_path(const, [io], pull=False, uplink_bw=10.0) == "event"


def test_path_selection_io_sym():
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.01) for i in range(2)]
    sym = [SimTask(0.05, io_mb=10.0, datanode=0, task_id=i) for i in range(6)]
    # network-governed (cpu span 0.06 <= round 2*10/10=2): closed form
    assert plan_path(nodes, [sym], pull=True, uplink_bw=10.0) \
        == "closed-pull-io-sym"
    # cpu-governed round: event
    heavy = [SimTask(5.0, io_mb=10.0, datanode=0, task_id=i) for i in range(6)]
    assert plan_path(nodes, [heavy], pull=True, uplink_bw=10.0) == "event"
    # a d=2 round-robin stripe over n=2 nodes qualifies for the
    # multi-datanode closed form (each round: one reader per datanode)
    striped = [SimTask(0.05, io_mb=10.0, datanode=i % 2, task_id=i)
               for i in range(6)]
    assert plan_path(nodes, [striped], pull=True, uplink_bw=10.0) \
        == "closed-pull-io-sym"
    # aperiodic datanode sequence or unequal io_mb: event
    aperiodic = [SimTask(0.05, io_mb=10.0, datanode=d, task_id=i)
                 for i, d in enumerate((0, 1, 1, 0, 0, 1))]
    assert plan_path(nodes, [aperiodic], pull=True, uplink_bw=10.0) == "event"
    mixed_mb = [SimTask(0.05, io_mb=10.0 + i, datanode=0, task_id=i)
                for i in range(6)]
    assert plan_path(nodes, [mixed_mb], pull=True, uplink_bw=10.0) == "event"
    # stripe width not dividing the fleet (d=3 over n=2): event
    trio = [SimTask(0.05, io_mb=10.0, datanode=i % 3, task_id=i)
            for i in range(6)]
    assert plan_path(nodes, [trio], pull=True, uplink_bw=10.0) == "event"


# --------------------------------------------------------------------------
# profile cursor exactness
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_profile_cursor_bitwise_matches_simnode(seed):
    rng = np.random.default_rng(seed)
    n_seg = int(rng.integers(1, 5))
    breaks = np.concatenate([[0.0], np.cumsum(rng.uniform(0.5, 4.0, n_seg - 1))])
    prof = [(float(t), float(rng.uniform(0.2, 3.0))) for t in breaks]
    node = SimNode("a", prof)
    cur = ProfileCursor(prof)
    t0 = 0.0
    for _ in range(20):
        t0 += float(rng.uniform(0.0, 2.0))
        work = float(rng.uniform(0.0, 4.0))
        assert cur.finish_time(work, t0) == node.finish_time(work, t0)
    cur2 = ProfileCursor(prof)
    t0 = 0.0
    for _ in range(20):
        t0 += float(rng.uniform(0.0, 2.0))
        t1 = t0 + float(rng.uniform(0.0, 3.0))
        assert cur2.work_between(t0, t1) == pytest.approx(
            node.work_between(t0, t1), rel=1e-12, abs=1e-12)


def test_cursor_burstable_profile_edges():
    prof = [(0.0, 2.0), (5.0, 0.5)]
    cur = ProfileCursor(prof)
    assert cur.finish_time(10.0, 0.0) == 5.0          # exactly at the break
    assert cur.finish_time(1.0, 6.0) == 8.0           # fully in the tail
    assert ProfileCursor(prof).finish_time(0.0, 3.0) == 3.0


# --------------------------------------------------------------------------
# idle-time accounting (satellite fix)
# --------------------------------------------------------------------------

def test_idle_time_ignores_nodes_that_never_ran():
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(3)]
    res = run_pull_stage(nodes, [SimTask(4.0, task_id=0)])
    assert res.completion == pytest.approx(4.0)
    assert res.idle_time == pytest.approx(0.0)        # was 4.0 pre-fix
    # oracle agrees after the fix
    legacy = _run_stage(nodes, [[SimTask(4.0, task_id=0)]], pull=True)
    assert legacy.idle_time == pytest.approx(0.0)
    # static with an empty assignment: the empty node is excluded too
    res = run_static_stage(nodes, [[SimTask(2.0, task_id=0)],
                                   [SimTask(3.0, task_id=1)], []])
    assert res.idle_time == pytest.approx(1.0)
    # but nodes that ran still count in full
    res = run_static_stage(nodes, [[SimTask(2.0, task_id=0)],
                                   [SimTask(3.0, task_id=1)],
                                   [SimTask(0.5, task_id=2)]])
    assert res.idle_time == pytest.approx(2.5)


def test_empty_stage_is_well_formed():
    nodes = [SimNode.constant("a", 1.0)]
    res = run_pull_stage(nodes, [], start_time=7.0)
    assert res.records == []
    assert res.completion == pytest.approx(7.0)
    assert res.idle_time == pytest.approx(0.0)


# --------------------------------------------------------------------------
# engine-specific edge cases
# --------------------------------------------------------------------------

def test_io_bound_completion_waits_for_flow_share():
    # two co-readers on one datanode: 100 MB each over a shared 100 MB/s
    # uplink -> both finish at t=2 even though CPU work is done at t=0.1
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(2)]
    tasks = [SimTask(0.1, io_mb=100.0, datanode=0, task_id=i)
             for i in range(2)]
    res = run_stage_events(nodes, [tasks], pull=True, uplink_bw=100.0)
    assert res.completion == pytest.approx(2.0, rel=0.05)


def test_reader_departure_repriced_incrementally():
    # reader A (50 MB) leaves the shared flow at t=1; B's second half then
    # runs at full rate: B = 50 MB shared (1 s) + 50 MB solo (0.5 s)
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(2)]
    tasks = [SimTask(0.01, io_mb=50.0, datanode=0, task_id=0),
             SimTask(0.01, io_mb=100.0, datanode=0, task_id=1)]
    res = run_static_stage(nodes, [[tasks[0]], [tasks[1]]], uplink_bw=100.0)
    ends = {r.task_id: r.end for r in res.records}
    assert ends[0] == pytest.approx(1.0, rel=1e-6)
    assert ends[1] == pytest.approx(1.5, rel=1e-6)


def test_simultaneous_io_drains_stay_causal():
    """Deliberate divergence from the oracle: identical co-reading tasks
    drain at the same instant; the legacy loop then completes the non-owner
    retroactively at its cpu_done_at (before its I/O could have finished)
    and feeds a negative time delta into every other flow.  The engine must
    stay causal: no record may end before its start + io_mb/uplink_bw, and
    every node's records must be time-ordered."""
    nodes = [SimNode.constant(f"w{i}", 1.0, overhead=0.1) for i in range(2)]
    # 8 identical network-bound tasks, 2 per datanode -> exact drain ties
    tasks = [SimTask(0.125, io_mb=64.0, datanode=i % 4, task_id=i)
             for i in range(8)]
    res = run_stage_events(nodes, [tasks], pull=True, uplink_bw=8.0)
    by_id = {t.task_id: t for t in tasks}
    last_end = {}
    for r in sorted(res.records, key=lambda r: r.start):
        assert r.end - r.start >= by_id[r.task_id].io_mb / 8.0 - 1e-9
        assert r.start >= last_end.get(r.node, 0.0) - 1e-9
        last_end[r.node] = r.end
    assert res.completion == pytest.approx(max(r.end for r in res.records))


def test_zero_work_tasks_complete_instantly():
    nodes = [SimNode.constant("a", 1.0, overhead=0.25)]
    tasks = [SimTask(0.0, task_id=i) for i in range(4)]
    oracle = _run_stage(nodes, [list(tasks)], pull=True)
    got = run_pull_stage(nodes, tasks)
    assert_results_match(oracle, got)
    assert got.completion == pytest.approx(1.0)


def test_multisegment_profile_straddles_tasks():
    # 2.0-speed for 5 s then 0.5: 12 units of work = 5 s (10 units) + 4 s
    nodes = [SimNode("a", [(0.0, 2.0), (5.0, 0.5)])]
    res = run_static_stage(nodes, [[SimTask(12.0, task_id=0)]])
    assert res.completion == pytest.approx(9.0)
    # and a queue of tasks crossing the break matches the oracle
    tasks = [SimTask(3.0, task_id=i) for i in range(5)]
    oracle = _run_stage(nodes, [list(tasks)], pull=True)
    assert_results_match(oracle, run_pull_stage(nodes, tasks))


def test_large_pull_sweep_smoke():
    """10k microtasks on 4 heterogeneous nodes — the benchmark regime —
    stays exact w.r.t. per-node totals and conservation of tasks."""
    nodes = [SimNode.constant(f"n{i}", s, 0.01)
             for i, s in enumerate([1.0, 0.8, 0.5, 0.4])]
    tasks = [SimTask(100.0 / 10_000, task_id=i) for i in range(10_000)]
    res = run_pull_stage(nodes, tasks)
    assert len(res.records) == 10_000
    counts = {nd.name: 0 for nd in nodes}
    for r in res.records:
        counts[r.node] += 1
    assert sum(counts.values()) == 10_000
    # faster nodes take proportionally more microtasks
    assert counts["n0"] > counts["n2"] > 0
    assert res.idle_time <= max(0.01 + 100.0 / 10_000 / 0.4, 0.5)


# --------------------------------------------------------------------------
# whole-job engine (run_job) vs. per-stage event loop
# --------------------------------------------------------------------------

def _per_stage_event_baseline(nodes, specs, uplink_bw=None, start=0.0):
    """Reference whole-job run: re-enter the event calendar once per stage,
    carrying the barrier by hand. Returns per-stage StageResults."""
    t, results = start, []
    for spec in specs:
        if isinstance(spec, StaticSpec):
            queues = [[SimTask(w, task_id=i)]
                      for i, w in enumerate(spec.works)]
            res = run_stage_events(nodes, queues, pull=False,
                                   uplink_bw=uplink_bw, start_time=t)
        else:
            works = spec.works if spec.works is not None \
                else (spec.task_work,) * spec.n_tasks
            tasks = [SimTask(float(w), spec.io_mb, spec.datanode, task_id=i)
                     for i, w in enumerate(works)]
            res = run_stage_events(nodes, [tasks], pull=True,
                                   uplink_bw=uplink_bw, start_time=t)
        results.append(res)
        t = res.completion
    return results


def assert_job_matches(results, sched):
    assert len(sched.stages) == len(results)
    for res, summ in zip(results, sched.stages):
        assert summ.completion == _approx(res.completion)
        assert summ.idle_time == _approx(res.idle_time)
        for name, tf in res.node_finish.items():
            assert summ.node_finish[name] == _approx(tf)
        counts = {name: 0 for name in res.node_finish}
        for r in res.records:
            counts[r.node] += 1
        assert summ.counts == counts
    if results:
        assert sched.completion == _approx(results[-1].completion)


def _random_specs(rng, n_nodes, n_stages):
    specs = []
    for _ in range(n_stages):
        kind = rng.integers(0, 3)
        if kind == 0:      # uniform pull
            specs.append(PullSpec(n_tasks=int(rng.integers(1, 30)),
                                  task_work=float(rng.uniform(0.05, 3.0))))
        elif kind == 1:    # heterogeneous pull
            works = rng.uniform(0.01, 3.0, int(rng.integers(1, 30)))
            specs.append(PullSpec(works=tuple(float(w) for w in works)))
        else:              # HeMT macrotasks
            works = rng.uniform(0.0, 5.0, n_nodes)
            specs.append(StaticSpec(works=tuple(float(w) for w in works)))
    return specs


@given(seed=st.integers(0, 10_000))
def test_run_job_matches_per_stage_event_loop(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=4, constant=True)
    specs = _random_specs(rng, len(nodes), int(rng.integers(1, 6)))
    sched = run_job(nodes, specs)
    assert_job_matches(_per_stage_event_baseline(nodes, specs), sched)


@given(seed=st.integers(0, 10_000))
def test_run_job_repeated_specs_share_cached_solve(seed):
    """[spec] * S (the Fig 17/18 shape) must shift one cached solve across
    barriers and still match S independent engine entries."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=4, constant=True)
    works = rng.uniform(0.01, 2.0, int(rng.integers(2, 25)))
    spec = PullSpec(works=tuple(float(w) for w in works))
    specs = [spec] * int(rng.integers(2, 8))
    sched = run_job(nodes, specs)
    assert_job_matches(_per_stage_event_baseline(nodes, specs), sched)


def test_run_job_multisegment_cluster_falls_back_per_stage():
    """Multi-segment profiles are not start-invariant: run_job must hit the
    absolute per-stage path and still match the event calendar."""
    nodes = [SimNode("a", [(0.0, 2.0), (5.0, 0.5)], 0.05),
             SimNode("b", [(0.0, 1.0), (3.0, 2.0)], 0.1)]
    specs = [PullSpec(n_tasks=7, task_work=1.3),
             StaticSpec(works=(4.0, 2.0)),
             PullSpec(works=(0.5, 2.5, 1.0, 0.25))]
    sched = run_job(nodes, specs)
    assert_job_matches(_per_stage_event_baseline(nodes, specs), sched)


def test_run_job_io_specs():
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.01) for i in range(3)]
    sym = PullSpec(n_tasks=8, task_work=0.05, io_mb=20.0, datanode=0)
    # cpu-governed symmetric spec: run_job's internal event fallback
    heavy = PullSpec(n_tasks=4, task_work=50.0, io_mb=20.0, datanode=0)
    specs = [sym, heavy, sym]
    sched = run_job(nodes, specs, uplink_bw=10.0)
    assert_job_matches(
        _per_stage_event_baseline(nodes, specs, uplink_bw=10.0), sched)


def test_run_job_empty_and_start_time():
    nodes = [SimNode.constant("a", 1.0)]
    sched = run_job(nodes, [], start_time=3.0)
    assert sched.completion == pytest.approx(3.0) and sched.stages == []
    sched = run_job(nodes, [PullSpec(n_tasks=0), StaticSpec(works=(2.0,))],
                    start_time=3.0)
    assert sched.stages[0].completion == pytest.approx(3.0)
    assert sched.completion == pytest.approx(5.0)


# --------------------------------------------------------------------------
# MultiStageJob rides run_job (satellite: randomized multi-stage pinning)
# --------------------------------------------------------------------------

@given(params=st.tuples(st.integers(0, 10_000), st.integers(1, 8)),
       mode=st.sampled_from(["hemt", "homt"]))
def test_multistage_job_pinned_to_event_loop(params, mode):
    """MultiStageJob.run (via run_job) vs. the per-stage event loop on
    heterogeneous-speed clusters with skewed shuffle weights."""
    seed, n_stages = params
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    nodes = [SimNode.constant(f"n{i}", float(rng.uniform(0.2, 3.0)),
                              float(rng.uniform(0.0, 0.3)))
             for i in range(n)]
    stage_works = [float(rng.uniform(1.0, 30.0)) for _ in range(n_stages)]
    job = MultiStageJob(stage_works=stage_works)
    if mode == "homt":
        k = int(rng.integers(1, 33))
        total, summaries = job.run(nodes, None, n_tasks_per_stage=k)
        specs = job.specs(None, k)
    else:
        weights = rng.uniform(0.1, 3.0, n)        # skewed shuffle shares
        total, summaries = job.run(nodes, list(weights))
        specs = job.specs(list(weights))
    results = _per_stage_event_baseline(nodes, specs)
    assert total == _approx(results[-1].completion)
    assert_job_matches(results, type("S", (), {
        "stages": summaries, "completion": total})())


def test_multistage_records_mode_agrees():
    nodes = [SimNode.constant("a", 1.0, 0.2), SimNode.constant("b", 0.4, 0.2)]
    job = MultiStageJob(stage_works=[14.0] * 6)
    fast, summaries = job.run(nodes, weights=[1.0, 0.4])
    slow, results = job.run(nodes, weights=[1.0, 0.4], records=True)
    assert fast == pytest.approx(slow, rel=REL)
    for summ, res in zip(summaries, results):
        assert summ.completion == _approx(res.completion)
        assert res.records                        # full records retained


# --------------------------------------------------------------------------
# module-level run_job solve LRU (satellite: cross-call sharing)
# --------------------------------------------------------------------------

def test_run_job_solve_cache_shared_across_calls(monkeypatch):
    from repro.core import engine

    engine.run_job_cache_clear()
    calls = []
    real = engine._rel_summary

    def counting(nodes, speeds, spec, uplink_bw):
        calls.append(spec)
        return real(nodes, speeds, spec, uplink_bw)

    monkeypatch.setattr(engine, "_rel_summary", counting)
    nodes = [SimNode.constant(f"n{i}", s, 0.01)
             for i, s in enumerate([1.0, 0.5])]
    specs = [PullSpec(n_tasks=10, task_work=0.3),
             StaticSpec(works=(2.0, 1.0))]
    first = run_job(nodes, specs)
    assert len(calls) == 2
    # same cluster, distinct-but-equal specs: served from the module LRU
    again = run_job(nodes, [PullSpec(n_tasks=10, task_work=0.3),
                            StaticSpec(works=(2.0, 1.0))])
    assert len(calls) == 2
    assert again.completion == pytest.approx(first.completion, rel=REL)
    # equal profiles under different names share the solve (names only
    # label results); a different overhead is a different cluster
    renamed = [SimNode.constant(f"m{i}", s, 0.01)
               for i, s in enumerate([1.0, 0.5])]
    res = run_job(renamed, [PullSpec(n_tasks=10, task_work=0.3)])
    assert len(calls) == 2
    assert set(res.stages[0].node_finish) == {"m0", "m1"}
    assert res.completion == pytest.approx(first.stages[0].completion,
                                           rel=REL)
    other = [SimNode.constant(f"n{i}", s, 0.02)
             for i, s in enumerate([1.0, 0.5])]
    run_job(other, [PullSpec(n_tasks=10, task_work=0.3)])
    assert len(calls) == 3
    # large-works specs stay un-hashed (id-cache only): a fresh equal spec
    # re-solves, repeated stages of one object still share
    big = PullSpec(works=tuple(0.1 + (i % 7) * 0.01 for i in range(2000)))
    run_job(nodes, [big] * 3)
    assert len(calls) == 4
    run_job(nodes, [PullSpec(works=big.works)])
    assert len(calls) == 5
    engine.run_job_cache_clear()


def test_run_job_cache_eviction_bounded(monkeypatch):
    from repro.core import engine

    engine.run_job_cache_clear()
    monkeypatch.setattr(engine, "_SOLVE_CACHE_MAX", 4)
    nodes = [SimNode.constant("a", 1.0)]
    for k in range(10):
        run_job(nodes, [StaticSpec(works=(float(k + 1),))])
    assert len(engine._SOLVE_CACHE) == 4
    engine.run_job_cache_clear()
    assert len(engine._SOLVE_CACHE) == 0


# --------------------------------------------------------------------------
# run-length batched hetero pull (satellite: numpy merged-grid batching)
# --------------------------------------------------------------------------

def _blocky_works(rng, n_blocks=None, lo=40, hi=120):
    """Fig 18-style shuffle queue: runs of equal-sized tasks."""
    n_blocks = n_blocks or int(rng.integers(2, 7))
    lens = rng.integers(lo, hi, n_blocks)
    vals = rng.uniform(0.05, 2.0, n_blocks)
    return np.repeat(vals, lens)


def test_pull_hetero_batched_engages_on_blocky_works():
    from repro.core.engine import _pull_hetero_try_batched

    rng = np.random.default_rng(0)
    blocky = _blocky_works(rng)
    got = _pull_hetero_try_batched([0.01, 0.02], [1.0, 0.5], blocky, 0.0,
                                   False)
    assert got is not None
    node_end, counts, wsums, per_task = got
    assert per_task is None and sum(counts) == len(blocky)
    assert sum(wsums) == pytest.approx(float(np.sum(blocky)), rel=1e-9)
    # continuous draws (run length 1) and degenerate zero periods decline
    distinct = rng.uniform(0.1, 2.0, 200)
    assert _pull_hetero_try_batched([0.01, 0.02], [1.0, 0.5], distinct,
                                    0.0, False) is None
    zeros = np.zeros(200)
    assert _pull_hetero_try_batched([0.0, 0.1], [1.0, 0.5], zeros,
                                    0.0, False) is None


@given(seed=st.integers(0, 10_000))
def test_pull_hetero_batched_matches_oracle(seed):
    """Blocky queues through the full stack (records + summary paths) must
    match the legacy rescan oracle and the event calendar at 1e-9."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, max_nodes=5, constant=True)
    works = _blocky_works(rng, n_blocks=int(rng.integers(2, 5)),
                          lo=33, hi=70)
    tasks = [SimTask(float(w), task_id=i) for i, w in enumerate(works)]
    start = float(rng.uniform(0.0, 2.0))
    assert plan_path(nodes, [tasks], pull=True) == "closed-pull-hetero"
    oracle = _run_stage(nodes, [list(tasks)], pull=True, start_time=start)
    assert_results_match(oracle,
                         run_pull_stage(nodes, tasks, start_time=start))
    # record-free summary (the run_job hot loop) agrees too
    from repro.core import engine

    engine.run_job_cache_clear()
    sched = run_job(nodes, [PullSpec(works=tuple(float(w) for w in works))],
                    start_time=start)
    summ = sched.stages[0]
    assert summ.completion == _approx(oracle.completion)
    for nd in nodes:
        assert summ.node_finish[nd.name] == _approx(
            oracle.node_finish[nd.name])
    counts = {nd.name: 0 for nd in nodes}
    for r in oracle.records:
        counts[r.node] += 1
    assert summ.counts == counts


def test_pull_hetero_batched_identical_nodes_tie_break():
    """Exact cross-node grid ties (identical nodes, equal-size runs) must
    keep the heap's lowest-index round-robin order."""
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.1) for i in range(3)]
    works = np.concatenate([np.full(60, 0.5), np.full(45, 1.25)])
    tasks = [SimTask(float(w), task_id=i) for i, w in enumerate(works)]
    oracle = _run_stage(nodes, [list(tasks)], pull=True)
    assert_results_match(oracle, run_pull_stage(nodes, tasks))
    assert_results_match(oracle,
                         run_stage_events(nodes, [tasks], pull=True))


# --------------------------------------------------------------------------
# multi-datanode symmetric co-readers (satellite: d-striped closed form)
# --------------------------------------------------------------------------

def _random_io_sym_striped(rng):
    """Symmetric d-striped co-reader stage guaranteed network-governed:
    task k reads datanode ``dns[k % d]`` with ``d | n``, CPU spans drawn
    well inside the smallest drain any round can produce (a lone tail
    reader at full uplink rate)."""
    d = int(rng.integers(1, 5))
    n = d * int(rng.integers(1, 3))
    speeds = rng.uniform(0.2, 3.0, n)
    io_mb = float(rng.uniform(10.0, 50.0))
    bw = float(rng.uniform(5.0, 50.0))
    n_tasks = int(rng.integers(1, 41))
    d_min = io_mb / bw                       # lone-reader drain
    nodes = [SimNode.constant(f"n{i}", float(s),
                              float(rng.uniform(0.0, 0.1 * d_min)))
             for i, s in enumerate(speeds)]
    dns = [int(x) for x in rng.permutation(8)[:d]]
    works = rng.uniform(0.0, 0.5 * d_min * speeds.min(), n_tasks)
    tasks = [SimTask(float(w), io_mb=io_mb, datanode=dns[i % d], task_id=i)
             for i, w in enumerate(works)]
    return nodes, tasks, bw


@given(seed=st.integers(0, 10_000))
def test_closed_form_io_sym_striped_matches_event_path(seed):
    """The d-striped generalization: every full round puts n/d co-readers
    on each of d datanodes (simultaneous per-group drains), the tail
    round's groups drain independently; the closed form is pinned against
    the causal event calendar across random stripe widths."""
    rng = np.random.default_rng(seed)
    nodes, tasks, bw = _random_io_sym_striped(rng)
    assert plan_path(nodes, [tasks], pull=True, uplink_bw=bw) \
        == "closed-pull-io-sym"
    event = run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw)
    assert_results_match(
        event, simulate_stage(nodes, [tasks], pull=True, uplink_bw=bw))


def test_io_sym_striped_round_structure():
    """4 nodes / 2 datanodes, 100 MB/s uplink, 100 MB tasks: each full
    round is two 2-reader groups draining together after 2s; the 1-task
    tail is a lone reader at the full rate (1s)."""
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(4)]
    tasks = [SimTask(0.1, io_mb=100.0, datanode=i % 2, task_id=i)
             for i in range(9)]
    res = simulate_stage(nodes, [tasks], pull=True, uplink_bw=100.0)
    assert plan_path(nodes, [tasks], pull=True, uplink_bw=100.0) \
        == "closed-pull-io-sym"
    ends = {r.task_id: r.end for r in res.records}
    assert all(ends[i] == pytest.approx(2.0) for i in range(4))
    assert all(ends[i] == pytest.approx(4.0) for i in range(4, 8))
    assert ends[8] == pytest.approx(5.0)      # lone tail reader: 4 + 1
    assert res.completion == pytest.approx(5.0)
    assert_results_match(
        run_stage_events(nodes, [tasks], pull=True, uplink_bw=100.0), res)


# --------------------------------------------------------------------------
# JobContinuation: resumable run_job (satellite: resident splice plumbing)
# --------------------------------------------------------------------------

def test_resume_validation():
    from repro.core.engine import JobContinuation
    nodes = [SimNode.constant("a", 1.0)]
    stages = [StaticSpec(works=(1.0,))] * 2
    with pytest.raises(ValueError):
        run_job(nodes, stages, resume=JobContinuation(3, 0.0))
    with pytest.raises(ValueError):
        run_job(nodes, stages, resume=JobContinuation(-1, 0.0))
    # next_stage == len(stages): legal empty tail anchored at the clock
    sched = run_job(nodes, stages, resume=JobContinuation(2, 7.5))
    assert sched.completion == pytest.approx(7.5) and sched.stages == []


def test_resume_slices_the_program_tail():
    """Resuming at stage k from the stage-(k-1) barrier clock reproduces
    the full run's tail summaries exactly, and the schedule records the
    continuation so callers can re-align stage indices."""
    from repro.core.engine import JobContinuation
    nodes = [SimNode.constant("a", 2.0, 0.01), SimNode.constant("b", 1.0)]
    stages = [StaticSpec(works=(2.0, 1.0)),
              PullSpec(n_tasks=6, task_work=0.5),
              StaticSpec(works=(1.0, 2.0)),
              StaticSpec(works=(3.0, 3.0))]
    full = run_job(nodes, stages)
    cont = JobContinuation(2, full.stages[1].completion)
    tail = run_job(nodes, stages, resume=cont)
    assert tail.continuation == cont and full.continuation is None
    assert tail.completion == pytest.approx(full.completion, rel=REL)
    assert len(tail.stages) == 2
    for got, want in zip(tail.stages, full.stages[2:]):
        assert got.start == pytest.approx(want.start, rel=REL)
        assert got.completion == pytest.approx(want.completion, rel=REL)
        for name in want.node_finish:
            assert got.node_finish[name] == \
                pytest.approx(want.node_finish[name], rel=REL)


def test_resume_carry_folds_into_first_stage():
    """A (residual, throughputs) carry folds into the resumed stage
    proportionally to throughput — identical to handing run_job the
    explicitly folded spec."""
    from repro.core.engine import JobContinuation
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    cont = JobContinuation(0, 4.0, carry=(2.0, (3.0, 1.0)))
    got = run_job(nodes, [StaticSpec(works=(2.0, 2.0))], resume=cont)
    want = run_job(nodes, [StaticSpec(works=(3.5, 2.5))], start_time=4.0)
    assert got.completion == pytest.approx(want.completion, rel=REL)
    assert got.completion == pytest.approx(7.5)
    # a zero residual is a no-op carry
    none = run_job(nodes, [StaticSpec(works=(2.0, 2.0))],
                   resume=JobContinuation(0, 4.0, carry=(0.0, (1.0, 1.0))))
    assert none.completion == pytest.approx(6.0)
