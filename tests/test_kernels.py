"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.skewed_hash import bucket_of, integer_capacities
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (1, 2, 2, 64, 64, 16),
    (2, 4, 2, 96, 96, 32),      # GQA + non-128 seq (padding path)
    (1, 8, 1, 128, 256, 64),    # MQA, cross lengths
    (1, 2, 2, 33, 65, 16),      # ragged padding
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, sq, sk, d, causal, window, dtype):
    if causal and sq != sk:
        pytest.skip("causal needs square")
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = fa_kernel(q, k, v, causal=causal, window=window,
                    block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


def test_flash_ops_wrapper_model_layout():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = jnp.swapaxes(ref.flash_attention_ref(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
        causal=True), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# --------------------------------------------------------------------------
# SSD scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("bsz,s,h,p,g,n,chunk", [
    (1, 64, 2, 16, 1, 8, 16),
    (2, 96, 4, 8, 2, 16, 32),
    (1, 50, 4, 16, 4, 8, 16),    # padding path (50 % 16 != 0)
])
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_scan_sweep(bsz, s, h, p, g, n, chunk, with_init):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (bsz, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 8.0, h))
    B = jax.random.normal(ks[2], (bsz, s, g, n)) * 0.3
    C = jax.random.normal(ks[3], (bsz, s, g, n)) * 0.3
    init = (jax.random.normal(ks[4], (bsz, h, p, n)) * 0.1
            if with_init else None)
    y, f = ops.ssd_scan(x, dt, a_log, B, C, chunk=chunk, init_state=init)
    yr, fr = ref.ssd_scan_ref(x, dt, a_log, B, C, init_state=init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(f), np.asarray(fr), atol=2e-3)


# --------------------------------------------------------------------------
# skewed bucket (Algorithm 1)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("weights", [[1.0, 0.4], [1.0, 1.0, 1.0],
                                     [3, 4, 4], [0.5, 0.3, 0.1, 0.1]])
@pytest.mark.parametrize("t", [17, 1024, 5000])
def test_skewed_bucket_sweep(weights, t):
    caps = integer_capacities(weights, resolution=997)
    hashes = jax.random.randint(KEY, (t,), 0, 2**30)
    got = ops.skewed_bucket(hashes, jnp.asarray(caps, jnp.int32))
    want_ref = ref.skewed_bucket_ref(hashes, jnp.asarray(caps, jnp.int32))
    want_np = bucket_of(np.asarray(hashes), caps)
    assert (np.asarray(got) == np.asarray(want_ref)).all()
    assert (np.asarray(got) == want_np).all()
    assert got.min() >= 0 and got.max() < len(weights)
