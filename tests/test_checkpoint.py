"""Checkpointing: atomicity, rotation, crash debris, async, resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint


@pytest.fixture
def tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "opt": (jnp.zeros(()), [jnp.full((2,), 3.0)])}


def test_roundtrip(tmp_path, tree):
    path = save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
    step, restored, meta = restore_checkpoint(path, tree)
    assert step == 7 and meta == {"note": "x"}
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(restored)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_missing_commit_marker_rejected(tmp_path, tree):
    path = save_checkpoint(str(tmp_path), 1, tree)
    os.remove(os.path.join(path, "_COMPLETE"))
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(path, tree)


def test_shape_mismatch_rejected(tmp_path, tree):
    path = save_checkpoint(str(tmp_path), 1, tree)
    bad = dict(tree)
    bad["params"] = {"w": jnp.zeros((4, 4)), "b": tree["params"]["b"]}
    with pytest.raises(ValueError):
        restore_checkpoint(path, bad)


def test_manager_rotation_and_debris(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    # uncommitted step dir (crashed writer) is invisible and pruned
    os.makedirs(os.path.join(str(tmp_path), "step_00000099"))
    assert mgr.latest() == 4
    mgr.save(5, tree)
    assert not os.path.exists(os.path.join(str(tmp_path), "step_00000099"))


def test_manager_async_and_resume(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(10, tree)
    mgr.wait()
    got = mgr.restore_latest(tree)
    assert got is not None and got[0] == 10


def test_resume_after_simulated_crash(tmp_path, tree):
    """Kill-at-any-instant: a partial step dir never wins over the last
    committed one."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, tree)
    # a later save that 'crashed' mid-write (no marker)
    partial = os.path.join(str(tmp_path), "step_00000002")
    os.makedirs(partial)
    open(os.path.join(partial, "arrays.npz"), "wb").close()
    step, _, _ = mgr.restore_latest(tree)
    assert step == 1


def test_end_to_end_train_resume(tmp_path):
    import dataclasses
    from repro.configs import ArchBundle, TrainConfig, get_reduced
    from repro.runtime.train_loop import make_train_step, train_state_init
    from repro.data.pipeline import SyntheticCorpus

    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(lr=1e-3, warmup_steps=1,
                                                     total_steps=10))
    corpus = SyntheticCorpus(cfg.vocab_size, 16, seed=0)
    step_fn = jax.jit(make_train_step(cfg, bundle))
    mgr = CheckpointManager(str(tmp_path))

    state = train_state_init(jax.random.PRNGKey(0), cfg, bundle)
    for s in range(4):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(
            range(s * 4, s * 4 + 4)).items()}
        state, _ = step_fn(state, batch)
        if s == 1:
            mgr.save(2, state)

    # crash + resume from step 2, replay steps 2..3 -> identical state
    step, resumed, _ = mgr.restore_latest(state)
    assert step == 2
    for s in range(2, 4):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(
            range(s * 4, s * 4 + 4)).items()}
        resumed, _ = step_fn(resumed, batch)
    err = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        state.params, resumed.params)
    assert max(jax.tree.leaves(err)) == 0.0
