"""Fault injection & recovery vs. a naive full-rescan fault oracle.

The oracle below restates the documented fault semantics (the
``repro.core.faults`` module docstring) as a rescan-everything loop on top
of the I/O-mitigation oracle pattern: per-datanode fair-share rates
recomputed from scratch at every event, full ``SimNode`` profile walks, and
fault sub-events merged into the event selection by ``(t, node, rank)``
with recover < drain < kill < any same-instant completion of the same
node.  Randomized differential suites pin ``run_stage_events(faults=...)``
— and the ``run_job`` threading of fault traces — against it at 1e-9,
covering crashes mid-CPU, crashes mid-I/O-drain, crashes of speculation
victims, recoveries mid-stage, and preemption drains.  A no-poisoning
suite proves fault-window solves never contaminate the start-invariant
solve LRU.
"""
import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    AdaptivePlan, PullSpec, StaticSpec, _spec_tasks, run_job,
    run_job_cache_clear, run_stage_events,
)
from repro.core.faults import (
    DEAD, DRAINING, FaultTrace, NodeCrash, RetryPolicy, SpotPreemption,
    lost_work,
)
from repro.core.hdfs_model import DuplicatePlacement
from repro.core.simulator import (
    SimNode, SimTask, TaskRecord, _stage_result, run_pull_stage,
    run_static_stage,
)
from repro.core.speculation import (
    ReskewHandoff, RunningAttempt, Speculate, SpeculativeCopies, WorkStealing,
)

REL = ABS = 1e-9
_EPS = 1e-9

# fault sub-events sort below (before) same-instant completions of their
# node; among themselves a recovery ending one interval precedes the kill
# starting the next
_PRIO = {"recover": 0, "drain": 1, "kill": 2,
         "io": 3, "done": 3, "recheck": 3}


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# the oracle: naive rescan loop with faults, per the documented semantics
# --------------------------------------------------------------------------

def oracle_stage_faults(nodes, queues, pull, faults, uplink_bw=None,
                        mitigation=None, start_time=0.0):
    """Full-rescan fault + I/O + mitigation oracle: rates recomputed
    globally at every event, all flows advanced between events, fault
    sub-events dispatched by ``(t, node, rank)`` — no incremental state."""
    n = len(nodes)
    bw = uplink_bw if uplink_bw else None
    shared = list(queues[0]) if pull else None
    private = None if pull else [list(q) for q in queues]
    busy = [False] * n
    tid = [0] * n
    start = [0.0] * n
    launch = [0.0] * n
    task_work = [0.0] * n        # the attempt task's cpu_work field
    task_io = [0.0] * n          # the attempt task's io_mb field (raw)
    task_dn = [-1] * n           # the attempt task's datanode field (raw)
    att_work = [0.0] * n         # attempt work (shrinks on steal)
    att_io = [0.0] * n           # effective attempt bytes (shrinks on steal)
    io_left = [0.0] * n
    cpu_done = [0.0] * n
    twin = [-1] * n
    copied = set()
    done = []
    rechecks = {}
    records = []
    node_finish = {nd.name: start_time for nd in nodes}
    placement = getattr(mitigation, "placement", None)

    f_dead = [faults.state_at(i, start_time) == DEAD for i in range(n)]
    f_drain = [faults.state_at(i, start_time) == DRAINING for i in range(n)]
    fpend = list(faults.sub_events(start_time))
    requeued = {}                # task_id -> kill-requeues so far
    pen = {}                     # task_id -> pending relaunch penalty

    def dup_dn(d):
        return d if placement is None else placement.choose(d)

    def flow_active(i):
        return (busy[i] and bw is not None and task_dn[i] >= 0
                and io_left[i] > _EPS)

    def rates():
        cnt = {}
        for i in range(n):
            if flow_active(i):
                cnt[task_dn[i]] = cnt.get(task_dn[i], 0) + 1
        return {d: bw / c for d, c in cnt.items()}

    def start_attempt(i, task_id, w, io, d, now):
        busy[i] = True
        tid[i] = task_id
        start[i] = now
        launch[i] = now + nodes[i].task_overhead + pen.pop(task_id, 0.0)
        task_work[i] = att_work[i] = w
        task_io[i] = io
        task_dn[i] = d
        cpu_done[i] = nodes[i].finish_time(w, launch[i])
        if bw is not None and d >= 0 and io > _EPS:
            att_io[i] = io
            io_left[i] = io
        else:
            att_io[i] = 0.0
            io_left[i] = 0.0
        rechecks.pop(i, None)

    def refill(i, now):
        if f_dead[i] or f_drain[i]:
            return
        if pull:
            if shared:
                tk = shared.pop(0)
                start_attempt(i, tk.task_id, tk.cpu_work, tk.io_mb,
                              tk.datanode, now)
        elif private[i]:
            tk = private[i].pop(0)
            start_attempt(i, tk.task_id, tk.cpu_work, tk.io_mb,
                          tk.datanode, now)

    def remaining(k, now):
        if now < launch[k]:
            return att_work[k]
        return nodes[k].work_between(now, cpu_done[k])

    def queue_empty(i):
        return not shared if pull else not private[i]

    def wake(now):
        for k in range(n):
            if not busy[k]:
                refill(k, now)

    def real(tk):
        return tk.cpu_work > _EPS or tk.io_mb > _EPS

    def requeue(tk, victim, now):
        if pull:
            shared.append(tk)
            return
        if faults.recovery_after(victim, now) is not None and real(tk):
            private[victim].insert(0, tk)
            return
        best, best_load = -1, math.inf
        for j in range(n):
            if f_dead[j] or f_drain[j]:
                continue
            load = ((remaining(j, now) if busy[j] else 0.0)
                    + sum(q.cpu_work for q in private[j]))
            if load < best_load:
                best, best_load = j, load
        if best < 0:
            best_rec = math.inf
            for j in range(n):
                rec = faults.recovery_after(j, now)
                if rec is not None and rec < best_rec:
                    best, best_rec = j, rec
        if best >= 0:
            private[best].append(tk)

    def shed(i, now):
        if pull or not private[i]:
            return
        if faults.recovery_after(i, now) is None:
            moving, private[i][:] = list(private[i]), []
        else:
            moving = [tk for tk in private[i] if not real(tk)]
            private[i][:] = [tk for tk in private[i] if real(tk)]
        for tk in moving:
            requeue(tk, i, now)

    def kill(i, now):
        f_dead[i] = True
        f_drain[i] = False
        if busy[i]:
            executed = att_work[i] - remaining(i, now)
            saved = 0.0
            g = faults.checkpoint_grain
            if g > 0.0 and executed > 0.0:
                saved = min(math.floor((executed + _EPS) / g) * g,
                            att_work[i])
            if saved > _EPS:
                records.append(TaskRecord(tid[i], nodes[i].name, start[i],
                                          now, saved))
                node_finish[nodes[i].name] = now
            surv = twin[i]
            busy[i] = False
            io_left[i] = 0.0
            if surv >= 0:
                twin[i] = twin[surv] = -1
            else:
                rem = att_work[i] - saved
                if rem > _EPS:
                    k = requeued.get(tid[i], 0)
                    if k < faults.retry.max_attempts - 1:
                        requeued[tid[i]] = k + 1
                        p = faults.retry.penalty(k + 1)
                        if p > 0.0:
                            pen[tid[i]] = p
                        if att_io[i] > _EPS and att_work[i] > _EPS:
                            io = att_io[i] * rem / att_work[i]
                        else:
                            io = 0.0
                        requeue(SimTask(rem, io,
                                        task_dn[i] if io > _EPS else -1,
                                        task_id=tid[i]), i, now)
        shed(i, now)

    def offer_all(now):
        while True:
            running = [RunningAttempt(k, tid[k], start[k], att_work[k],
                                      remaining(k, now), tid[k] in copied,
                                      att_io[k])
                       for k in range(n) if busy[k]]
            if not running:
                return
            by_node = {r.node: r for r in running}
            acted = False
            for k in range(n):
                if busy[k] or f_dead[k] or f_drain[k] or not queue_empty(k):
                    continue
                act = mitigation.offer(done, running, now)
                if act is None:
                    continue
                victim = by_node[act.victim]
                j = act.victim
                if isinstance(act, Speculate):
                    copied.add(victim.task_id)
                    start_attempt(k, victim.task_id, task_work[j],
                                  task_io[j], dup_dn(task_dn[j]), now)
                    twin[k] = j
                    twin[j] = k
                else:                  # Steal
                    moved = 0.0
                    if att_io[j] > _EPS and victim.work > 0.0:
                        moved = att_io[j] * act.amount / victim.work
                        att_io[j] -= moved
                    att_work[j] -= act.amount
                    cpu_done[j] = nodes[j].finish_time(
                        victim.remaining - act.amount, max(now, launch[j]))
                    if moved > 0.0:
                        io_left[j] = max(0.0, io_left[j] - moved)
                    start_attempt(k, victim.task_id, act.amount, moved,
                                  dup_dn(task_dn[j]) if moved > _EPS
                                  else -1, now)
                acted = True
                break
            if not acted:
                for k in range(n):
                    if (busy[k] or f_dead[k] or f_drain[k]
                            or not queue_empty(k)):
                        continue
                    nc = mitigation.next_check(done, running, now)
                    if nc is not None:
                        rechecks[k] = nc
                return

    def complete(i, now):
        records.append(TaskRecord(tid[i], nodes[i].name, start[i], now,
                                  att_work[i]))
        node_finish[nodes[i].name] = now
        busy[i] = False
        io_left[i] = 0.0
        if mitigation is None:
            refill(i, now)
            return
        done.append(now - start[i])
        loser = twin[i]
        if loser >= 0:
            twin[i] = twin[loser] = -1
            busy[loser] = False
            io_left[loser] = 0.0
        refill(i, now)
        if loser >= 0:
            refill(loser, now)
        offer_all(now)

    for i in range(n):
        if f_dead[i] or f_drain[i]:
            continue
        refill(i, start_time)
    if not pull:
        for i in range(n):
            if f_dead[i]:
                shed(i, start_time)
        wake(start_time)
    if mitigation is not None:
        offer_all(start_time)

    t = start_time
    guard = 0
    while any(busy) or rechecks or fpend:
        guard += 1
        assert guard < 1_000_000, "oracle runaway"
        cur = rates()
        events = []
        for i in range(n):
            if not busy[i]:
                continue
            if flow_active(i):
                events.append((t + io_left[i] / cur[task_dn[i]], i, "io"))
            else:
                events.append((max(t, cpu_done[i]), i, "done"))
        events += [(tc, i, "recheck") for i, tc in rechecks.items()
                   if not busy[i]]
        events += fpend
        t_next, i, kind = min(events,
                              key=lambda e: (e[0], e[1], _PRIO[e[2]]))
        for j in range(n):
            if flow_active(j):
                io_left[j] = max(0.0,
                                 io_left[j] - cur[task_dn[j]] * (t_next - t))
        t = t_next
        if kind in ("kill", "drain", "recover"):
            fpend.remove((t_next, i, kind))
            if kind == "kill":
                kill(i, t)
                wake(t)
            elif kind == "drain":
                f_drain[i] = True
            else:
                f_dead[i] = False
                wake(t)
            if mitigation is not None:
                offer_all(t)
        elif kind == "recheck":
            del rechecks[i]
            offer_all(t)
        elif kind == "io":
            io_left[i] = 0.0
            if t + _EPS >= cpu_done[i]:
                complete(i, t)
        else:
            complete(i, t)

    return _stage_result(records, node_finish, start_time)


def assert_stage_match(oracle, got):
    assert got.completion == _approx(oracle.completion)
    assert got.idle_time == _approx(oracle.idle_time)
    assert set(got.node_finish) == set(oracle.node_finish)
    for name, tt in oracle.node_finish.items():
        assert got.node_finish[name] == _approx(tt)
    ra = sorted(oracle.records, key=lambda r: (r.task_id, r.node, r.start))
    rb = sorted(got.records, key=lambda r: (r.task_id, r.node, r.start))
    assert len(ra) == len(rb)
    for a, b in zip(ra, rb):
        assert b.task_id == a.task_id and b.node == a.node
        assert b.start == _approx(a.start)
        assert b.end == _approx(a.end)
        assert b.cpu_work == _approx(a.cpu_work)


# --------------------------------------------------------------------------
# randomized generators
# --------------------------------------------------------------------------

N_DATANODES = 3


def random_cluster(rng, max_nodes=4, constant=False):
    n = int(rng.integers(2, max_nodes + 1))
    nodes = []
    for i in range(n):
        if constant:
            prof = [(0.0, float(rng.uniform(0.2, 3.0)))]
        else:
            n_seg = int(rng.integers(1, 4))
            breaks = np.concatenate(
                [[0.0], np.cumsum(rng.uniform(0.5, 5.0, n_seg - 1))])
            prof = [(float(tb), float(rng.uniform(0.2, 3.0)))
                    for tb in breaks]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.3))))
    return nodes


def random_policy(rng):
    placement = (None if rng.random() < 0.5
                 else DuplicatePlacement("replica", N_DATANODES))
    if rng.random() < 0.5:
        return WorkStealing(grain=float(rng.choice([0.25, 0.5, 1.0])),
                            placement=placement)
    return SpeculativeCopies(
        quantile=float(rng.choice([0.5, 0.75])),
        factor=float(rng.uniform(1.05, 3.0)),
        min_completed=int(rng.integers(1, 3)),
        io_cost_per_mb=float(rng.choice([0.0, 0.1])),
        placement=placement)


def random_io_tasks(rng, lo=1, hi=14):
    n_tasks = int(rng.integers(lo, hi))
    tasks = []
    for i in range(n_tasks):
        if rng.random() < 0.6:
            io = float(rng.uniform(0.3, 6.0))
            dn = int(rng.integers(0, N_DATANODES))
        else:
            io, dn = 0.0, -1
        tasks.append(SimTask(float(rng.uniform(0.01, 5.0)), io, dn,
                             task_id=i))
    return tasks


def random_static_queues(rng, n):
    queues, next_id = [], 0
    for _ in range(n):
        q = []
        for _ in range(int(rng.integers(0, 3))):
            io = float(rng.uniform(0.3, 6.0)) if rng.random() < 0.5 else 0.0
            dn = int(rng.integers(0, N_DATANODES)) if io else -1
            q.append(SimTask(float(rng.uniform(0.0, 6.0)), io, dn,
                             task_id=next_id))
            next_id += 1
        queues.append(q)
    return queues


def random_uplink(rng):
    return None if rng.random() < 0.25 else float(rng.uniform(0.5, 4.0))


def random_trace(rng, n, t_hi=12.0):
    """1-3 fault events on distinct nodes (one of which may crash twice
    after recovering), random retry policy + checkpoint grain: crashes
    mid-CPU and mid-I/O, recoveries mid-stage, preemption drains."""
    events = []
    hit = rng.permutation(n)[:int(rng.integers(1, min(n, 3) + 1))]
    for nd in hit:
        at = float(rng.uniform(0.1, t_hi))
        u = rng.random()
        if u < 0.3:
            events.append(NodeCrash(int(nd), at))
        elif u < 0.7:
            rec = at + float(rng.uniform(0.5, 6.0))
            events.append(NodeCrash(int(nd), at, recover_at=rec))
            if rng.random() < 0.3:
                events.append(NodeCrash(int(nd),
                                        rec + float(rng.uniform(0.5, 3.0))))
        else:
            events.append(SpotPreemption(
                int(nd), at, warning=float(rng.choice([0.0, 0.5, 1.5]))))
    retry = RetryPolicy(max_attempts=int(rng.integers(1, 4)),
                        relaunch_overhead=float(rng.choice([0.0, 0.2, 0.7])),
                        backoff=float(rng.choice([1.0, 2.0])))
    return FaultTrace(tuple(events), retry=retry,
                      checkpoint_grain=float(rng.choice([0.0, 0.25, 1.0])))


# --------------------------------------------------------------------------
# randomized differential suites (engine vs. oracle at 1e-9)
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_differential_faulted_pull(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_io_tasks(rng)
    bw = random_uplink(rng)
    trace = random_trace(rng, len(nodes))
    start = float(rng.uniform(0.0, 2.0))
    oracle = oracle_stage_faults(nodes, [list(tasks)], pull=True,
                                 faults=trace, uplink_bw=bw,
                                 start_time=start)
    got = run_stage_events(nodes, [tasks], pull=True, uplink_bw=bw,
                           start_time=start, faults=trace)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_faulted_static(seed):
    """HeMT macrotask queues under random crash/recover/preemption traces:
    re-queue destinations, recovery re-execution, retry exhaustion and
    checkpoint flooring all pinned against the rescan oracle."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    queues = random_static_queues(rng, len(nodes))
    bw = random_uplink(rng)
    trace = random_trace(rng, len(nodes))
    oracle = oracle_stage_faults(nodes, [list(q) for q in queues],
                                 pull=False, faults=trace, uplink_bw=bw)
    got = run_stage_events(nodes, queues, pull=False, uplink_bw=bw,
                           faults=trace)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_faulted_mitigated(seed):
    """Faults composed with speculation / work stealing: victims of kills
    that had racing copies, mitigation offers around dead and draining
    nodes, idle rechecks across recoveries."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    pol = random_policy(rng)
    bw = random_uplink(rng)
    trace = random_trace(rng, len(nodes))
    if rng.random() < 0.5:
        queues, pull = [random_io_tasks(rng, hi=10)], True
    else:
        queues, pull = random_static_queues(rng, len(nodes)), False
    oracle = oracle_stage_faults(nodes, [list(q) for q in queues],
                                 pull=pull, faults=trace, uplink_bw=bw,
                                 mitigation=pol)
    got = run_stage_events(nodes, [list(q) for q in queues], pull=pull,
                           uplink_bw=bw, mitigation=pol, faults=trace)
    assert_stage_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_run_job_faulted(seed):
    """run_job threading a fault trace: fault-free stages ride the cached
    shifted solves, fault-overlapping stages re-solve on the absolute-time
    event path — every stage must equal the per-stage oracle run with
    barriers carried by hand."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=True)
    n = len(nodes)
    trace = random_trace(rng, n, t_hi=8.0)
    specs = []
    for _ in range(int(rng.integers(1, 4))):
        pol = random_policy(rng) if rng.random() < 0.4 else None
        if rng.random() < 0.5:
            specs.append(StaticSpec(
                works=tuple(rng.uniform(0.0, 5.0, n)), mitigation=pol,
                io_mb=float(rng.uniform(0.0, 8.0)),
                datanode=int(rng.integers(0, N_DATANODES))))
        else:
            specs.append(PullSpec(
                works=tuple(rng.uniform(0.01, 3.0,
                                        int(rng.integers(1, 10)))),
                io_mb=float(rng.uniform(0.0, 2.0)),
                datanode=int(rng.integers(0, N_DATANODES)),
                mitigation=pol))
    bw = float(rng.uniform(0.5, 4.0))
    run_job_cache_clear()
    sched = run_job(nodes, specs, uplink_bw=bw, faults=trace)
    t = 0.0
    for spec, summ in zip(specs, sched.stages):
        res = oracle_stage_faults(nodes, _spec_tasks(spec),
                                  pull=isinstance(spec, PullSpec),
                                  faults=trace, uplink_bw=bw,
                                  mitigation=spec.mitigation, start_time=t)
        assert summ.completion == _approx(res.completion)
        assert summ.idle_time == _approx(res.idle_time)
        for nd in nodes:
            assert summ.node_finish[nd.name] == _approx(
                res.node_finish[nd.name])
        t = res.completion
    assert sched.completion == _approx(t)


# --------------------------------------------------------------------------
# crafted scenarios: exact numbers per the documented semantics
# --------------------------------------------------------------------------

def _records(res):
    return sorted((r.task_id, r.node, r.start, r.end, r.cpu_work)
                  for r in res.records)


def test_crash_mid_cpu_redistributes_residual():
    """A permanent crash mid-CPU: with no checkpoint the 3 executed units
    are lost, so the WHOLE task re-runs on the least-loaded survivor."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    trace = FaultTrace((NodeCrash(0, 3.0),))
    res = run_static_stage(nodes, [[SimTask(10.0, task_id=0)],
                                   [SimTask(4.0, task_id=1)]],
                           faults=trace)
    assert _records(res) == [(0, "b", 4.0, _approx(14.0), _approx(10.0)),
                             (1, "b", 0.0, _approx(4.0), _approx(4.0))]
    assert res.completion == _approx(14.0)
    assert_stage_match(oracle_stage_faults(
        nodes, [[SimTask(10.0, task_id=0)], [SimTask(4.0, task_id=1)]],
        pull=False, faults=trace), res)


def test_crash_mid_io_drain_frees_the_flow():
    """A reader crashing mid-fetch leaves the uplink at the kill instant:
    the surviving co-reader's flow reprices causally to the full rate, and
    the re-queued task re-fetches its input from scratch."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    tasks = [SimTask(0.1, 8.0, 0, task_id=0), SimTask(0.1, 8.0, 0, task_id=1)]
    trace = FaultTrace((NodeCrash(0, 2.0),))
    res = run_pull_stage(nodes, list(tasks), uplink_bw=2.0, faults=trace)
    # shared 1 MB/s each until t=2; b alone at 2 MB/s drains 6 MB by t=5;
    # task 0 re-fetches all 8 MB alone: 5 + 4 = 9
    assert _records(res) == [(0, "b", 5.0, _approx(9.0), _approx(0.1)),
                             (1, "b", 0.0, _approx(5.0), _approx(0.1))]
    assert res.completion == _approx(9.0)
    assert_stage_match(oracle_stage_faults(
        nodes, [list(tasks)], pull=True, faults=trace, uplink_bw=2.0), res)


def test_speculation_victim_crash_copy_becomes_primary():
    """The straggler dies while a speculative copy races it: the copy
    survives as the task's only attempt — no re-queue, no retry charge."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(10.0, task_id=0), SimTask(1.0, task_id=1)]]
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=1)
    trace = FaultTrace((NodeCrash(0, 5.0),))
    res = run_stage_events(nodes, [list(q) for q in queues], pull=True,
                           mitigation=pol, faults=trace)
    # b finishes task 1 at t=1 -> threshold 2 -> copy of task 0 launches
    # on b at t=2 (work 10, done t=12); a dies at 5 -> copy is primary
    assert _records(res) == [(0, "b", 2.0, _approx(12.0), _approx(10.0)),
                             (1, "b", 0.0, _approx(1.0), _approx(1.0))]
    assert res.completion == _approx(12.0)
    assert_stage_match(oracle_stage_faults(
        nodes, [list(q) for q in queues], pull=True, faults=trace,
        mitigation=pol), res)


def test_recovery_mid_stage_reexecutes_on_the_victim():
    """A crash with a scheduled recovery: the residual waits at the front
    of the victim's own queue and re-executes when the node comes back
    (with the retry policy's relaunch penalty at the new launch)."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(2.0, task_id=0)], [SimTask(8.0, task_id=1)]]
    trace = FaultTrace((NodeCrash(0, 1.0, recover_at=3.0),))
    res = run_static_stage(nodes, [list(q) for q in queues], faults=trace)
    # a's executed unit is lost: the full 2-unit task re-runs at recovery
    assert _records(res) == [(0, "a", 3.0, _approx(5.0), _approx(2.0)),
                             (1, "b", 0.0, _approx(8.0), _approx(8.0))]
    assert res.completion == _approx(8.0)

    slow = FaultTrace((NodeCrash(0, 1.0, recover_at=3.0),),
                      retry=RetryPolicy(relaunch_overhead=0.5))
    res2 = run_static_stage(nodes, [list(q) for q in queues], faults=slow)
    rec = [r for r in res2.records if r.task_id == 0][0]
    assert rec.end == _approx(5.5)          # 3.0 start + 0.5 penalty + 2.0


def test_preemption_drain_checkpoints_at_grain_boundary():
    """A spot preemption with a warning window drains to the kill instant;
    with a checkpoint grain the executed prefix floors to a grain boundary
    and survives as a partial record.  Also pins the tie rule: b's own
    completion at the kill instant is processed after the kill (lower node
    index first), so the residual lands behind b's just-finished task."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(10.0, task_id=0)], [SimTask(3.0, task_id=1)]]
    trace = FaultTrace((SpotPreemption(0, 2.0, warning=1.0),),
                       checkpoint_grain=2.0)
    res = run_static_stage(nodes, [list(q) for q in queues], faults=trace)
    # killed at 3 having executed 3 units -> 2 saved, 8 re-queued to b
    assert _records(res) == [(0, "a", 0.0, _approx(3.0), _approx(2.0)),
                             (0, "b", 3.0, _approx(11.0), _approx(8.0)),
                             (1, "b", 0.0, _approx(3.0), _approx(3.0))]
    assert res.completion == _approx(11.0)
    assert_stage_match(oracle_stage_faults(
        nodes, [list(q) for q in queues], pull=False, faults=trace), res)


def test_draining_node_pulls_no_new_work():
    """During the warning window the node keeps its current attempt but
    pulls nothing new; after the kill, spot capacity never returns."""
    nodes = [SimNode.constant("a", 1.0)]
    tasks = [SimTask(2.0, task_id=0), SimTask(2.0, task_id=1)]
    trace = FaultTrace((SpotPreemption(0, 1.0, warning=10.0),))
    res = run_pull_stage(nodes, list(tasks), faults=trace)
    # task 0 completes at 2 inside the drain window; task 1 is stranded
    assert _records(res) == [(0, "a", 0.0, _approx(2.0), _approx(2.0))]
    assert res.completion == _approx(2.0)


def test_retries_exhausted_abandons_residual():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(10.0, task_id=0)], [SimTask(3.0, task_id=1)]]
    trace = FaultTrace((NodeCrash(0, 3.0),),
                       retry=RetryPolicy(max_attempts=1))
    res = run_static_stage(nodes, [list(q) for q in queues], faults=trace)
    assert _records(res) == [(1, "b", 0.0, _approx(3.0), _approx(3.0))]
    assert res.completion == _approx(3.0)


def test_relaunch_backoff_compounds_across_retries():
    """Two crashes of the same node: the k-th re-launch of the surviving
    task pays relaunch_overhead * backoff**(k-1) at its next launch."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(4.0, task_id=0)], [SimTask(0.5, task_id=1)]]
    trace = FaultTrace(
        (NodeCrash(0, 1.0, recover_at=2.0),
         NodeCrash(0, 3.5, recover_at=5.0)),
        retry=RetryPolicy(max_attempts=3, relaunch_overhead=1.0,
                          backoff=2.0))
    res = run_static_stage(nodes, [list(q) for q in queues], faults=trace)
    # kill 1: no checkpoint, the full 4 units re-queue with penalty 1.0
    # (launch 3, done 7); kill 2 at 3.5 loses the 0.5 executed again and
    # re-queues all 4 with penalty 2.0: the attempt starts at the t=5
    # recovery, computes from launch 7, finishes at 11
    assert _records(res) == [(0, "a", 5.0, _approx(11.0), _approx(4.0)),
                             (1, "b", 0.0, _approx(0.5), _approx(0.5))]
    assert res.completion == _approx(11.0)
    assert_stage_match(oracle_stage_faults(
        nodes, [list(q) for q in queues], pull=False, faults=trace), res)


def test_zero_work_macrotask_on_dead_node_never_waits():
    """An alive-masked replan parks zero-work macrotasks on dead nodes;
    waiting a recovery out to run a no-op would serialize the stage on it,
    so zero-work zero-byte tasks redistribute immediately — real work
    still waits for its node."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    trace = FaultTrace((NodeCrash(1, 0.0, recover_at=100.0),))
    res = run_static_stage(nodes, [[SimTask(2.0, task_id=0)],
                                   [SimTask(0.0, task_id=1)]], faults=trace)
    assert res.completion == _approx(2.0)
    assert all(r.node == "a" for r in res.records)

    real = run_static_stage(nodes, [[SimTask(2.0, task_id=0)],
                                    [SimTask(3.0, task_id=1)]],
                            faults=trace)
    assert real.completion == _approx(103.0)


def test_trace_validation_and_queries():
    with pytest.raises(ValueError):
        NodeCrash(-1, 1.0)
    with pytest.raises(ValueError):
        NodeCrash(0, 2.0, recover_at=1.0)
    with pytest.raises(ValueError):
        SpotPreemption(0, 1.0, warning=-0.5)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff=0.5)
    with pytest.raises(ValueError):        # overlapping intervals, one node
        FaultTrace((NodeCrash(0, 1.0, recover_at=5.0), NodeCrash(0, 3.0)))
    with pytest.raises(ValueError):        # nothing may follow a preemption
        FaultTrace((SpotPreemption(0, 1.0), NodeCrash(0, 9.0)))

    tr = FaultTrace((NodeCrash(0, 2.0, recover_at=4.0),
                     SpotPreemption(1, 3.0, warning=1.0)))
    assert tr.state_at(0, 1.9) == 0 and tr.state_at(0, 2.0) == DEAD
    assert tr.state_at(0, 4.0) == 0
    assert tr.state_at(1, 3.5) == DRAINING and tr.state_at(1, 4.0) == DEAD
    assert tr.alive_mask(3, 3.5) == [False, False, True]
    assert tr.recovery_after(0, 3.0) == 4.0
    assert tr.recovery_after(1, 5.0) is None
    assert tr.overlaps(0.0, 1.0) is False
    assert tr.overlaps(0.0, 2.5) and tr.overlaps(5.0, 6.0)  # preempt open
    assert tr.sub_events(0.0) == [(2.0, 0, "kill"), (3.0, 1, "drain"),
                                  (4.0, 0, "recover"), (4.0, 1, "kill")]
    assert tr.sub_events(2.0) == [(3.0, 1, "drain"), (4.0, 0, "recover"),
                                  (4.0, 1, "kill")]
    # a same-instant recover/kill pair on one node processes recover first
    adj = FaultTrace((NodeCrash(0, 1.0, recover_at=3.0), NodeCrash(0, 3.0)))
    assert adj.sub_events(0.0) == [(1.0, 0, "kill"), (3.0, 0, "recover"),
                                   (3.0, 0, "kill")]

    shifted = tr.shift(10.0)
    assert shifted.state_at(0, 12.5) == DEAD
    kept = tr.restrict([1, 2])
    assert kept.max_node() == 0            # node 1 renumbered to 0
    assert kept.state_at(0, 3.5) == DRAINING

    cold = FaultTrace((NodeCrash(2, 1.0, recover_at=6.0, cold_restart=True),
                       NodeCrash(0, 2.0, recover_at=3.0)))
    assert cold.cold_restarts() == [(6.0, 2)]

    with pytest.raises(ValueError):        # trace names a node out of range
        run_stage_events([SimNode.constant("a", 1.0)],
                         [[SimTask(1.0, task_id=0)]], pull=False,
                         faults=FaultTrace((NodeCrash(3, 1.0),)))

    assert lost_work(10.0, 7.0) == _approx(3.0)
    assert lost_work(7.0, 7.0 + 1e-12) == 0.0


def test_trace_shift_restrict_edge_cases():
    """shift/restrict corners the resident + elastic drivers rely on:
    negative shifts, empty/superset/reordered keep sets, and policy/grain
    preservation on every derived trace."""
    retry = RetryPolicy(max_attempts=2, relaunch_overhead=0.5, backoff=2.0)
    tr = FaultTrace((NodeCrash(0, 2.0, recover_at=4.0, cold_restart=True),
                     SpotPreemption(2, 3.0, warning=1.0)),
                    retry=retry, checkpoint_grain=0.25)

    # a negative shift moves events before t=0 and stays queryable ...
    back = tr.shift(-3.0)
    assert back.events[0].at == _approx(-1.0)
    assert back.state_at(0, -0.5) == DEAD and back.state_at(0, 1.5) == 0
    assert back.state_at(2, 0.5) == DRAINING
    # ... and shifting back is an exact inverse (frozen-dataclass equality)
    assert back.shift(3.0) == tr
    # the retry policy and checkpoint grain ride every derived trace
    assert back.retry == retry and back.checkpoint_grain == 0.25

    # restrict to the empty fleet: no events, no max node, all-alive
    empty = tr.restrict([])
    assert empty.events == () and empty.max_node() == -1
    assert empty.state_at(0, 2.5) == 0
    assert empty.retry == retry and empty.checkpoint_grain == 0.25

    # the keep *order* defines the renumbering: keep=[2, 0] -> 2->0, 0->1
    swapped = tr.restrict([2, 0])
    assert {type(e).__name__: e.node for e in swapped.events} == \
        {"SpotPreemption": 0, "NodeCrash": 1}
    assert swapped.state_at(0, 3.5) == DRAINING   # the preemption moved
    assert swapped.state_at(1, 2.5) == DEAD
    crash = next(e for e in swapped.events if isinstance(e, NodeCrash))
    assert crash.cold_restart and crash.recover_at == 4.0
    assert swapped.cold_restarts() == [(4.0, 1)]
    pre = next(e for e in swapped.events if isinstance(e, SpotPreemption))
    assert pre.warning == 1.0

    # a keep list naming untouched nodes (superset) renumbers around them
    sup = tr.restrict([3, 0, 5, 2])
    assert {e.node for e in sup.events} == {1, 3}
    assert sup.max_node() == 3
    # ... and a reordering that keeps everything is a pure permutation
    assert tr.restrict([0, 1, 2]).events == tr.events

    # restricting away every faulted node leaves a clean trace that still
    # composes with shift and never overlaps anything
    clean = tr.restrict([1]).shift(100.0)
    assert clean.events == () and clean.overlaps(0.0, math.inf) is False

    # per-node non-overlap is re-validated on the renumbered events, so a
    # legal reordering of a two-interval node stays legal
    multi = FaultTrace((NodeCrash(0, 1.0, recover_at=3.0),
                        NodeCrash(0, 5.0), NodeCrash(1, 2.0)))
    re = multi.restrict([1, 0])
    assert [(e.node, e.at) for e in re.events] == \
        [(1, 1.0), (0, 2.0), (1, 5.0)]


# --------------------------------------------------------------------------
# run_job: cache no-poisoning, reskew fold, adaptive composition
# --------------------------------------------------------------------------

def test_fault_solves_never_poison_the_start_invariant_cache():
    """Fault windows break start-invariance, so fault-affected stages must
    bypass both solve cache levels: a fault-free job run right after a
    faulted one (warm LRU) must reproduce the pure closed-form schedule,
    and a warm-cache faulted re-run must reproduce itself."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    spec = StaticSpec(works=(4.0, 4.0))
    trace = FaultTrace((NodeCrash(1, 5.0),),
                       retry=RetryPolicy(max_attempts=1))
    run_job_cache_clear()
    faulted = run_job(nodes, [spec] * 3, faults=trace)
    # stage 0 [0,4] is untouched; stage 1 loses b's residual at t=5;
    # stage 2 runs both macrotasks on a (b dead for good, queue shed)
    assert faulted.stages[0].span == _approx(4.0)
    assert faulted.stages[1].completion == _approx(8.0)
    assert faulted.stages[1].work["b"] == _approx(0.0)
    assert faulted.stages[2].completion == _approx(16.0)

    clean = run_job(nodes, [spec] * 3)     # warm cache: must be untainted
    assert [s.span for s in clean.stages] == [_approx(4.0)] * 3
    assert clean.completion == _approx(12.0)

    again = run_job(nodes, [spec] * 3, faults=trace)
    for a, b in zip(faulted.stages, again.stages):
        assert b.completion == _approx(a.completion)
        assert b.node_finish == a.node_finish
    assert again.completion == _approx(faulted.completion)


def test_fault_lost_work_folds_through_reskew_handoff():
    """Work a fault-affected stage abandoned folds into the next stage's
    split through ReskewHandoff, proportional to observed survivor
    throughput; without a handoff the loss is eaten."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    trace = FaultTrace((NodeCrash(1, 2.0),),
                       retry=RetryPolicy(max_attempts=1))
    rk = ReskewHandoff(cutoff_factor=10.0)  # never cuts on its own
    run_job_cache_clear()
    folded = run_job(nodes, [StaticSpec(works=(4.0, 4.0), mitigation=rk),
                             StaticSpec(works=(4.0, 4.0), mitigation=rk)],
                     faults=trace)
    # stage 0: b's 4 units die at t=2 unrecorded -> lost=4 folds onto a
    # (only observed survivor); stage 1 works (8, 4) all execute on a
    assert folded.completion == _approx(16.0)

    eaten = run_job(nodes, [StaticSpec(works=(4.0, 4.0)),
                            StaticSpec(works=(4.0, 4.0))], faults=trace)
    assert eaten.completion == _approx(12.0)


def test_adaptive_replan_masks_dead_nodes_at_the_barrier():
    """OA-HeMT under faults: a stage planned while a node is dead re-splits
    the whole total over the survivors (who keep their AR(1) estimates);
    the dead node gets a zero-work macrotask."""
    nodes = [SimNode.constant("a", 2.0), SimNode.constant("b", 1.0),
             SimNode.constant("c", 4.0)]
    spec = StaticSpec(works=(20.0, 10.0, 40.0))
    trace = FaultTrace((NodeCrash(2, 11.0, recover_at=1000.0),),
                       retry=RetryPolicy(max_attempts=1))
    adaptive = AdaptivePlan()
    run_job_cache_clear()
    sched = run_job(nodes, [spec] * 3, adaptive=adaptive, faults=trace)
    h = adaptive.history
    # stage 0 [0,10] fault-free, cold estimator keeps the planned split
    assert not h[0].replanned
    # stage 1 replans from learned speeds (2,1,4) -> same split; c dies
    # mid-stage at t=11, its residual is abandoned (1 attempt)
    assert h[1].replanned and h[1].works == _approx((20.0, 10.0, 40.0))
    assert sched.stages[1].work["c"] == _approx(0.0)
    # stage 2 barrier at t=20: c is dead -> masked replan, survivors split
    # the full 70 units by their kept estimates (2:1), c gets zero
    assert h[2].works[2] == 0.0
    assert h[2].works[0] == _approx(140.0 / 3.0)
    assert h[2].works[1] == _approx(70.0 / 3.0)
    assert sched.completion == _approx(20.0 + 70.0 / 3.0)


def test_cold_restart_forgets_estimate_at_recovery_barrier():
    """A crash marked cold_restart=True: the first barrier at/after the
    recovery forgets the node's AR(1) estimate, so the replacement
    cold-starts at the survivor mean (paper §5.1's L_k^o rule)."""
    nodes = [SimNode.constant("a", 2.0), SimNode.constant("b", 1.0),
             SimNode.constant("c", 4.0)]
    spec = StaticSpec(works=(20.0, 10.0, 40.0))
    trace = FaultTrace((NodeCrash(2, 3.0, recover_at=5.0,
                                  cold_restart=True),))
    adaptive = AdaptivePlan()
    run_job_cache_clear()
    run_job(nodes, [spec] * 2, adaptive=adaptive, faults=trace)
    # stage 0: c killed at 3, re-executes 28 units on recovery [5, 12];
    # barrier t=12 >= recover_at=5 -> forget c before replanning stage 1
    h = adaptive.history
    assert h[1].replanned
    assert h[1].speeds[0] == _approx(2.0)
    assert h[1].speeds[1] == _approx(1.0)
    assert h[1].speeds[2] == _approx(1.5)   # survivor mean of (2, 1)


def test_empty_trace_is_a_no_op():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 0.5)]
    queues = [[SimTask(3.0, task_id=0)], [SimTask(1.0, task_id=1)]]
    base = run_static_stage(nodes, [list(q) for q in queues])
    got = run_static_stage(nodes, [list(q) for q in queues],
                           faults=FaultTrace())
    assert got.records == base.records
    assert got.completion == base.completion
    run_job_cache_clear()
    assert run_job(nodes, [StaticSpec(works=(2.0, 1.0))],
                   faults=FaultTrace()).completion == _approx(2.0)


def test_bench_faults_reproduces_degradation_ordering():
    """Acceptance row: under the same preemption trace, HomT degrades
    gracefully, stale static HeMT collapses, and OA-HeMT with a re-skew
    handoff stays within a small gap of the post-failure clairvoyant
    schedule."""
    from benchmarks.bench_faults import scenario_completions

    c = scenario_completions()
    assert c["oa_hemt_faults"] < c["hemt_stale_faults"], c
    assert c["homt_faults"] < c["hemt_stale_faults"], c
    # graceful HomT: bounded blow-up over its own fault-free run
    assert c["homt_faults"] < 2.0 * c["homt_clean"], c
    # stale static HeMT collapses: worse than double its clean run
    assert c["hemt_stale_faults"] > 2.0 * c["hemt_clean"], c
    # OA-HeMT lands within 30% of the post-failure clairvoyant optimum
    assert c["oa_hemt_faults"] <= 1.3 * c["clairvoyant_faults"], c
