"""The benchmarks/run.py --check CI perf gate (ROADMAP item)."""
import json
import os

import pytest

from benchmarks.run import compare_rows, resolve_threshold, run_check


@pytest.fixture(autouse=True)
def _isolate_threshold_env(monkeypatch):
    """run_check resolves BENCH_CHECK_THRESHOLD when no explicit threshold
    is passed; a developer's exported value (README documents exporting
    it) must not flip the default-path tests."""
    monkeypatch.delenv("BENCH_CHECK_THRESHOLD", raising=False)


def _row(name, us):
    return {"name": name, "us_per_call": us, "derived": ""}


BASE = [_row("sim_engine/pull_10000", 1000.0),
        _row("sim_engine/job_pull_10x1000", 500.0),
        _row("sim_engine/summary", 0.0)]         # derived-only: never gated


def test_within_threshold_passes():
    fresh = [_row("sim_engine/pull_10000", 1900.0),
             _row("sim_engine/job_pull_10x1000", 400.0),
             _row("sim_engine/summary", 0.0)]
    assert compare_rows(BASE, fresh) == []


def test_regression_flagged():
    fresh = [_row("sim_engine/pull_10000", 2100.0),
             _row("sim_engine/job_pull_10x1000", 400.0)]
    msgs = compare_rows(BASE, fresh)
    assert len(msgs) == 1
    assert "pull_10000" in msgs[0]


def test_missing_row_flagged_and_new_rows_ignored():
    fresh = [_row("sim_engine/pull_10000", 900.0),
             _row("sim_engine/brand_new_row", 1e9)]
    msgs = compare_rows(BASE, fresh)
    assert len(msgs) == 1
    assert "job_pull_10x1000" in msgs[0] and "missing" in msgs[0]


def test_derived_only_rows_never_gate():
    fresh = [_row("sim_engine/pull_10000", 900.0),
             _row("sim_engine/job_pull_10x1000", 490.0),
             _row("sim_engine/summary", 1e9)]
    assert compare_rows(BASE, fresh) == []


def test_custom_threshold():
    fresh = [_row("sim_engine/pull_10000", 1500.0),
             _row("sim_engine/job_pull_10x1000", 500.0)]
    assert compare_rows(BASE, fresh, threshold=2.0) == []
    assert len(compare_rows(BASE, fresh, threshold=1.2)) == 1


@pytest.mark.parametrize("fresh_us,expect", [(1500.0, 0), (2500.0, 1)])
def test_run_check_exit_codes(tmp_path, capsys, fresh_us, expect):
    baseline = tmp_path / "BENCH_sim.json"
    baseline.write_text(json.dumps(
        {"schema": 1, "sim": BASE, "kernels": [_row("kern/x", 1.0)]}))
    fresh = [_row("sim_engine/pull_10000", fresh_us),
             _row("sim_engine/job_pull_10x1000", 500.0)]
    rc = run_check(str(baseline), fresh_rows=fresh)
    assert rc == expect
    err = capsys.readouterr().err
    if expect:
        assert "REGRESSION" in err
    else:
        assert "REGRESSION" not in err


def test_threshold_override_precedence(monkeypatch):
    """CLI flag > BENCH_CHECK_THRESHOLD env var > 2x default — hardcoded
    headroom is wrong for noisy shared CI runners."""
    assert resolve_threshold() == 2.0
    monkeypatch.setenv("BENCH_CHECK_THRESHOLD", "4.5")
    assert resolve_threshold() == 4.5
    assert resolve_threshold(1.5) == 1.5          # CLI beats env
    monkeypatch.setenv("BENCH_CHECK_THRESHOLD", "")
    assert resolve_threshold() == 2.0             # empty = unset


@pytest.mark.parametrize("bad", ["abc", "0", "-3", "nan"])
def test_threshold_env_rejects_malformed_values(monkeypatch, bad):
    monkeypatch.setenv("BENCH_CHECK_THRESHOLD", bad)
    with pytest.raises(SystemExit, match="BENCH_CHECK_THRESHOLD"):
        resolve_threshold()


@pytest.mark.parametrize("bad", [0.0, -1.0, float("nan")])
def test_threshold_cli_rejects_malformed_values(bad):
    """A zero/NaN --threshold would make the gate always-fail or
    always-pass; reject it like the env var."""
    with pytest.raises(SystemExit, match="--threshold"):
        resolve_threshold(bad)


def test_run_check_honors_env_threshold(tmp_path, capsys, monkeypatch):
    """A 2.1x regression passes with BENCH_CHECK_THRESHOLD=4, fails at the
    default — the override reaches the gate itself."""
    baseline = tmp_path / "BENCH_sim.json"
    baseline.write_text(json.dumps({"schema": 1, "sim": BASE}))
    fresh = [_row("sim_engine/pull_10000", 2100.0),
             _row("sim_engine/job_pull_10x1000", 500.0)]
    monkeypatch.setenv("BENCH_CHECK_THRESHOLD", "4")
    assert run_check(str(baseline), fresh_rows=fresh) == 0
    monkeypatch.delenv("BENCH_CHECK_THRESHOLD")
    assert run_check(str(baseline), fresh_rows=fresh) == 1
    assert "REGRESSION" in capsys.readouterr().err


def test_run_check_gates_speculation_io_section(tmp_path, capsys):
    """The --check gate covers the speculation_io rows alongside
    sim_engine: a regressed duplicate-reader row fails the gate; a
    section absent from the baseline is ignored (transition PRs)."""
    baseline = tmp_path / "BENCH_sim.json"
    baseline.write_text(json.dumps({
        "schema": 1, "sim": BASE,
        "speculation_io": [_row("speculation_io/stale_hemt_io_spec", 100.0),
                           _row("speculation_io/stale_ordering", 0.0)]}))
    ok = {"sim": [_row("sim_engine/pull_10000", 900.0),
                  _row("sim_engine/job_pull_10x1000", 500.0)],
          "speculation_io": [_row("speculation_io/stale_hemt_io_spec", 150.0),
                             _row("speculation_io/stale_ordering", 0.0)]}
    assert run_check(str(baseline), fresh_rows=ok) == 0
    bad = {**ok,
           "speculation_io": [_row("speculation_io/stale_hemt_io_spec",
                                   500.0)]}
    assert run_check(str(baseline), fresh_rows=bad) == 1
    err = capsys.readouterr().err
    assert "stale_hemt_io_spec" in err and "REGRESSION" in err
    # baseline without the section: nothing to gate there
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps({"schema": 1, "sim": BASE}))
    assert run_check(str(bare), fresh_rows=ok) == 0


def test_batched_section_registered():
    """The batched planner rows are wired into all three run.py tables:
    they run with the full sweep, persist to BENCH_sim.json, and gate."""
    from benchmarks.run import GATED_SECTIONS, JSON_SECTIONS, MODULES
    assert "benchmarks.bench_batched" in MODULES
    assert JSON_SECTIONS["benchmarks.bench_batched"] == "batched"
    assert GATED_SECTIONS["batched"] == "benchmarks.bench_batched"


def test_run_check_gates_batched_section(tmp_path, capsys):
    """The --check gate covers the batched rows: a regressed solver row
    fails, a vanished row fails, and a threshold override clears a
    borderline regression — mirroring the speculation_io coverage."""
    baseline = tmp_path / "BENCH_sim.json"
    baseline.write_text(json.dumps({
        "schema": 1, "sim": BASE,
        "batched": [_row("batched/pull_hetero_B1000", 20_000.0),
                    _row("batched/static_B1000", 300.0)]}))
    ok = {"sim": [_row("sim_engine/pull_10000", 900.0),
                  _row("sim_engine/job_pull_10x1000", 500.0)],
          "batched": [_row("batched/pull_hetero_B1000", 30_000.0),
                      _row("batched/static_B1000", 350.0)]}
    assert run_check(str(baseline), fresh_rows=ok) == 0

    regressed = {**ok,
                 "batched": [_row("batched/pull_hetero_B1000", 90_000.0),
                             _row("batched/static_B1000", 350.0)]}
    assert run_check(str(baseline), fresh_rows=regressed) == 1
    err = capsys.readouterr().err
    assert "pull_hetero_B1000" in err and "REGRESSION" in err

    vanished = {**ok, "batched": [_row("batched/static_B1000", 350.0)]}
    assert run_check(str(baseline), fresh_rows=vanished) == 1
    err = capsys.readouterr().err
    assert "pull_hetero_B1000" in err and "missing" in err

    # threshold override (CI headroom) clears the 4.5x-but-<6x regression
    assert run_check(str(baseline), fresh_rows=regressed, threshold=6.0) == 0


def test_run_check_missing_or_bad_baseline(tmp_path, capsys):
    assert run_check(str(tmp_path / "nope.json"), fresh_rows=[]) == 1
    assert "cannot read baseline" in capsys.readouterr().err
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert run_check(str(bad), fresh_rows=[]) == 1
    assert "not valid JSON" in capsys.readouterr().err


def test_committed_baseline_gate(capsys):
    """ROADMAP item 5: tier-1 pytest exercises the --check gate on the
    committed BENCH_sim.json — the sim_engine rows re-run live and must
    sit within threshold of the repo baseline.  4x (vs. the CLI's 2x
    default) leaves headroom for loaded CI machines; a genuine fast-path
    regression (the gated rows are 5-80x off their event-path fallbacks)
    still trips it."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    baseline = os.path.join(repo_root, "BENCH_sim.json")
    assert run_check(baseline, threshold=4.0) == 0
    assert "OK" in capsys.readouterr().out
