"""Fleet-scale request serving: arrival traces, the serving resident
path vs the rescan oracle, and the HeMT-vs-HomT latency claims.

Three layers:

* **arrival generators** (``repro.core.arrivals``) — determinism from
  the seed, hashability of frozen specs, bounds/ordering, expected
  counts, and the millions-of-requests scale contract;
* **randomized differential suites** — serving scenarios build resident
  batch jobs (prefill pulls + macrotask decodes, compatibility masks,
  faults, burstable replicas) and the calendar's run is pinned against
  ``oracle_resident`` (tests/test_resident.py's naive per-event rescan)
  at 1e-9, plus crafted burst / credit-exhaustion / strand scenarios
  with exact numbers;
* **policy claims** — the bench scenario's HeMT < HomT p99 / attainment
  ordering, and the closed-loop ``run_round`` driver (observe feedback,
  speculation on straggling replicas).
"""
import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.arrivals import (
    DiurnalTrace, MMPPTrace, PoissonTrace, dispatch_epochs,
)
from repro.core.engine import run_job_cache_clear
from repro.core.faults import FaultTrace, NodeCrash, SpotPreemption
from repro.core.resident import ResidentCalendar
from repro.core.simulator import SimNode
from repro.core.speculation import SpeculativeCopies
from repro.runtime.serve_loop import HeMTBatcher
from repro.runtime.serving import (
    RequestModel, ServingReport, ServingScenario, run_round,
)
from test_resident import assert_resident_match, oracle_resident

REL = ABS = 1e-9


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# arrival traces
# --------------------------------------------------------------------------

@pytest.mark.parametrize("trace", [
    PoissonTrace(3.0, 20.0, seed=5),
    DiurnalTrace(1.0, 5.0, 10.0, 20.0, seed=5),
    MMPPTrace((1.0, 8.0), (4.0, 1.0), 20.0, seed=5),
])
def test_traces_deterministic_sorted_bounded(trace):
    a, b = trace.times(), trace.times()
    assert np.array_equal(a, b)           # same seed -> identical trace
    assert np.all(np.diff(a) >= 0.0)
    if a.size:
        assert a[0] >= 0.0 and a[-1] < trace.horizon
    # frozen specs are hashable and compare by value
    assert hash(trace) == hash(type(trace)(**{
        f: getattr(trace, f) for f in trace.__dataclass_fields__}))


def test_trace_seeds_differ():
    a = PoissonTrace(3.0, 20.0, seed=1).times()
    b = PoissonTrace(3.0, 20.0, seed=2).times()
    assert a.size != b.size or not np.array_equal(a, b)


@pytest.mark.parametrize("trace", [
    PoissonTrace(50.0, 40.0, seed=9),
    DiurnalTrace(20.0, 80.0, 10.0, 40.0, seed=9),
])
def test_trace_counts_near_expected(trace):
    n = trace.times().size
    exp = trace.expected()
    assert abs(n - exp) < 5.0 * math.sqrt(exp) + 5.0


def test_mmpp_counts_near_expected_in_mean():
    """MMPP counts are over-dispersed (dwell randomness dominates over a
    few cycles), so the expected() contract is checked on the seed
    average rather than one realization."""
    mean = np.mean([MMPPTrace((20.0, 100.0), (5.0, 2.0), 40.0,
                              seed=s).times().size for s in range(30)])
    exp = MMPPTrace((20.0, 100.0), (5.0, 2.0), 40.0).expected()
    assert abs(mean - exp) < 0.15 * exp


def test_diurnal_rate_curve():
    tr = DiurnalTrace(1.0, 5.0, 10.0, 20.0, phase=2.0)
    assert tr.rate_at(2.0) == _approx(1.0)        # trough at the phase
    assert tr.rate_at(7.0) == _approx(5.0)        # peak half a period on
    assert tr.mean_rate == _approx(3.0)
    assert tr.expected() == _approx(60.0)         # two whole periods


def test_mmpp_mean_rate_is_dwell_weighted():
    tr = MMPPTrace((1.0, 9.0), (3.0, 1.0), 100.0)
    assert tr.mean_rate == _approx(3.0)


def test_million_request_scale():
    t = PoissonTrace(50_000.0, 20.0, seed=2).times()
    assert t.size > 900_000
    assert np.all(np.diff(t) >= 0.0)


def test_dispatch_epochs():
    ep = dispatch_epochs(np.array([0.0, 0.4, 1.9, 2.0, 7.5]), 2.0)
    assert ep.tolist() == [0, 0, 0, 1, 3]
    with pytest.raises(ValueError):
        dispatch_epochs(np.array([1.0]), 0.0)


@pytest.mark.parametrize("bad", [
    lambda: PoissonTrace(-1.0, 10.0),
    lambda: PoissonTrace(1.0, 0.0),
    lambda: DiurnalTrace(2.0, 1.0, 10.0, 20.0),
    lambda: MMPPTrace((1.0,), (0.0,), 10.0),
    lambda: MMPPTrace((1.0, 2.0), (1.0,), 10.0),
    lambda: MMPPTrace((1.0,), (1.0,), 10.0, start_state=3),
])
def test_trace_validation(bad):
    with pytest.raises(ValueError):
        bad()


# --------------------------------------------------------------------------
# request model & scenario validation
# --------------------------------------------------------------------------

def test_request_model_sampling():
    m = RequestModel(decode_work=2.0, work_cv=0.5, classes=3, seed=4)
    w1, k1 = m.sample(500)
    w2, k2 = m.sample(500)
    assert np.array_equal(w1, w2) and np.array_equal(k1, k2)
    assert abs(w1.mean() - 2.0) < 0.2             # lognormal mean preserved
    assert set(np.unique(k1)) <= {0, 1, 2}
    w3, k3 = RequestModel().sample(4)
    assert w3.tolist() == [1.0] * 4 and k3.tolist() == [0] * 4


def test_scenario_validation():
    nd = [SimNode("a", [(0.0, 1.0)], 0.0)]
    with pytest.raises(ValueError):
        ServingScenario(nd, window=0.0)
    with pytest.raises(ValueError):
        ServingScenario(nd, window=1.0, mode="magic")
    with pytest.raises(ValueError):
        ServingScenario(nd, window=1.0, mask={0: ["ghost"]})
    with pytest.raises(ValueError):
        ServingScenario(nd, window=1.0, mask={0: []})
    with pytest.raises(ValueError):
        RequestModel(decode_work=0.0)
    with pytest.raises(ValueError):
        RequestModel(classes=0)


def test_empty_trace_report():
    nd = [SimNode("a", [(0.0, 1.0)], 0.0)]
    rep = ServingScenario(nd, window=1.0, slo=2.0).run(np.empty(0))
    assert rep.n_requests == 0
    assert rep.attainment == 1.0 and rep.goodput == 0.0


# --------------------------------------------------------------------------
# randomized differential suites: serving jobs vs the rescan oracle
# --------------------------------------------------------------------------

def _random_fleet(rng, burstable=False):
    n = int(rng.integers(2, 5))
    nodes = []
    for i in range(n):
        s = float(rng.uniform(0.5, 3.0))
        if burstable and rng.random() < 0.5:
            t_b = float(rng.uniform(1.0, 6.0))
            prof = [(0.0, s), (t_b, s * float(rng.uniform(0.2, 0.8)))]
        else:
            prof = [(0.0, s)]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.1))))
    return nodes


def _random_scenario(rng, nodes, faults=None, with_mask=False):
    classes = int(rng.integers(2, 4)) if with_mask else 1
    mask = None
    if with_mask:
        names = [nd.name for nd in nodes]
        mask = {}
        for c in range(classes):
            if rng.random() < 0.7:
                k = int(rng.integers(1, len(names) + 1))
                mask[c] = sorted(rng.permutation(names)[:k].tolist())
    model = RequestModel(
        decode_work=float(rng.uniform(0.3, 1.5)),
        work_cv=float(rng.choice([0.0, 0.5])),
        prefill_mb=float(rng.choice([0.0, 2.0])),
        prefill_work=float(rng.choice([0.0, 0.2])),
        classes=classes, seed=int(rng.integers(0, 1000)))
    return ServingScenario(
        nodes,
        window=float(rng.uniform(0.8, 2.0)),
        model=model,
        mode=str(rng.choice(["hemt", "even", "oracle"])),
        slo=None if rng.random() < 0.3 else float(rng.uniform(2.0, 8.0)),
        uplink_bw=None if model.prefill_mb == 0.0 or rng.random() < 0.3
        else float(rng.uniform(1.0, 8.0)),
        datanode=int(rng.integers(0, len(nodes))),
        faults=faults,
        mask=mask,
        alpha=float(rng.choice([0.0, 0.3])),
        warmup=int(rng.integers(0, 2)),
        max_prefill_tasks=int(rng.choice([0, 3])))


def _differential(rng, scenario, nodes, horizon, faults=None):
    times = np.sort(rng.uniform(0.0, horizon, int(rng.integers(3, 14))))
    works, klass = scenario.model.sample(times.size)
    run_job_cache_clear()
    jobs_got, _ = scenario.build_jobs(times, works, klass, horizon)
    jobs_exp, _ = scenario.build_jobs(times, works, klass, horizon)
    got = ResidentCalendar(nodes, scenario.uplink_bw,
                           faults=faults).run(jobs_got)
    exp = oracle_resident(nodes, jobs_exp, uplink_bw=scenario.uplink_bw,
                          faults=faults)
    assert_resident_match(exp, got)


@given(seed=st.integers(0, 10_000))
def test_differential_serving_clean(seed):
    """Serving batch jobs (prefill pulls + single-macrotask decodes,
    shared-estimator adaptive plans, oracle proportions) through the
    calendar vs the first-principles rescan oracle."""
    rng = np.random.default_rng(seed)
    nodes = _random_fleet(rng, burstable=True)
    sc = _random_scenario(rng, nodes)
    _differential(rng, sc, nodes, horizon=8.0)


@given(seed=st.integers(0, 10_000))
def test_differential_serving_masked(seed):
    """Sparse request->replica compatibility: windows split into per-mask
    sub-jobs whose ``allowed`` sets prune node grants on both sides."""
    rng = np.random.default_rng(seed)
    nodes = _random_fleet(rng)
    sc = _random_scenario(rng, nodes, with_mask=True)
    _differential(rng, sc, nodes, horizon=8.0)


@given(seed=st.integers(0, 10_000))
def test_differential_serving_faults(seed):
    """Crashes and spot preemptions mid-trace: killed decode attempts
    checkpoint and requeue per the retry budget, later batches split
    across survivors — still 1e-9 against the oracle."""
    rng = np.random.default_rng(seed)
    nodes = _random_fleet(rng, burstable=True)
    events = []
    for nd in rng.permutation(len(nodes))[:int(rng.integers(1, 3))]:
        at = float(rng.uniform(0.5, 7.0))
        if rng.random() < 0.5:
            events.append(NodeCrash(
                int(nd), at,
                recover_at=None if rng.random() < 0.5
                else at + float(rng.uniform(0.5, 3.0)),
                cold_restart=rng.random() < 0.3))
        else:
            events.append(SpotPreemption(
                int(nd), at, warning=float(rng.choice([0.0, 0.5]))))
    faults = FaultTrace(tuple(events),
                        checkpoint_grain=float(rng.choice([0.0, 0.25])))
    sc = _random_scenario(rng, nodes, faults=faults,
                          with_mask=rng.random() < 0.3)
    _differential(rng, sc, nodes, horizon=8.0, faults=faults)


# --------------------------------------------------------------------------
# crafted scenarios: exact numbers
# --------------------------------------------------------------------------

def _fleet(speeds, overhead=0.0):
    return [SimNode(f"n{i}", [(0.0, s)], overhead)
            for i, s in enumerate(speeds)]


def test_crafted_single_burst_even_vs_hemt():
    """Four 1.5-work requests in one 2 s window on a 2:1 fleet.  Even
    mode splits the 6.0 decode 3.0/3.0 (slow node finishes at 2+3);
    HeMT's probed estimator splits 4.0/2.0 so both replicas finish at
    2+2 — the batch-level makespan claim with exact numbers."""
    times = np.array([0.1, 0.5, 1.0, 1.9])
    even = ServingScenario(_fleet((2.0, 1.0)), window=2.0, mode="even",
                           slo=4.0, model=RequestModel(decode_work=1.5))
    rep = even.run(times)
    assert rep.result.outcomes["b0000000"].completion == _approx(5.0)
    assert rep.latencies.max() == _approx(5.0 - 0.1)
    assert rep.attainment == _approx(0.5)   # t=0.1, 0.5 miss the 4 s SLO

    hemt = ServingScenario(_fleet((2.0, 1.0)), window=2.0, mode="hemt",
                           slo=4.0, model=RequestModel(decode_work=1.5))
    rep_h = hemt.run(times)
    out = rep_h.result.outcomes["b0000000"]
    assert out.completion == _approx(4.0)
    assert out.planned[-1] == {"n0": _approx(4.0), "n1": _approx(2.0)}
    assert rep_h.attainment == 1.0
    assert rep_h.latencies.max() == _approx(3.9)


def test_compare_modes_sweep():
    """compare_modes runs one trace under every batching mode on replace()
    copies: the input scenario is untouched, each report matches a direct
    run of that mode, and the crafted 2:1 burst ordering (hemt beats even)
    carries through the sweep."""
    from repro.runtime.serving import compare_modes
    times = np.array([0.1, 0.5, 1.0, 1.9])
    sc = ServingScenario(_fleet((2.0, 1.0)), window=2.0, mode="even",
                         slo=4.0, model=RequestModel(decode_work=1.5))
    reports = compare_modes(sc, times)
    assert set(reports) == {"hemt", "even", "oracle"}
    assert sc.mode == "even"                      # input never mutated
    assert reports["even"].attainment == _approx(0.5)
    assert reports["hemt"].attainment == 1.0
    assert reports["hemt"].p99 <= reports["even"].p99 + 1e-9
    direct = ServingScenario(_fleet((2.0, 1.0)), window=2.0, mode="hemt",
                             slo=4.0,
                             model=RequestModel(decode_work=1.5)).run(times)
    assert np.array_equal(reports["hemt"].latencies, direct.latencies)
    sub = compare_modes(sc, times, modes=("oracle",))
    assert list(sub) == ["oracle"]
    with pytest.raises(ValueError, match="unknown modes"):
        compare_modes(sc, times, modes=("hemt", "magic"))


def test_crafted_credit_exhaustion_resplit():
    """Replica 0 burns its burst credits at t=2.5 (2.0x -> 0.4x).  The
    first batch is split on probed t=0 speeds (2:1); its barrier
    measures the throttled replica's realized throughput and the next
    batch's split shifts toward the steady 1.0x machine."""
    nodes = [SimNode("burst", [(0.0, 2.0), (2.5, 0.4)], 0.0),
             SimNode("flat", [(0.0, 1.0)], 0.0)]
    sc = ServingScenario(nodes, window=2.0, mode="hemt", alpha=0.0,
                         model=RequestModel(decode_work=3.0))
    times = np.array([0.5, 8.5])        # batch 0 at t=2, batch 4 at t=10
    works, klass = sc.model.sample(2)
    jobs, _ = sc.build_jobs(times, works, klass, 12.0)
    res = ResidentCalendar(nodes).run(jobs)
    o0, o1 = res.outcomes["b0000000"], res.outcomes["b0000004"]
    p0, p1 = o0.planned[-1], o1.planned[-1]
    assert p0["burst"] == _approx(2.0) and p0["flat"] == _approx(1.0)
    # burst runs 1.0 work at 2.0x (t=2..2.5), the rest at 0.4x: 2.0 work
    # over 3.0 s -> observed 2/3 vs flat's 1.0; completion t=5.
    assert o0.completion == _approx(5.0)
    # batch 4's replan: 3.0 * (2/3)/(5/3) = 1.2 on burst, 1.8 on flat
    assert p1["burst"] == _approx(1.2) and p1["flat"] == _approx(1.8)
    # burst's 1.2-work slice at 0.4x takes 3.0 s from t=10
    assert o1.completion == _approx(13.0)
    assert o1.stages[-1].work["burst"] == _approx(1.2)


def test_crafted_stranded_batch_counts_as_dropped():
    """Both replicas crash for good before the only batch dispatches:
    its requests never complete — latency inf, attainment/goodput 0."""
    nodes = _fleet((1.0, 1.0))
    faults = FaultTrace((NodeCrash(0, 0.5), NodeCrash(1, 0.6)))
    sc = ServingScenario(nodes, window=1.0, mode="even", slo=5.0,
                         faults=faults)
    rep = sc.run(np.array([0.2, 0.7]))
    assert rep.n_completed == 0
    assert np.all(np.isinf(rep.latencies))
    assert rep.attainment == 0.0 and rep.goodput == 0.0


def test_crafted_mask_keeps_forbidden_replica_idle():
    """Class 1 may only use n1.  The unmasked class-0 sub-batch (ranked
    first) takes the whole fleet and finishes at t=2; from then on BOTH
    nodes are free, yet the masked sub-batch holds n1 alone — n0 idles
    to the end because the compatibility mask prunes the grant."""
    nodes = _fleet((1.0, 1.0))
    sc = ServingScenario(nodes, window=1.0, mode="even",
                         model=RequestModel(classes=2),
                         mask={1: ["n1"]})
    times = np.array([0.1, 0.2])
    works = np.array([2.0, 2.0])
    klass = np.array([0, 1])
    jobs, groups = sc.build_jobs(times, works, klass, 2.0)
    assert len(jobs) == 2
    masked = [j for j in jobs if j.allowed is not None]
    assert len(masked) == 1 and masked[0].allowed == frozenset({"n1"})
    res = ResidentCalendar(nodes).run(jobs)
    open_out = res.outcomes[[j.name for j in jobs
                             if j.allowed is None][0]]
    masked_out = res.outcomes[masked[0].name]
    assert open_out.planned[-1] == {"n0": _approx(1.0),
                                    "n1": _approx(1.0)}
    assert open_out.completion == _approx(2.0)
    assert masked_out.admitted_at == _approx(2.0)
    assert masked_out.planned[-1] == {"n1": _approx(2.0)}
    assert masked_out.completion == _approx(4.0)


# --------------------------------------------------------------------------
# report reductions
# --------------------------------------------------------------------------

def test_report_percentiles_and_goodput():
    lat = np.array([1.0, 2.0, 3.0, np.inf])
    rep = ServingReport(lat, np.zeros(4), slo=2.5, horizon=10.0,
                        result=type("R", (), {"makespan": 8.0})())
    assert rep.n_completed == 3
    assert rep.p50 == _approx(2.5)
    assert rep.attainment == _approx(0.5)
    assert rep.goodput == _approx(0.2)    # 2 attained over max(10, 8) s
    summary = rep.summary()
    assert summary["n_requests"] == 4 and summary["attainment"] == 0.5


# --------------------------------------------------------------------------
# the bench ordering: HeMT beats HomT on tail latency and SLOs
# --------------------------------------------------------------------------

def test_bench_serving_orderings():
    """The gated `serving` section's tentpole claim: capacity-
    proportional batching beats even batching on p99 latency and SLO
    attainment, with the clairvoyant oracle no worse than the adaptive
    estimator (up to noise) on the flat fleet."""
    from benchmarks.bench_serving import scenario_metrics

    m = scenario_metrics()
    for variant in ("flat", "burstable", "preempt"):
        assert m[f"p99_{variant}_hemt"] < m[f"p99_{variant}_even"], variant
        assert m[f"att_{variant}_hemt"] >= m[f"att_{variant}_even"], variant
    assert m["p99_flat_oracle"] <= m["p99_flat_hemt"] + 1e-6
    assert m["att_flat_hemt"] == 1.0


# --------------------------------------------------------------------------
# run_round: the closed-loop dispatch driver
# --------------------------------------------------------------------------

def test_run_round_observe_loop_converges():
    nodes = _fleet((2.0, 1.0), overhead=0.0)
    b = HeMTBatcher([nd.name for nd in nodes], alpha=0.0)
    shares0, _ = run_round(b, nodes, 12, decode_work=1.0)
    assert shares0 == {"n0": 6, "n1": 6}          # cold: even
    shares1, sched = run_round(b, nodes, 12, decode_work=1.0)
    assert shares1 == {"n0": 8, "n1": 4}          # learned 2:1
    assert sched.completion == _approx(4.0)       # both finish together


def test_run_round_speculation_hedges_straggler():
    """A replica that collapses mid-round-1: the batcher flags it as
    straggling and a speculative decode copy on an idle finished replica
    caps round 2's makespan below the unhedged run."""
    nodes = [SimNode("fast", [(0.0, 2.0)], 0.0),
             SimNode("ok", [(0.0, 2.0)], 0.0),
             SimNode("slow", [(0.0, 2.0), (1.0, 0.1)], 0.0)]
    # min_share keeps the straggler fed (paper §5.1's averaging argument
    # needs every replica observed) — which is exactly when hedging pays
    b = HeMTBatcher([nd.name for nd in nodes], alpha=0.0, min_share=1)
    run_round(b, nodes, 12)
    assert b.straggling(factor=2.0) == ["slow"]
    _, plain = run_round(b, nodes, 12, start_time=30.0)
    b2 = HeMTBatcher([nd.name for nd in nodes], alpha=0.0, min_share=1)
    run_round(b2, nodes, 12)
    _, hedged = run_round(
        b2, nodes, 12, start_time=30.0,
        speculation=SpeculativeCopies(quantile=0.75, factor=1.5))
    assert hedged.completion < plain.completion


def test_run_round_validation():
    nodes = _fleet((1.0,))
    b = HeMTBatcher(["other"])
    with pytest.raises(ValueError):
        run_round(b, nodes, 4)
    b2 = HeMTBatcher(["n0"])
    with pytest.raises(ValueError):
        run_round(b2, nodes, -1)
