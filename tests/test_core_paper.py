"""The paper's analytical objects: Claims 1-2, partitioners, estimators,
token-bucket capacity — unit + hypothesis property tests."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.capacity import BurstableNode, burstable_split
from repro.core.estimators import (
    ARSpeedEstimator, FudgeFactorLearner, normalized, synchronization_delay,
)
from repro.core.hdfs_model import overlap_pmf, p_diff_block, p_same_block
from repro.core.partitioner import (
    even_split, hemt_split_floats, makespan, optimal_makespan, proportional_split,
)
from repro.core.straggler import claim1_bound, verify_claim1

speeds_st = st.lists(st.floats(0.1, 10.0), min_size=2, max_size=6)


# --------------------------------------------------------------------------
# Claim 1
# --------------------------------------------------------------------------

@given(speeds=speeds_st,
       n_tasks=st.integers(2, 60),
       total=st.floats(10.0, 1000.0))
def test_claim1_idle_bound_holds(speeds, n_tasks, total):
    idle, bound, ok = verify_claim1(total, n_tasks, speeds)
    assert ok, (idle, bound)


@given(speeds=speeds_st)
def test_claim1_bound_shrinks_with_task_count(speeds):
    b_few = claim1_bound(100.0, 4, speeds)
    b_many = claim1_bound(100.0, 64, speeds)
    assert b_many < b_few


def test_claim1_exact_example():
    # 2 nodes at speeds 1.0/0.4; 20 equal tasks of 5s-at-speed-1 each
    idle, bound, ok = verify_claim1(100.0, 20, [1.0, 0.4])
    assert ok
    assert bound == pytest.approx(5.0 / 0.4)


# --------------------------------------------------------------------------
# Claim 2 (storage contention model)
# --------------------------------------------------------------------------

@given(n=st.integers(1, 30), r=st.integers(1, 30))
def test_claim2_p1_ge_p2(n, r):
    if r > n:
        return
    p1, p2 = p_same_block(r), p_diff_block(n, r)
    assert p1 >= p2 - 1e-12
    if r == n:
        assert p1 == pytest.approx(p2)


@given(n=st.integers(2, 20), r=st.integers(1, 20))
def test_overlap_pmf_sums_to_one(n, r):
    if r > n:
        return
    total = sum(overlap_pmf(n, r, v) for v in range(0, r + 1))
    assert total == pytest.approx(1.0)


def test_paper_fig4_values():
    # r=2: p1 = 0.5 for all n; p2 < p1 for n > 2
    assert p_same_block(2) == 0.5
    assert p_diff_block(4, 2) == pytest.approx(0.25)
    assert p_diff_block(2, 2) == pytest.approx(0.5)


# --------------------------------------------------------------------------
# partitioners
# --------------------------------------------------------------------------

@given(total=st.integers(1, 10_000), n=st.integers(1, 32))
def test_even_split_sums_and_balance(total, n):
    s = even_split(total, n)
    assert sum(s) == total
    assert max(s) - min(s) <= 1


@given(total=st.integers(0, 5_000), weights=speeds_st)
def test_proportional_split_sums_and_error(total, weights):
    s = proportional_split(total, weights)
    assert sum(s) == total
    assert all(x >= 0 for x in s)
    # largest-remainder: within 1 unit of ideal per part
    ideal = [w * total for w in normalized(weights)]
    assert all(abs(si - ii) <= 1.0 + 1e-9 for si, ii in zip(s, ideal))


@given(weights=speeds_st, total=st.integers(64, 512))
def test_proportional_beats_even_makespan(weights, total):
    """HeMT's whole point: the skewed split's makespan <= the even one's."""
    s_h = proportional_split(total, weights)
    s_e = even_split(total, len(weights))
    assert makespan(s_h, weights) <= makespan(s_e, weights) + 1.0 / min(weights)


@given(weights=speeds_st)
def test_hemt_floats_achieve_optimal(weights):
    split = hemt_split_floats(100.0, weights)
    assert makespan(split, weights) == pytest.approx(
        optimal_makespan(100.0, weights))


def test_min_share_repair():
    assert proportional_split(8, [1.0, 0.4], min_share=1) == [6, 2]
    s = proportional_split(10, [100.0, 1.0, 1.0], min_share=1)
    assert sum(s) == 10 and min(s) >= 1


# --------------------------------------------------------------------------
# estimators (§5.1)
# --------------------------------------------------------------------------

def test_ar1_update_rule():
    est = ARSpeedEstimator(alpha=0.5)
    est.observe("a", 10.0, 2.0)          # first obs: v = d/t = 5
    assert est.speed("a") == pytest.approx(5.0)
    est.observe("a", 10.0, 10.0)         # sample 1.0 -> 0.5*1 + 0.5*5 = 3
    assert est.speed("a") == pytest.approx(3.0)


def test_cold_start_rules():
    for rule, expect in (("mean", 3.0), ("min", 2.0), ("max", 4.0)):
        est = ARSpeedEstimator(alpha=0.0, cold_start=rule)
        est.observe("a", 4.0, 1.0)
        est.observe("b", 2.0, 1.0)
        assert est.speeds(["a", "b", "new"])[2] == pytest.approx(expect)


def test_cold_start_no_observations_defaults_to_one():
    est = ARSpeedEstimator()
    assert est.speeds(["x", "y"]) == [1.0, 1.0]


def test_fudge_factor_learning():
    # paper: advertised 0.4, probes reveal 0.32
    f = FudgeFactorLearner(advertised=0.4, smoothing=1.0)
    assert f.effective == 0.4
    f.probe(fast_rate=1.0, slow_rate=0.32)
    assert f.effective == pytest.approx(0.32)


@given(finish=st.lists(st.floats(0, 100), min_size=1, max_size=8))
def test_sync_delay_nonnegative(finish):
    assert synchronization_delay(finish) >= 0


# --------------------------------------------------------------------------
# token-bucket capacity (§6.2)
# --------------------------------------------------------------------------

def test_paper_worked_example_w10():
    # t2.small: 4 credits, rho=0.2 -> W(10) = 6
    n = BurstableNode(credits=4, baseline=0.2)
    assert n.burst_time == pytest.approx(5.0)
    assert n.work_by(10.0) == pytest.approx(6.0)


def test_paper_worked_example_three_nodes():
    nodes = [BurstableNode(c, 0.2) for c in (4, 8, 12)]
    shares, t = burstable_split(nodes, 20.0)
    assert t == pytest.approx(80.0 / 11.0)
    assert np.allclose(shares, [60 / 11, 80 / 11, 80 / 11])
    # shares proportional to 3:4:4
    assert shares[1] == pytest.approx(shares[2])
    assert shares[0] / shares[1] == pytest.approx(3.0 / 4.0)


@given(credits=st.lists(st.floats(0, 30), min_size=1, max_size=5),
       rho=st.floats(0.05, 1.0), work=st.floats(0.1, 200.0))
def test_burstable_split_consistent(credits, rho, work):
    nodes = [BurstableNode(c, rho) for c in credits]
    shares, t = burstable_split(nodes, work)
    assert sum(shares) == pytest.approx(work, rel=1e-6)
    # every node finishes its share at exactly t
    for n, s in zip(nodes, shares):
        assert n.time_for(s) == pytest.approx(t, rel=1e-6, abs=1e-9)


@given(credits=st.floats(0, 20), rho=st.floats(0.05, 1.0),
       t=st.floats(0, 50))
def test_work_time_inverses(credits, rho, t):
    n = BurstableNode(credits, rho)
    w = n.work_by(t)
    assert n.time_for(w) == pytest.approx(t, abs=1e-6) or w == 0
