"""hemt-lint (repro.analysis): per-rule fixture snippets, waiver
semantics, the CLI, and the repo self-check gate (ISSUE 10).

Each rule gets positive (flagged), negative (clean), and waiver cases as
in-memory fixture files; the virtual path drives rule scoping exactly as
it does on disk.  The self-check test at the bottom is the tier-1 gate:
the committed tree must lint clean.
"""
import json
import textwrap

from repro.analysis import (Finding, Rule, all_rules, get_rule,
                            lint_source, parse_waivers, self_check)
from repro.analysis.lint import lint_paths, main

CORE = "src/repro/core/fixture.py"
ENGINE = "src/repro/core/engine.py"
BATCHED = "src/repro/core/batched.py"
KERNEL = "src/repro/kernels/fixture.py"
RUNTIME = "src/repro/runtime/fixture.py"
MODELS = "src/repro/models/fixture.py"


def codes(source, path=CORE, select=None):
    src = textwrap.dedent(source)
    return [f.code for f in lint_source(src, path, select).findings]


def run(source, path=CORE, select=None):
    return lint_source(textwrap.dedent(source), path, select)


# ---------------------------------------------------------------------------
# registry / protocol
# ---------------------------------------------------------------------------

def test_registry_has_the_six_rules_sorted():
    got = [r.code for r in all_rules()]
    assert got == sorted(got)
    assert {"HL001", "HL002", "HL003", "HL004", "HL005",
            "HL006"} <= set(got)


def test_rules_satisfy_the_protocol():
    for rule in all_rules():
        assert isinstance(rule, Rule)
        assert rule.description
        assert get_rule(rule.code) is rule


# ---------------------------------------------------------------------------
# HL001 frozen-spec
# ---------------------------------------------------------------------------

UNFROZEN_SPEC = """
    from dataclasses import dataclass

    @dataclass
    class PullSpec:
        n_tasks: int = 0
"""

def test_hl001_unfrozen_root_spec_flagged():
    assert codes(UNFROZEN_SPEC) == ["HL001"]


def test_hl001_frozen_spec_clean():
    assert codes("""
        from dataclasses import dataclass
        from typing import Tuple

        @dataclass(frozen=True)
        class PullSpec:
            works: Tuple[float, ...] = ()
    """) == []


def test_hl001_unhashable_field_flagged():
    out = run("""
        from dataclasses import dataclass, field
        from typing import List
        import numpy as np

        @dataclass(frozen=True)
        class StaticSpec:
            works: List[float] = field(default_factory=list)
            grid: np.ndarray = None
    """)
    assert [f.code for f in out.findings] == ["HL001", "HL001"]
    assert "works" in out.findings[0].message
    assert "grid" in out.findings[1].message


def test_hl001_suffix_convention_and_closure():
    # *Trace matches by suffix; Inner is pulled in via the field
    # annotation closure and must itself be frozen
    out = run("""
        from dataclasses import dataclass
        from typing import Optional

        @dataclass
        class Inner:
            x: int = 0

        @dataclass(frozen=True)
        class ReplayTrace:
            inner: Optional[Inner] = None
    """)
    assert [f.code for f in out.findings] == ["HL001"]
    assert "Inner" in out.findings[0].message


def test_hl001_non_spec_dataclass_and_tests_exempt():
    mutable_report = """
        from dataclasses import dataclass
        from typing import List

        @dataclass
        class StageReport:
            rows: List[float] = None
    """
    assert codes(mutable_report) == []                  # not a spec name
    assert codes(UNFROZEN_SPEC, "tests/test_x.py") == []  # tests exempt


# ---------------------------------------------------------------------------
# HL002 seeded-rng
# ---------------------------------------------------------------------------

def test_hl002_legacy_and_stdlib_and_unseeded_flagged():
    out = run("""
        import random
        import numpy as np
        from numpy.random import seed

        def sample(xs):
            np.random.seed(0)
            random.shuffle(xs)
            rng = np.random.default_rng()
            return rng
    """)
    got = [f.code for f in out.findings]
    assert got == ["HL002"] * 4


def test_hl002_seeded_generator_clean():
    assert codes("""
        import numpy as np

        def _rng(seed: int) -> np.random.Generator:
            return np.random.default_rng(seed)

        def jitter(seed, n):
            return np.random.default_rng(int(seed)).normal(size=n)
    """) == []


def test_hl002_scope_is_core_runtime_workloads():
    legacy = """
        import numpy as np
        def f():
            return np.random.rand(3)
    """
    assert codes(legacy, RUNTIME) == ["HL002"]
    assert codes(legacy, "src/repro/workloads/fixture.py") == ["HL002"]
    assert codes(legacy, MODELS) == []      # models/ draws via jax.random keys


def test_hl002_jax_random_exempt():
    assert codes("""
        import jax

        def init(key):
            return jax.random.split(key, 2)
    """) == []


# ---------------------------------------------------------------------------
# HL003 wall-clock
# ---------------------------------------------------------------------------

def test_hl003_time_datetime_flagged():
    out = run("""
        import time
        import datetime
        from time import perf_counter
        from datetime import datetime as dt

        def stamp():
            return (time.time(), perf_counter(), dt.now(),
                    datetime.datetime.utcnow())
    """)
    # perf_counter is flagged at its from-import; the other three at use
    assert [f.code for f in out.findings] == ["HL003"] * 4


def test_hl003_sim_clock_and_benchmarks_exempt():
    assert codes("""
        def advance(clock: float, dt: float) -> float:
            return clock + dt
    """) == []
    wall = """
        import time
        def bench():
            return time.time()
    """
    assert codes(wall, "benchmarks/bench_x.py") == []
    assert codes(wall, "tests/test_x.py") == []


# ---------------------------------------------------------------------------
# HL004 float-eq
# ---------------------------------------------------------------------------

def test_hl004_float_literal_and_annotation_flagged():
    out = run("""
        def solve(a: float, b, w):
            if a == b:                 # annotated param
                return 1
            return (w != 0.0)          # float literal
    """)
    assert [f.code for f in out.findings] == ["HL004", "HL004"]


def test_hl004_dataclass_field_attr_flagged():
    assert codes("""
        from dataclasses import dataclass

        @dataclass(frozen=True)
        class TaskSpec:
            weight: float = 0.0

        def route(t, u):
            return t.weight == u.weight
    """) == ["HL004"]
    # engine spec float fields are known across files
    assert codes("""
        def route(t, m):
            return t.io_mb != m
    """, ENGINE) == ["HL004"]


def test_hl004_tolerant_and_int_compares_clean():
    assert codes("""
        EPS = 1e-9

        def close(a: float, b: float) -> bool:
            return abs(a - b) <= EPS

        def count_eq(n: int) -> bool:
            return n == 0
    """) == []


def test_hl004_scope_is_core_only():
    src = """
        def f(a: float):
            return a == 0.5
    """
    assert codes(src, RUNTIME) == []
    assert codes(src, CORE) == ["HL004"]


# ---------------------------------------------------------------------------
# HL005 tracer-safety
# ---------------------------------------------------------------------------

def test_hl005_python_if_on_traced_value_flagged():
    out = run("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """, KERNEL)
    assert [f.code for f in out.findings] == ["HL005"]
    assert "if" in out.findings[0].message


def test_hl005_item_cast_and_data_dep_shapes_flagged():
    out = run("""
        import jax
        import jax.numpy as jnp

        def outer(xs):
            def step(carry, x):
                v = float(x)                 # concretizing cast
                idx = jnp.nonzero(carry)     # data-dependent shape
                hit = jnp.where(carry > 0)   # one-arg where
                return carry, x.item()       # .item()
            return jax.lax.scan(step, 0.0, xs)
    """, BATCHED)
    assert sorted(f.code for f in out.findings) == ["HL005"] * 4


def test_hl005_static_args_and_untraced_clean():
    assert codes("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def f(x, mode):
            if mode == "fast":           # static_argnames -> python value
                return x * 2
            return x

        def kernel(ref, *, n_chunks: int):
            if n_chunks > 1:             # kw-only params are static
                return ref
            return ref

        def plain(x):
            if x > 0:                    # never traced: no entry point
                return x
            return -x
    """, KERNEL) == []


def test_hl005_partial_bound_kernel_traced():
    # the ssd_scan idiom: partial(kernel, ...) handed to pallas_call
    assert codes("""
        import functools
        from jax.experimental import pallas as pl

        def _kernel(x_ref, o_ref):
            if x_ref[0] > 0:
                o_ref[0] = 1.0

        def launch(x):
            k = functools.partial(_kernel)
            return pl.pallas_call(k, grid=(1,))(x)
    """, KERNEL) == ["HL005"]


def test_hl005_scope_is_kernels_and_batched():
    src = """
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """
    assert codes(src, CORE) == []          # core/fixture.py: out of scope
    assert codes(src, BATCHED) == ["HL005"]


# ---------------------------------------------------------------------------
# HL006 arg-mutation
# ---------------------------------------------------------------------------

def test_hl006_param_stores_flagged():
    out = run("""
        import numpy as np

        def _closed_form_static(speeds, works):
            works[0] = 0.0
            speeds += 1.0
            works.sort()
            return works

        def batched_closed_pull(works):
            wk = np.asarray(works)       # asarray aliases, taint survives
            wk[0] = 1.0
            return wk
    """, ENGINE)
    assert [f.code for f in out.findings] == ["HL006"] * 4


def test_hl006_copy_and_locals_clean():
    assert codes("""
        import numpy as np

        def _closed_form_static(speeds, works):
            works = np.array(works)      # fresh copy: taint cleared
            works[0] = 0.0
            counts = np.zeros(3)
            counts[1] += 1               # local, never parameter storage
            return works, counts

        def helper_not_a_solver(xs):
            xs[0] = 1                    # outside the solver prefixes
            return xs
    """, ENGINE) == []


def test_hl006_scope_is_engine_and_batched():
    src = """
        def _closed_form_static(works):
            works[0] = 1.0
            return works
    """
    assert codes(src, BATCHED) == ["HL006"]
    assert codes(src, CORE) == []          # other core modules: out of scope


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------

def test_waiver_inline_and_standalone():
    out = run("""
        def solve(a: float, b: float):
            x = a == b  # hemt-lint: disable=HL004  exact sentinel
            # hemt-lint: disable=HL004  covers the next line
            y = a != b
            return x, y
    """)
    assert out.findings == []
    assert len(out.suppressed) == 2
    assert out.unused_waivers == []


def test_waiver_wrong_code_does_not_suppress():
    out = run("""
        def solve(a: float, b: float):
            return a == b  # hemt-lint: disable=HL001
    """)
    assert [f.code for f in out.findings] == ["HL004"]
    assert out.unused_waivers  # and the HL001 waiver is reported unused


def test_unused_waiver_reported_and_strings_ignored():
    out = run("""
        def clean():
            return 0  # hemt-lint: disable=HL004
    """)
    assert out.findings == []
    assert [(ln, code) for _, ln, code in out.unused_waivers] \
        == [(3, "HL004")]
    assert out.exit_code == 1      # stale waivers fail the gate too
    # a waiver spelled inside a string is documentation, not a waiver
    assert parse_waivers('msg = "# hemt-lint: disable=HL004"\n') == {}


def test_select_limits_waiver_policing():
    # --select HL002 must not call HL004 waivers unused
    out = run("""
        def solve(a: float, b: float):
            return a == b  # hemt-lint: disable=HL004  exactness note
    """, select=["HL002"])
    assert out.findings == [] and out.unused_waivers == []


def test_syntax_error_is_a_finding():
    out = lint_source("def broken(:\n", CORE)
    assert [f.code for f in out.findings] == ["HL000"]
    assert out.exit_code == 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _write_fixture(tmp_path, rel, source):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source).lstrip("\n"), encoding="utf-8")
    return p


def test_cli_text_and_exit_codes(tmp_path, capsys):
    _write_fixture(tmp_path, "src/repro/core/bad.py", """
        import numpy as np
        def f():
            return np.random.rand(3)
    """)
    assert main([str(tmp_path / "src")]) == 1
    text = capsys.readouterr().out
    assert "bad.py:3:" in text and "HL002" in text
    assert "1 finding(s)" in text

    _write_fixture(tmp_path, "src/repro/core/bad.py", "x = 1\n")
    assert main([str(tmp_path / "src")]) == 0


def test_cli_json_report_and_output_artifact(tmp_path, capsys):
    _write_fixture(tmp_path, "src/repro/core/bad.py", """
        import time
        def f():
            return time.perf_counter()
    """)
    report_path = tmp_path / "hemt-lint.json"
    rc = main(["--format=json", "--output", str(report_path),
               str(tmp_path / "src")])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["version"] == 1
    assert payload["counts"] == {"HL003": 1}
    assert payload["findings"][0]["line"] == 3
    # the artifact the CI job uploads is byte-identical to stdout
    assert json.loads(report_path.read_text()) == payload


def test_cli_list_rules_and_select(tmp_path, capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in all_rules():
        assert rule.code in out

    _write_fixture(tmp_path, "src/repro/core/bad.py", """
        import time
        def f(a: float):
            return a == 0.0, time.time()
    """)
    assert main(["--select", "HL004", str(tmp_path / "src")]) == 1
    assert "HL003" not in capsys.readouterr().out


def test_pycache_skipped(tmp_path):
    _write_fixture(tmp_path, "src/repro/core/__pycache__/junk.py",
                   "import random\nrandom.random()\n")
    assert lint_paths([str(tmp_path / "src")]).files_checked == 0


# ---------------------------------------------------------------------------
# the repo self-check gate (the CI hemt-lint job runs the same thing)
# ---------------------------------------------------------------------------

def test_repo_tree_is_clean():
    report = self_check()
    assert report.files_checked > 50       # really walked src/
    msgs = [f.format() for f in report.findings]
    assert msgs == [], "hemt-lint violations in src/:\n" + "\n".join(msgs)
    assert report.unused_waivers == [], report.unused_waivers
    assert report.exit_code == 0


def test_repo_waivers_are_documented():
    # every committed waiver carries its justification in-tree; if this
    # count drifts, update it alongside the new waiver + justification
    report = self_check()
    assert len(report.suppressed) == 8
    codes_used = {f.code for f in report.suppressed}
    assert codes_used == {"HL003", "HL004"}


def test_finding_is_ordered_and_formattable():
    a = Finding("a.py", 1, 0, "HL001", "x")
    b = Finding("a.py", 2, 0, "HL001", "x")
    assert a < b
    assert a.format() == "a.py:1:0: HL001 x"
    assert a.to_json()["code"] == "HL001"
