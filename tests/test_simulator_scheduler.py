"""Discrete-event simulator + job-level schedulers (paper §5-§7)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.capacity import BurstableNode
from repro.core.scheduler import (
    AdaptiveHeMTScheduler, BurstableHeMTScheduler, HomTScheduler,
    MultiStageJob, ProvisionedHeMTScheduler,
)
from repro.core.simulator import (
    SimNode, SimTask, run_pull_stage, run_static_stage,
)
from repro.core.skewed_hash import (
    bucket_of, expected_shares, integer_capacities, skewed_shuffle_counts,
)
from repro.core.straggler import detect_stragglers, rebalance_after_loss


# --------------------------------------------------------------------------
# simulator mechanics
# --------------------------------------------------------------------------

def test_single_node_constant_speed():
    n = SimNode.constant("a", 2.0)
    res = run_pull_stage([n], [SimTask(10.0, task_id=0)])
    assert res.completion == pytest.approx(5.0)


def test_overhead_added_per_task():
    n = SimNode.constant("a", 1.0, overhead=0.5)
    res = run_pull_stage([n], [SimTask(1.0, task_id=i) for i in range(4)])
    assert res.completion == pytest.approx(4 * 1.5)


def test_profile_change_mid_task():
    # speed 1.0 for 5s then 0.5: 10 units takes 5 + 10 = 15s
    n = SimNode("a", [(0.0, 1.0), (5.0, 0.5)])
    res = run_static_stage([n], [[SimTask(10.0, task_id=0)]])
    assert res.completion == pytest.approx(15.0)


def test_pull_faster_node_takes_more():
    nodes = [SimNode.constant("fast", 1.0), SimNode.constant("slow", 0.25)]
    tasks = [SimTask(1.0, task_id=i) for i in range(20)]
    res = run_pull_stage(nodes, tasks)
    counts = {"fast": 0, "slow": 0}
    for r in res.records:
        counts[r.node] += 1
    assert counts["fast"] > 3 * counts["slow"]


def test_static_stage_respects_assignment():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    res = run_static_stage(nodes, [[SimTask(3.0, task_id=0)],
                                   [SimTask(1.0, task_id=1)]])
    assert res.node_finish["a"] == pytest.approx(3.0)
    assert res.node_finish["b"] == pytest.approx(1.0)
    assert res.idle_time == pytest.approx(2.0)


def test_idle_time_counts_only_nodes_that_ran():
    """Pull mode with fewer tasks than nodes: a node that never receives a
    task sits at start_time and must not inflate the Claim-1 idle metric."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0),
             SimNode.constant("c", 1.0)]
    res = run_pull_stage(nodes, [SimTask(6.0, task_id=0)])
    assert res.completion == pytest.approx(6.0)
    assert res.idle_time == pytest.approx(0.0)


def test_uplink_sharing_slows_coreaders():
    # two readers on one datanode share bandwidth -> 2x io time
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(2)]
    tasks = [SimTask(0.1, io_mb=100.0, datanode=0, task_id=i)
             for i in range(2)]
    res = run_pull_stage(nodes, tasks, uplink_bw=100.0)
    assert res.completion == pytest.approx(2.0, rel=0.05)
    tasks2 = [SimTask(0.1, io_mb=100.0, datanode=i, task_id=i)
              for i in range(2)]
    res2 = run_pull_stage(nodes, tasks2, uplink_bw=100.0)
    assert res2.completion == pytest.approx(1.0, rel=0.05)


# --------------------------------------------------------------------------
# OA-HeMT (§5): Fig 7 / Fig 8 behaviours
# --------------------------------------------------------------------------

def test_oahemt_learns_static_shares_in_two_jobs():
    """Paper Fig 8: 1.0/0.4 provisioning learned after ~2 trials."""
    sched = AdaptiveHeMTScheduler(["a", "b"], alpha=0.0)
    nodes = lambda k: [SimNode.constant("a", 1.0), SimNode.constant("b", 0.4)]
    hist = sched.run_simulated_sequence(nodes, n_jobs=5, total_work=140.0)
    # job 0 is the even split (paper's k=1 rule)
    assert hist[0].split == pytest.approx([70.0, 70.0])
    opt = 140.0 / 1.4
    # by job 2 the completion time is within 2% of optimal
    assert hist[2].completion == pytest.approx(opt, rel=0.02)
    assert hist[4].idle_time < 1e-6


def test_oahemt_adapts_to_interference():
    """Paper Fig 7: interference injected mid-sequence; re-balances."""
    def nodes(k):
        # node b slows to 0.3 from job 10 onward (interfering process)
        vb = 1.0 if k < 10 else 0.3
        return [SimNode.constant("a", 1.0), SimNode.constant("b", vb)]
    sched = AdaptiveHeMTScheduler(["a", "b"], alpha=0.0)
    hist = sched.run_simulated_sequence(nodes, n_jobs=20, total_work=130.0)
    # completion spikes at job 10 then recovers within 2 jobs
    assert hist[10].completion > hist[9].completion * 1.3
    assert hist[12].completion == pytest.approx(100.0, rel=0.03)


def test_provisioned_with_fudge_matches_observed():
    from repro.core.estimators import FudgeFactorLearner
    fudge = FudgeFactorLearner(advertised=0.4, smoothing=1.0)
    fudge.probe(1.0, 0.32)
    sched = ProvisionedHeMTScheduler([1.0, 0.4], fudge=fudge, fudge_index=1)
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 0.32)]
    res = sched.run_simulated(nodes, 132.0)
    assert res.idle_time < 1e-6          # perfect balance with true ratio


def test_burstable_scheduler_finishes_simultaneously():
    bnodes = [BurstableNode(4, 0.2), BurstableNode(8, 0.2),
              BurstableNode(12, 0.2)]
    sched = BurstableHeMTScheduler(bnodes)
    res = sched.run_simulated(20.0)
    assert res.idle_time < 1e-6
    assert res.completion == pytest.approx(80 / 11)


def test_homt_beats_bad_static_even_under_heterogeneity():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 0.4)]
    homt = HomTScheduler(n_tasks=16).run_simulated(nodes, 140.0)
    even = run_static_stage(nodes, [[SimTask(70.0, task_id=0)],
                                    [SimTask(70.0, task_id=1)]])
    assert homt.completion < even.completion


# --------------------------------------------------------------------------
# multi-stage (§7) + Algorithm 1
# --------------------------------------------------------------------------

@given(weights=st.lists(st.floats(0.1, 5.0), min_size=2, max_size=5),
       n_records=st.integers(1000, 20_000))
def test_algorithm1_shares_proportional(weights, n_records):
    caps = integer_capacities(weights, resolution=1 << 14)
    counts = skewed_shuffle_counts(n_records, caps, seed=1)
    share = counts / counts.sum()
    expect = np.asarray(expected_shares(caps))
    assert np.all(np.abs(share - expect) < 0.05)


def test_algorithm1_identity_hash_ranges():
    caps = np.asarray([3, 1])
    # hash mod 4: 0,1,2 -> bucket 0; 3 -> bucket 1
    b = bucket_of(np.arange(8), caps)
    assert list(b) == [0, 0, 0, 1, 0, 0, 0, 1]


def test_multistage_hemt_beats_homt_with_overhead():
    """Paper Fig 18 regime: short stages, per-task overhead."""
    nodes = [SimNode.constant("a", 1.0, overhead=0.2),
             SimNode.constant("b", 0.4, overhead=0.2)]
    job = MultiStageJob(stage_works=[14.0] * 10)
    t_hemt, _ = job.run(nodes, weights=[1.0, 0.4])
    t_homt, _ = job.run(nodes, weights=None, n_tasks_per_stage=16)
    assert t_hemt < t_homt


# --------------------------------------------------------------------------
# straggler utilities
# --------------------------------------------------------------------------

def test_detect_stragglers():
    reports = detect_stragglers([1.0, 1.05, 0.95, 0.2], z_threshold=-1.5)
    assert len(reports) == 1 and reports[0].index == 3


def test_rebalance_after_loss():
    w = rebalance_after_loss([0.5, 0.3, 0.2], lost=[1])
    # weights map back to the surviving original indices
    assert sorted(w) == [0, 2]
    assert w[0] == pytest.approx(0.5 / 0.7)
    assert w[2] == pytest.approx(0.2 / 0.7)
    with pytest.raises(ValueError):
        rebalance_after_loss([0.5, 0.5], lost=[0, 1])
