"""Batched many-solve planner vs. the scalar closed forms (tentpole suite).

Randomized differential suites pin the three array-form solvers of
``repro.core.batched`` — ``closed-static``, ``closed-pull`` (uniform) and
``closed-pull-hetero`` — row by row against scalar
:func:`repro.core.engine.run_job` at 1e-9: makespan, idle, per-node finish
offsets and executed work, and task counts *exactly* (the batched argmin
must reproduce the heap's ``(end, node)`` tie-break, not just its float
totals).  Also covered: cross-batch de-dup equivalence (the batched
demotion of the solve LRU), the jax scan twin under x64, the Monte-Carlo
``plan_capacity`` planner, and the lazy columnar ``StageResult`` the
refactor introduced underneath the engine's closed forms.
"""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.batched import (
    BatchResult, batched_closed_pull, batched_closed_pull_hetero,
    batched_closed_static, dedup_rows, plan_capacity, pull_scan,
)
from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear,
)
from repro.core.simulator import SimNode, StageColumns, TaskRecord

REL = ABS = 1e-9
OVERHEAD = 0.01


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


def _nodes(speeds, overhead=OVERHEAD):
    return [SimNode.constant(f"n{i}", float(s), overhead)
            for i, s in enumerate(speeds)]


def _pin_row(res: BatchResult, b: int, speeds, spec, overhead=OVERHEAD):
    """One batched row vs. the scalar whole-job solve of the same stage."""
    run_job_cache_clear()
    nodes = _nodes(speeds, overhead)
    sched = run_job(nodes, [spec])
    summ = sched.stages[0]
    assert res.makespan[b] == _approx(sched.completion)
    assert res.idle[b] == _approx(summ.idle_time)
    for i, nd in enumerate(nodes):
        assert res.node_finish[b, i] == _approx(summ.node_finish[nd.name])
        assert res.executed[b, i] == _approx(summ.work[nd.name])
        assert res.counts[b, i] == summ.counts[nd.name]


# --------------------------------------------------------------------------
# randomized differential suites: batched vs. scalar closed forms at 1e-9
# --------------------------------------------------------------------------

@given(
    n=st.integers(min_value=1, max_value=5),
    work_vals=st.lists(st.floats(min_value=0.2, max_value=3.0),
                       min_size=2, max_size=10),
    overhead=st.floats(min_value=0.0, max_value=0.2),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_static_differential(n, work_vals, overhead, seed):
    B = 4
    rng = np.random.default_rng(seed)
    sp = rng.uniform(0.2, 3.0, (B, n))
    wk = rng.uniform(0.0, 4.0, (B, n))
    wk[0, :] = (work_vals * n)[:n]     # one row from the drawn values
    res = batched_closed_static(sp, wk, overhead)
    for b in range(B):
        _pin_row(res, b, sp[b], StaticSpec(works=tuple(wk[b])), overhead)


@given(
    n=st.integers(min_value=1, max_value=5),
    n_tasks=st.integers(min_value=1, max_value=40),
    task_work=st.floats(min_value=0.05, max_value=2.0),
    overhead=st.floats(min_value=0.0, max_value=0.2),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_pull_uniform_differential(n, n_tasks, task_work, overhead, seed):
    if overhead == 0.0 and task_work == 0.0:
        return      # zero-period grid is rejected by both paths
    B = 3
    sp = np.random.default_rng(seed).uniform(0.2, 3.0, (B, n))
    res = batched_closed_pull(sp, n_tasks, task_work, overhead)
    for b in range(B):
        _pin_row(res, b, sp[b],
                 PullSpec(n_tasks=n_tasks, task_work=task_work), overhead)


@given(
    n=st.integers(min_value=1, max_value=5),
    n_tasks=st.integers(min_value=0, max_value=40),
    overhead=st.floats(min_value=0.0, max_value=0.2),
    blocky=st.booleans(),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_pull_hetero_differential(n, n_tasks, overhead, blocky, seed):
    B = 3
    rng = np.random.default_rng(seed)
    sp = rng.uniform(0.2, 3.0, (B, n))
    if blocky:      # runs of equal sizes: the engine's run-length path
        wk = np.repeat(rng.uniform(0.1, 2.0, (B, max(n_tasks // 4, 1))),
                       4, axis=1)[:, :n_tasks]
    else:
        wk = rng.uniform(0.0, 3.0, (B, n_tasks))
    res = batched_closed_pull_hetero(sp, wk, overhead)
    for b in range(B):
        _pin_row(res, b, sp[b], PullSpec(works=tuple(wk[b])), overhead)


def test_pull_tie_break_matches_heap_exactly():
    """Equal speeds make every pull a tie: counts must still agree with
    the scalar heap's lowest-node-index resolution, node for node."""
    for speeds in ([1.0] * 4, [1.0, 1.0, 2.0, 2.0], [0.5, 0.5]):
        n_tasks = 23
        sp = np.tile(speeds, (2, 1))
        res = batched_closed_pull(sp, n_tasks, 0.7, OVERHEAD, dedup=False)
        run_job_cache_clear()
        nodes = _nodes(speeds)
        summ = run_job(nodes, [PullSpec(n_tasks=n_tasks,
                                        task_work=0.7)]).stages[0]
        for i, nd in enumerate(nodes):
            assert res.counts[0, i] == summ.counts[nd.name]
            assert res.node_finish[0, i] == _approx(summ.node_finish[nd.name])


def test_pull_scan_bitwise_matches_scalar_hetero():
    """The batched scan is the scalar scan, not merely close to it: on the
    same row, hetero finish times agree bitwise (== with no tolerance)."""
    rng = np.random.default_rng(5)
    sp = rng.uniform(0.2, 3.0, (1, 4))
    wk = rng.uniform(0.0, 3.0, (1, 50))
    res = batched_closed_pull_hetero(sp, wk, OVERHEAD, dedup=False)
    run_job_cache_clear()
    nodes = _nodes(sp[0])
    summ = run_job(nodes, [PullSpec(works=tuple(wk[0]))]).stages[0]
    for i, nd in enumerate(nodes):
        assert res.node_finish[0, i] == summ.node_finish[nd.name]


def test_empty_batches_and_zero_tasks():
    res = batched_closed_pull_hetero([[1.0, 2.0]], np.empty((1, 0)))
    assert res.makespan[0] == 0.0 and res.idle[0] == 0.0
    assert res.counts.sum() == 0
    res = batched_closed_pull([[1.0, 2.0]], 0, 1.0, OVERHEAD)
    assert res.makespan[0] == 0.0


def test_broadcasting_one_split_many_fleets():
    """One split vector scored against B sampled fleets (and one fleet
    against B work grids) broadcasts without materializing the stack."""
    sp = np.random.default_rng(0).uniform(0.5, 2.0, (6, 3))
    res = batched_closed_static(sp, np.array([3.0, 2.0, 1.0])[None, :])
    assert res.makespan.shape == (6,)
    grids = np.random.default_rng(1).uniform(0.1, 1.0, (5, 12))
    res = batched_closed_pull_hetero([1.0, 0.5, 0.25], grids, OVERHEAD)
    assert res.makespan.shape == (5,)


def test_validation_errors():
    with pytest.raises(ValueError):
        batched_closed_static([[0.0, 1.0]], [[1.0, 1.0]])
    with pytest.raises(ValueError):
        batched_closed_static([[1.0, 1.0]], [[-1.0, 1.0]])
    with pytest.raises(ValueError):
        batched_closed_pull([[1.0]], -1, 1.0)
    with pytest.raises(ValueError):
        batched_closed_pull_hetero([[1.0, 1.0]], [[1.0]], overheads=-0.1)
    with pytest.raises(ValueError):
        batched_closed_pull_hetero(np.ones((3, 2)), np.ones((2, 5)))


# --------------------------------------------------------------------------
# cross-batch de-dup (the solve LRU, demoted to one np pass per batch)
# --------------------------------------------------------------------------

def test_dedup_rows_first_occurrence():
    key = np.array([[1.0, 2.0], [3.0, 4.0], [1.0, 2.0], [5.0, 6.0],
                    [3.0, 4.0]])
    uniq, inverse = dedup_rows(key)
    assert uniq.tolist() == [0, 1, 3]
    assert inverse.tolist() == [0, 1, 0, 2, 1]
    assert np.array_equal(key[uniq][inverse], key)


@given(seed=st.integers(min_value=0, max_value=10_000))
def test_dedup_solves_match_full_batch_exactly(seed):
    """dedup=True must be invisible: bit-identical results to solving
    every row, on a batch built to contain duplicates."""
    rng = np.random.default_rng(seed)
    base_sp = rng.uniform(0.2, 3.0, (4, 3))
    base_wk = rng.uniform(0.0, 2.0, (4, 11))
    idx = rng.integers(0, 4, 13)
    sp, wk = base_sp[idx], base_wk[idx]
    a = batched_closed_pull_hetero(sp, wk, OVERHEAD, dedup=True)
    b = batched_closed_pull_hetero(sp, wk, OVERHEAD, dedup=False)
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    u = batched_closed_pull(sp, 9, 0.4, OVERHEAD, dedup=True)
    v = batched_closed_pull(sp, 9, 0.4, OVERHEAD, dedup=False)
    for x, y in zip(u, v):
        assert np.array_equal(x, y)


# --------------------------------------------------------------------------
# jax scan twin
# --------------------------------------------------------------------------

def test_pull_scan_jax_matches_numpy():
    jax = pytest.importorskip("jax")
    from repro.core.batched import pull_scan_jax
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        rng = np.random.default_rng(3)
        B, n, T = 7, 4, 29
        oh = np.full((B, n), OVERHEAD)
        sp = rng.uniform(0.2, 3.0, (B, n))
        wk = rng.uniform(0.0, 3.0, (B, T))
        ne, ct, ex = pull_scan(oh, sp, wk)
        jne, jct, jex = pull_scan_jax(oh, sp, wk)
        np.testing.assert_allclose(np.asarray(jne), ne, rtol=REL, atol=ABS)
        assert np.array_equal(np.asarray(jct), ct)
        np.testing.assert_allclose(np.asarray(jex), ex, rtol=REL, atol=ABS)
        # fewer tasks than nodes: unprimed nodes report 0 finish, 0 count
        ne, ct, _ = pull_scan(oh[:1, :], sp[:1, :], wk[:1, :2])
        jne, jct, _ = pull_scan_jax(oh[:1, :], sp[:1, :], wk[:1, :2])
        assert np.array_equal(np.asarray(jct), ct)
        np.testing.assert_allclose(np.asarray(jne), ne, rtol=REL, atol=ABS)
    finally:
        jax.config.update("jax_enable_x64", old)


# --------------------------------------------------------------------------
# plan_capacity: the Monte-Carlo planner on top
# --------------------------------------------------------------------------

def test_plan_capacity_deterministic_and_monotone():
    kw = dict(target=20.0, n_range=range(2, 9), samples=200, seed=11)
    a = plan_capacity([2.0, 1.0, 0.5], 60.0, **kw)
    b = plan_capacity([2.0, 1.0, 0.5], 60.0, **kw)
    assert a.chosen == b.chosen
    for n in a.quantiles:
        assert a.quantiles[n] == b.quantiles[n]
        assert np.array_equal(a.makespans[n], b.makespans[n])
    # cv=0 is deterministic: quantiles equal the closed-form solve and
    # fall monotonically with fleet size
    det = plan_capacity([1.0], 60.0, target=20.0, n_range=range(1, 7),
                        cv=0.0, samples=50, overhead=OVERHEAD)
    qs = [det.quantiles[n] for n in sorted(det.quantiles)]
    assert all(x >= y - ABS for x, y in zip(qs, qs[1:]))
    assert det.quantiles[3] == _approx(OVERHEAD + 60.0 / 3)
    assert det.chosen == min(n for n, q in det.quantiles.items()
                             if q <= 20.0)


def test_plan_capacity_cv0_differential_vs_run_job():
    """cv=0 collapses Monte-Carlo to the scalar closed forms: each mode's
    quantile must equal the matching run_job solve of the mean fleet."""
    pool, total, n = [2.0, 1.0, 0.5], 45.0, 5
    means = np.asarray(pool)[np.arange(n) % 3]
    rep = plan_capacity(pool, total, target=1.0, n_range=[n], cv=0.0,
                        samples=3, overhead=OVERHEAD, mode="hemt")
    run_job_cache_clear()
    split = total * means / means.sum()
    sched = run_job(_nodes(means), [StaticSpec(works=tuple(split))])
    assert rep.quantiles[n] == _approx(sched.completion)
    rep = plan_capacity(pool, total, target=1.0, n_range=[n], cv=0.0,
                        samples=3, overhead=OVERHEAD, mode="homt",
                        n_tasks=4 * n)
    run_job_cache_clear()
    sched = run_job(_nodes(means),
                    [PullSpec(n_tasks=4 * n, task_work=total / (4 * n))])
    assert rep.quantiles[n] == _approx(sched.completion)


def test_plan_capacity_oracle_lower_envelope():
    """The clairvoyant split never loses to the advertised-means split on
    the same draws (same seed => same sampled speeds)."""
    kw = dict(target=5.0, n_range=[4, 6], samples=300, seed=3, cv=0.4)
    hemt = plan_capacity([2.0, 1.0], 80.0, mode="hemt", **kw)
    oracle = plan_capacity([2.0, 1.0], 80.0, mode="oracle", **kw)
    for n in hemt.quantiles:
        assert oracle.quantiles[n] <= hemt.quantiles[n] + ABS


def test_plan_capacity_unreachable_target():
    rep = plan_capacity([1.0], 100.0, target=0.5, n_range=[1, 2],
                        samples=20)
    assert rep.chosen is None


def test_plan_capacity_validation():
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=1.0, n_range=[1], mode="nope")
    with pytest.raises(ValueError):
        plan_capacity([], 10.0, target=1.0, n_range=[1])
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=0.0, n_range=[1])
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=1.0, n_range=[])
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=1.0, n_range=[0, 2])
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=1.0, n_range=[1], samples=0)
    with pytest.raises(ValueError):
        plan_capacity([1.0], 10.0, target=1.0, n_range=[1], cv=-0.1)


# --------------------------------------------------------------------------
# columnar StageResult: the lazy refactor underneath the closed forms
# --------------------------------------------------------------------------

def test_closed_form_results_are_columnar_and_lazy():
    """Closed-form solves build columns; TaskRecords appear only on
    .records access and match the columns field for field."""
    from repro.core.simulator import run_pull_stage
    from repro.core.simulator import SimTask
    nodes = _nodes([1.0, 0.5, 2.0])
    tasks = [SimTask(0.3 + 0.1 * (i % 5), task_id=i) for i in range(40)]
    res = run_pull_stage(nodes, tasks)
    assert res._records is None          # nothing materialized yet
    cols = res.columns()
    assert isinstance(cols, StageColumns)
    assert cols.node_names == tuple(nd.name for nd in nodes)
    recs = res.records
    assert res.records is recs           # cached
    assert len(recs) == len(tasks)
    for j, r in enumerate(recs):
        assert isinstance(r, TaskRecord)
        assert r.task_id == cols.task_ids[j]
        assert r.node == cols.node_names[cols.node_index[j]]
        assert r.start == cols.starts[j]
        assert r.end == cols.ends[j]
        assert r.cpu_work == cols.works[j]


def test_record_built_results_derive_columns():
    """Event-path results (records-primary) produce the same columns the
    records hold, using node_finish insertion order as the name table."""
    from repro.core.engine import run_stage_events
    from repro.core.simulator import SimTask
    nodes = _nodes([1.0, 0.5])
    tasks = [SimTask(0.5, task_id=i) for i in range(7)]
    res = run_stage_events(nodes, [tasks], True)
    assert res._cols is None
    cols = res.columns()
    assert res.columns() is cols         # cached
    for j, r in enumerate(res.records):
        assert cols.node_names[cols.node_index[j]] == r.node
        assert cols.ends[j] == r.end and cols.works[j] == r.cpu_work


def test_empty_stage_result_roundtrip():
    from repro.core.engine import run_stage_events
    res = run_stage_events(_nodes([1.0, 2.0]), [[]], True)
    assert res.records == []
    cols = res.columns()
    assert cols.task_ids.size == 0
    assert res.makespan == res.completion
