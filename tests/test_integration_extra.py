"""Deeper integration: elasticity mid-training, burstable fleets,
HeMT-EP capacity routing, cluster-state offers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ArchBundle, TrainConfig, get_reduced
from repro.core.capacity import BurstableNode, burstable_split
from repro.launch.cluster import ClusterState, SliceInfo
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.train_loop import train_state_init

KEY = jax.random.PRNGKey(0)


def _tiny():
    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=60))
    return cfg, bundle


def test_elastic_slice_loss_mid_training():
    """A slice dies mid-run; training continues on survivors, re-skewed,
    with the loss still descending (no restart, the paper's point)."""
    cfg, bundle = _tiny()
    slices3 = [SliceSpec("a", [(0.0, 1.0)], 0.02),
               SliceSpec("b", [(0.0, 0.5)], 0.02),
               SliceSpec("c", [(0.0, 1.0)], 0.02)]
    tr = HeMTTrainer(cfg, bundle, slices3, grain_batch=2, global_batch=12,
                     seq_len=16, mode="hemt", grain_cost=1.0)
    st = train_state_init(KEY, cfg, bundle)
    losses = []
    for _ in range(4):
        st, rep = tr.run_step(st)
        losses.append(rep.loss)
    # slice c is preempted
    tr.resize(slices3[:2])
    for _ in range(4):
        st, rep = tr.run_step(st)
        losses.append(rep.loss)
    assert set(rep.grain_counts) == {"a", "b"}
    assert sum(rep.grain_counts.values()) == 6      # full batch re-covered
    assert rep.grain_counts["a"] > rep.grain_counts["b"]   # still skewed
    assert np.mean(losses[-2:]) < np.mean(losses[:2])      # still learning


def test_elastic_scale_up_cold_start():
    cfg, bundle = _tiny()
    tr = HeMTTrainer(cfg, bundle, [SliceSpec("a"), SliceSpec("b", [(0.0, 0.5)])],
                     grain_batch=2, global_batch=12, seq_len=16, mode="hemt")
    st = train_state_init(KEY, cfg, bundle)
    for _ in range(3):
        st, rep = tr.run_step(st)
    # newcomer joins; cold-starts at survivor mean (paper §5.1 L_k^o rule)
    tr.resize([SliceSpec("a"), SliceSpec("b", [(0.0, 0.5)]), SliceSpec("new")])
    st, rep = tr.run_step(st)
    assert "new" in rep.grain_counts and rep.grain_counts["new"] >= 1


def test_burstable_fleet_profiles():
    """§6.2 on the trainer: slices backed by token-bucket capacity. The
    credit-rich slice keeps full speed; the depleted one runs at baseline;
    the planner converges to the burstable_split ratio."""
    cfg, bundle = _tiny()
    rich = BurstableNode(credits=1e9, baseline=0.4)    # never depletes
    poor = BurstableNode(credits=0.0, baseline=0.4)    # at baseline now
    from repro.core.simulator import SimNode
    s_rich = SimNode.burstable("rich", rich).profile
    s_poor = SimNode.burstable("poor", poor).profile
    tr = HeMTTrainer(cfg, bundle,
                     [SliceSpec("rich", s_rich, 0.02),
                      SliceSpec("poor", s_poor, 0.02)],
                     grain_batch=2, global_batch=16, seq_len=16,
                     mode="hemt", grain_cost=1.0)
    st = train_state_init(KEY, cfg, bundle)
    for _ in range(5):
        st, rep = tr.run_step(st)
    # 1.0 : 0.4 -> 6:2 grains (same as the provisioned-container case)
    assert rep.grain_counts == {"rich": 6, "poor": 2}
    # a-priori burstable plan agrees with what was learned online
    shares, _ = burstable_split([rich, poor], 8.0)
    assert shares[0] / shares[1] == pytest.approx(1.0 / 0.4, rel=0.05)


def test_hemt_ep_skew_reduces_hot_shard_tokens():
    """HeMT-EP: skewed shard capacities shift *kept* tokens away from the
    slow expert shard in the real dispatch."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import expert_capacities, moe_init
    import numpy as np
    cfg_even = MoEConfig(n_experts=4, top_k=2, capacity_factor=1.0)
    cfg_skew = MoEConfig(n_experts=4, top_k=2, capacity_factor=1.0,
                         shard_capacities=(1.0, 1.0, 1.0, 0.25))
    caps_e = expert_capacities(cfg_even, 64)
    caps_s = expert_capacities(cfg_skew, 64)
    assert caps_s[3] < caps_e[3] and caps_s[:3].min() > caps_e[0] - 1
    # run dispatch and count tokens landing on expert 3
    p = moe_init(KEY, 16, 32, cfg_even, glu=True, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 16))
    from repro.models import moe as moe_mod
    out_e, _ = moe_mod.moe_apply(p, x, cfg_even)
    out_s, _ = moe_mod.moe_apply(p, x, cfg_skew)
    # outputs differ only via capacity-drop pattern; both finite
    assert np.isfinite(np.asarray(out_e)).all()
    assert np.isfinite(np.asarray(out_s)).all()
    assert not np.allclose(np.asarray(out_e), np.asarray(out_s))


def test_cluster_state_offer_report_cycle():
    """The Mesos-analogue Fig 6 loop: offers carry speed estimates; missed
    heartbeats remove slices from offers."""
    cs = ClusterState([SliceInfo("s0", 256), SliceInfo("s1", 256)],
                      heartbeat_timeout=2.0)
    cs.report("s0", grains_done=8, elapsed=1.0, now=1.0)
    cs.report("s1", grains_done=8, elapsed=2.0, now=1.0)
    offer = cs.offers()
    speeds = {s.name: s.speed for s in offer.slices}
    assert speeds["s0"] == pytest.approx(8.0)
    assert speeds["s1"] == pytest.approx(4.0)
    # s1 goes silent
    cs.report("s0", grains_done=8, elapsed=1.0, now=4.0)
    dead = cs.check()
    assert dead == ["s1"]
    assert [s.name for s in cs.offers().slices] == ["s0"]
    # revocation path
    cs.remove_slice("s1")
    cs.add_slice(SliceInfo("s2", 256, preemptible=True))
    assert "s2" in {s.name for s in cs.offers().slices}


def test_serve_cli_smoke(capsys):
    import sys
    from repro.launch import serve as serve_cli
    argv = sys.argv
    sys.argv = ["serve", "--rounds", "2", "--requests", "6", "--gen-len", "3"]
    try:
        serve_cli.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert out.count("makespan_s") == 2


def test_train_cli_smoke(tmp_path, capsys):
    import sys
    from repro.launch import train as train_cli
    argv = sys.argv
    sys.argv = ["train", "--steps", "3", "--global-batch", "8",
                "--grain-batch", "2", "--seq-len", "16",
                "--ckpt", str(tmp_path)]
    try:
        train_cli.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert out.count('"loss"') == 3
    # a checkpoint was committed and resume works
    sys.argv = ["train", "--steps", "4", "--global-batch", "8",
                "--grain-batch", "2", "--seq-len", "16",
                "--ckpt", str(tmp_path)]
    try:
        train_cli.main()
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert "resumed from step" in out
