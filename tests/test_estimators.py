"""Direct coverage for ARSpeedEstimator (cold-start modes, forget) and
FudgeFactorLearner.probe — previously only exercised through scheduler
tests."""
import pytest

from repro.core.estimators import (
    ARSpeedEstimator, FudgeFactorLearner, estimate_quality, normalized,
)


def _warm(est):
    est.observe("a", 4.0, 2.0)     # 2.0
    est.observe("b", 3.0, 6.0)     # 0.5
    return est


# --------------------------------------------------------------------------
# cold-start fill rules (paper §5.1: v_i = v-bar for i in L_k^o)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode,fill", [("mean", 1.25), ("min", 0.5),
                                       ("max", 2.0)])
def test_cold_start_modes_fill_unseen_executors(mode, fill):
    est = _warm(ARSpeedEstimator(alpha=0.0, cold_start=mode))
    assert est.speeds(["a", "b", "new"]) == pytest.approx([2.0, 0.5, fill])


def test_cold_start_with_no_observations_fills_one():
    est = ARSpeedEstimator()
    assert est.speeds(["x", "y"]) == [1.0, 1.0]
    assert est.known() == {}
    assert est.speed("x") is None


def test_cold_start_mode_validated():
    with pytest.raises(ValueError, match="mean|min|max"):
        ARSpeedEstimator(cold_start="median")
    with pytest.raises(ValueError, match="alpha"):
        ARSpeedEstimator(alpha=1.0)
    with pytest.raises(ValueError, match="alpha"):
        ARSpeedEstimator(alpha=-0.1)


# --------------------------------------------------------------------------
# AR(1) update + first-observation rule
# --------------------------------------------------------------------------

def test_first_observation_overrides_cold_fill():
    est = _warm(ARSpeedEstimator(alpha=0.5))
    # "c" currently reads as the mean fill; its FIRST direct observation
    # must be taken whole (paper k=1 rule), not smoothed against the fill
    assert est.speeds(["c"]) == [1.25]
    est.observe("c", 9.0, 3.0)
    assert est.speed("c") == pytest.approx(3.0)
    # second observation: (1 - alpha) * sample + alpha * old
    est.observe("c", 1.0, 1.0)
    assert est.speed("c") == pytest.approx(0.5 * 1.0 + 0.5 * 3.0)


def test_observe_many_and_elapsed_validation():
    est = ARSpeedEstimator()
    est.observe_many({"a": (2.0, 1.0), "b": (1.0, 4.0)})
    assert est.known() == pytest.approx({"a": 2.0, "b": 0.25})
    with pytest.raises(ValueError, match="elapsed"):
        est.observe("a", 1.0, 0.0)


def test_forget_drops_executor_and_cold_start_refills():
    est = _warm(ARSpeedEstimator())
    est.forget("a")
    assert est.speed("a") is None
    # the fill now comes from the survivors only
    assert est.speeds(["a"]) == [0.5]
    est.forget("zzz")               # unknown executor: no-op, no raise
    est.forget("b")
    assert est.speeds(["a", "b"]) == [1.0, 1.0]


# --------------------------------------------------------------------------
# fudge factor (§6.2)
# --------------------------------------------------------------------------

def test_fudge_probe_learns_and_smooths():
    f = FudgeFactorLearner(advertised=0.4, smoothing=0.25)
    assert f.effective == 0.4       # nothing probed yet
    assert f.probe(10.0, 3.2) == pytest.approx(0.32)
    assert f.effective == pytest.approx(0.32)
    # exponential smoothing toward the new measurement
    assert f.probe(10.0, 4.0) == pytest.approx(0.75 * 0.32 + 0.25 * 0.40)


def test_fudge_probe_validates_rates():
    f = FudgeFactorLearner(advertised=0.4)
    with pytest.raises(ValueError, match="positive"):
        f.probe(0.0, 1.0)
    with pytest.raises(ValueError, match="positive"):
        f.probe(1.0, -2.0)
    assert f.effective == 0.4       # failed probes leave no trace


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def test_normalized_and_estimate_quality():
    assert normalized([1.0, 3.0]) == pytest.approx([0.25, 0.75])
    with pytest.raises(ValueError):
        normalized([0.0, 0.0])
    with pytest.raises(ValueError):
        normalized([1.0, -1.0])
    assert estimate_quality([1.0, 1.0], [1.0, 1.0]) == 0.0
    assert estimate_quality([2.0, 2.0], [1.0, 3.0]) == pytest.approx(0.5)
