"""Online-adaptive HeMT (engine.AdaptivePlan + run_job(adaptive=...)) vs a
naive per-stage re-plan loop.

The oracle below restates the documented OA-HeMT barrier semantics
independently: per stage — fold any reskew residual into the planned
works, re-split from a separately-maintained AR(1) estimator (the paper's
``d_i = D v_i / V``), run the stage through the per-stage engine at its
true absolute start, cut stragglers per the ReskewHandoff rule, and feed
the estimator (executed work, busy time) per node.  Randomized
differential suites pin the adaptive ``run_job`` path (rel-summary
shifts, solve LRU, fold-then-replan composition) against it at 1e-9 on
constant-speed and multi-segment clusters, with and without re-skew
hand-off, float and quantized splits.
"""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    AdaptivePlan, PullSpec, StageSummary, StaticSpec, run_job,
    run_job_cache_clear, simulate_stage,
)
from repro.core.estimators import ARSpeedEstimator
from repro.core.partitioner import proportional_split
from repro.core.scheduler import AdaptiveHeMTScheduler, MultiStageJob
from repro.core.simulator import SimNode, SimTask
from repro.core.speculation import ReskewHandoff, fold_residual, quantile

REL = ABS = 1e-9


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# the naive per-stage re-plan oracle
# --------------------------------------------------------------------------

def _spec_queues(spec):
    if isinstance(spec, StaticSpec):
        return [[SimTask(w, task_id=i)] for i, w in enumerate(spec.works)], \
            False
    works = spec.works if spec.works is not None \
        else (spec.task_work,) * spec.n_tasks
    return [[SimTask(float(w), spec.io_mb, spec.datanode, task_id=k)
             for k, w in enumerate(works)]], True


def naive_adaptive_job(nodes, specs, alpha=0.0, quantum=None, min_units=0,
                       start=0.0):
    """Independent restatement: per-stage absolute-time engine entries +
    explicit fold / re-split / cut / observe at every barrier."""
    names = [nd.name for nd in nodes]
    est = ARSpeedEstimator(alpha=alpha)
    t = start
    carry = None                      # (residual, vhat)
    finishes = []
    for k, spec in enumerate(specs):
        works = list(spec.works) if isinstance(spec, StaticSpec) else None
        # 1. residual fold (reskew hand-off from an earlier barrier)
        if carry is not None and works is not None and len(works):
            works = fold_residual(works, carry[0], carry[1])
            carry = None
        # 2. re-plan from the estimator (paper §5.1 split; degenerate
        #    guards restated: V = 0 -> even split, D < quantum -> even)
        if works is not None and est.known():
            speeds = est.speeds(names)
            if not any(v > 0.0 for v in speeds):
                speeds = [1.0] * len(names)
            total = sum(works)
            if quantum is None:
                works = [total * v / sum(speeds) for v in speeds]
            else:
                units = int(round(total / quantum))
                if abs(units * quantum - total) > 1e-9 * max(1.0, total):
                    units = int(total / quantum)
                if units == 0 or units < min_units * len(names):
                    works = [total / len(names)] * len(names)
                else:
                    works = [u * quantum for u in
                             proportional_split(units, speeds,
                                                min_share=min_units)]
                    rem = total - units * quantum
                    if rem > 0.0:
                        works[max(range(len(works)),
                                  key=lambda i: speeds[i])] += rem
        # 3. solve the stage at its true absolute start
        if works is not None:
            queues = [[SimTask(w, task_id=i)] for i, w in enumerate(works)]
            res = simulate_stage(nodes, queues, pull=False, start_time=t)
        else:
            queues, pull = _spec_queues(spec)
            res = simulate_stage(nodes, queues, pull=pull, start_time=t)
        offs = [res.node_finish[nm] - t for nm in names]
        executed = {nm: 0.0 for nm in names}
        for r in res.records:
            executed[r.node] += r.cpu_work
        # 4. straggler cut at the barrier (ReskewHandoff restatement)
        if (works is not None and isinstance(spec.mitigation, ReskewHandoff)
                and k + 1 < len(specs)):
            ran = [o for nm, o in zip(names, offs) if executed[nm] > 0.0]
            cutoff = spec.mitigation.cutoff_factor * quantile(ran, 0.5)
            residual, clipped = 0.0, []
            for nd, off, w in zip(nodes, offs, works):
                if off > cutoff + 1e-9:
                    r = min(nd.work_between(t + cutoff, t + off), w)
                    residual += r
                    executed[nd.name] = w - r
                    clipped.append(cutoff)
                else:
                    clipped.append(off)
            if residual > 0.0:
                vhat = [executed[nm] / c if c > 0 else 0.0
                        for nm, c in zip(names, clipped)]
                carry = (residual, vhat)
                offs = clipped
        # 5. observe (executed work, busy time) per node
        for nm, off in zip(names, offs):
            if executed[nm] > 0.0 and off > 0.0:
                est.observe(nm, executed[nm], off)
        finishes.append([t + o for o in offs])
        t += max(offs) if offs else 0.0
    return t, finishes


def _rand_nodes(rng, n, multi_segment=False):
    nodes = []
    for i in range(n):
        if multi_segment:
            k = int(rng.integers(2, 4))
            times = np.concatenate(([0.0], np.sort(rng.uniform(1.0, 60.0, k))))
            profile = [(float(tt), float(rng.uniform(0.3, 2.0)))
                       for tt in times]
        else:
            profile = [(0.0, float(rng.uniform(0.3, 2.0)))]
        nodes.append(SimNode(f"n{i}", profile,
                             float(rng.uniform(0.0, 0.3))))
    return nodes


def _rand_specs(rng, n, n_stages, reskew=False, with_pull=False):
    specs = []
    for _ in range(n_stages):
        if with_pull and rng.random() < 0.3:
            specs.append(PullSpec(n_tasks=int(rng.integers(n, 4 * n)),
                                  task_work=float(rng.uniform(0.5, 3.0))))
            continue
        works = tuple(float(w) for w in rng.uniform(0.5, 12.0, n))
        mit = ReskewHandoff(float(rng.uniform(1.0, 1.6))) if reskew else None
        specs.append(StaticSpec(works=works, mitigation=mit))
    return specs


@given(seed=st.integers(0, 10_000), multi=st.booleans(),
       reskew=st.booleans())
def test_adaptive_run_job_matches_naive_replan_loop(seed, multi, reskew):
    """The tentpole differential: fold -> re-plan -> solve -> cut ->
    observe at every barrier, fast path vs naive restatement at 1e-9."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    nodes = _rand_nodes(rng, n, multi_segment=multi)
    specs = _rand_specs(rng, n, int(rng.integers(2, 6)), reskew=reskew,
                        with_pull=not reskew)
    alpha = float(rng.uniform(0.0, 0.8))
    run_job_cache_clear()
    sched = run_job(nodes, specs, adaptive=AdaptivePlan(alpha=alpha))
    total, finishes = naive_adaptive_job(nodes, specs, alpha=alpha)
    assert sched.completion == _approx(total)
    for summ, fin in zip(sched.stages, finishes):
        for nd, f in zip(nodes, fin):
            assert summ.node_finish[nd.name] == _approx(f)


@given(seed=st.integers(0, 10_000), reskew=st.booleans())
def test_adaptive_quantized_matches_naive(seed, reskew):
    """Whole-quantum splits (the HeMT-DP grain case) differential; with
    ``reskew`` the hand-off folds a *continuous* residual into a
    quantized stage — the sub-quantum remainder must ride the fastest
    estimated executor, not crash the run."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    nodes = _rand_nodes(rng, n)
    q = float(rng.choice([0.25, 0.5, 1.0]))
    units = int(rng.integers(4 * n, 8 * n))
    works = tuple(q * u for u in
                  proportional_split(units, rng.uniform(0.5, 2.0, n)))
    mit = ReskewHandoff(float(rng.uniform(1.0, 1.4))) if reskew else None
    specs = [StaticSpec(works=works, mitigation=mit)] * int(rng.integers(2, 6))
    run_job_cache_clear()
    plan = AdaptivePlan(alpha=0.0, quantum=q, min_units=1)
    sched = run_job(nodes, specs, adaptive=plan)
    total, _ = naive_adaptive_job(nodes, specs, alpha=0.0, quantum=q,
                                  min_units=1)
    assert sched.completion == _approx(total)
    for log in plan.history[1:]:
        assert log.replanned
        whole = [w for w in log.works
                 if round(w / q) * q == pytest.approx(w, abs=1e-9)]
        assert len(whole) >= len(log.works) - 1   # <= 1 fractional tail
        for w in log.works:
            assert w >= q - 1e-12          # min_units floor


# --------------------------------------------------------------------------
# executed-work summaries (what the loop observes), all solve paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec,uplink,multi", [
    (StaticSpec(works=(3.0, 5.0, 2.0)), None, False),          # closed-static
    (PullSpec(n_tasks=17, task_work=1.3), None, False),        # closed-pull
    (PullSpec(works=tuple([2.0] * 40 + [0.7] * 40 + [1.1] * 37)),
     None, False),                                             # hetero batched
    (PullSpec(works=(1.0, 2.5, 0.5, 3.0, 1.7, 0.9, 2.2)), None,
     False),                                                   # hetero heap
    (PullSpec(n_tasks=12, task_work=0.4, io_mb=64.0, datanode=0),
     128.0, False),                                            # io-sym
    (StaticSpec(works=(3.0, 5.0, 2.0)), None, True),           # event path
])
def test_stage_summary_executed_work_matches_records(spec, uplink, multi):
    rng = np.random.default_rng(7)
    nodes = _rand_nodes(rng, 3, multi_segment=multi)
    run_job_cache_clear()
    sched = run_job(nodes, [spec], uplink_bw=uplink)
    queues, pull = _spec_queues(spec)
    res = simulate_stage(nodes, queues, pull=pull, uplink_bw=uplink)
    executed = {nd.name: 0.0 for nd in nodes}
    for r in res.records:
        executed[r.node] += r.cpu_work
    for nd in nodes:
        assert sched.stages[0].work[nd.name] == _approx(executed[nd.name])


def test_reskew_summary_reports_clipped_work():
    nodes = [SimNode.constant(f"n{i}", 1.0) for i in range(3)]
    spec = StaticSpec(works=(2.0, 2.0, 10.0),
                      mitigation=ReskewHandoff(cutoff_factor=1.5))
    sched = run_job(nodes, [spec, StaticSpec(works=(1.0, 1.0, 1.0))])
    cut = sched.stages[0]
    # straggler cut at 1.5 * median(2, 2, 10) = 3.0: node 2 executed 3.0
    assert cut.work["n2"] == _approx(3.0)
    assert cut.work["n0"] == _approx(2.0)
    assert sum(cut.work.values()) + (10.0 - 3.0) == _approx(14.0)


# --------------------------------------------------------------------------
# solve-cache correctness under adaptive re-planning
# --------------------------------------------------------------------------

def test_adaptive_runs_do_not_poison_solve_caches():
    """Re-planned specs are fresh values, so the value-keyed LRU can never
    hand a planned solve to an adaptive stage or vice versa."""
    nodes = [SimNode.constant(f"n{i}", s, 0.1)
             for i, s in enumerate([1.0, 0.5, 0.25])]
    specs = [StaticSpec(works=(4.0, 4.0, 4.0))] * 4
    run_job_cache_clear()
    baseline = run_job(nodes, specs).completion
    adaptive = run_job(nodes, specs, adaptive=AdaptivePlan()).completion
    assert adaptive < baseline          # sanity: adaptation helped
    # same spec objects again, warm LRU: must reproduce the cold solves
    assert run_job(nodes, specs).completion == baseline
    assert run_job(nodes, specs,
                   adaptive=AdaptivePlan()).completion == adaptive
    run_job_cache_clear()
    assert run_job(nodes, specs).completion == baseline


def test_adaptive_converges_to_balanced_split():
    nodes = [SimNode.constant(f"n{i}", s, 0.05)
             for i, s in enumerate([1.0, 0.6, 0.4])]
    plan = AdaptivePlan()
    sched = run_job(nodes, [StaticSpec(works=(5.0, 5.0, 5.0))] * 6,
                    adaptive=plan)
    spans = [s.span for s in sched.stages]
    # ideal balanced span: D / sum(v) + overhead-ish; stale even split
    # leaves the 0.4 node running 5/0.4 = 12.5s
    assert spans[0] > 12.0
    assert spans[-1] < 15.0 / 2.0 * 1.1
    assert plan.history[0].replanned is False
    assert all(h.replanned for h in plan.history[1:])


# --------------------------------------------------------------------------
# AdaptivePlan API
# --------------------------------------------------------------------------

def test_adaptive_plan_validation():
    with pytest.raises(ValueError):
        AdaptivePlan(quantum=0.0)
    with pytest.raises(ValueError):
        AdaptivePlan(quantum=-1.0)
    with pytest.raises(ValueError):
        AdaptivePlan(min_units=-1, quantum=1.0)
    with pytest.raises(ValueError, match="quantum"):
        AdaptivePlan(min_units=2)       # no quantum: no unit to floor by
    with pytest.raises(ValueError):
        AdaptivePlan(alpha=1.5)         # forwarded to ARSpeedEstimator


def test_adaptive_quantum_observes_in_quanta_per_second():
    """Quantum plans must record GrainPlanner-compatible grains/sec, not
    work-units/sec, so sharing one estimator across per-step and windowed
    driver scheduling mixes no units."""
    plan = AdaptivePlan(quantum=2.0)
    summ = StageSummary(0.0, 4.0, 0.0, {"a": 4.0}, {"a": 1}, {"a": 8.0})
    plan.observe(["a"], summ)           # 8 work units = 4 quanta in 4 s
    assert plan.estimator.speed("a") == _approx(1.0)
    unscaled = AdaptivePlan()
    unscaled.observe(["a"], summ)
    assert unscaled.estimator.speed("a") == _approx(2.0)


def test_adaptive_plan_quantum_conserves_fractional_total():
    """A reskew residual makes quantized totals fractional mid-run: the
    whole quanta split proportionally, the remainder rides the fastest
    estimated executor, and no work is lost."""
    plan = AdaptivePlan(quantum=1.0)
    plan.estimator.observe("a", 2.0, 1.0)      # speed 2.0 (fastest)
    plan.estimator.observe("b", 2.0, 2.0)      # speed 1.0
    split = plan.split(["a", "b"], 7.3)
    assert sum(split) == _approx(7.3)
    assert split[1] == _approx(round(split[1]))    # b stays whole-quantum
    assert split[0] - int(split[0]) == _approx(0.3)  # tail on the fastest
    assert sum(plan.split(["a", "b"], 7.0)) == _approx(7.0)


def test_adaptive_quantum_with_reskew_residual_does_not_crash():
    """Live repro of the composition: a cut straggler folds a continuous
    residual into a whole-grain stage."""
    nodes = [SimNode.constant("f", 1.0), SimNode.constant("s", 0.25)]
    specs = [StaticSpec(works=(4.0, 4.0),
                        mitigation=ReskewHandoff(cutoff_factor=1.3)),
             StaticSpec(works=(4.0, 4.0))]
    run_job_cache_clear()
    plan = AdaptivePlan(quantum=1.0)
    sched = run_job(nodes, specs, adaptive=plan)
    assert sched.completion > 0.0
    # stage 1 total = its own 8.0 + stage 0's unexecuted residual
    residual = 8.0 - sum(sched.stages[0].work.values())
    assert residual > 0.0                      # the cut actually happened
    assert sum(plan.history[1].works) == _approx(8.0 + residual)


def test_adaptive_zero_speed_barrier_falls_back_to_even_split():
    """Degenerate re-split, V = 0: every executor known but zero-speed at
    the barrier (d_i = D v_i / V is 0/0) — the plan falls back to an even
    split instead of raising out of ``normalized`` mid-job."""
    for plan in (AdaptivePlan(), AdaptivePlan(quantum=1.0, min_units=1)):
        plan.estimator.observe("a", 0.0, 1.0)
        plan.estimator.observe("b", 0.0, 1.0)
        assert plan.split(["a", "b"], 6.0) == _approx([3.0, 3.0])
        out = plan.replan(["a", "b"], StaticSpec(works=(4.0, 2.0)))
        assert out.works == _approx((3.0, 3.0))
        assert plan.history[-1].replanned


def test_adaptive_subquantum_total_splits_evenly():
    """Degenerate quantization, D < quantum: no executor can receive a
    whole quantum, so the sub-quantum total is split evenly instead of
    riding the fastest executor (and min_units no longer raises
    'infeasible' on a tiny folded residual)."""
    plan = AdaptivePlan(quantum=1.0, min_units=1)
    plan.estimator.observe("a", 4.0, 1.0)      # fast
    plan.estimator.observe("b", 1.0, 1.0)
    split = plan.split(["a", "b"], 0.4)
    assert split == _approx([0.2, 0.2])
    assert sum(split) == _approx(0.4)          # conserved exactly
    assert plan.split(["a", "b"], 0.0) == _approx([0.0, 0.0])


def test_adaptive_quantum_infeasible_min_units_floor_splits_evenly():
    """Between one quantum and the min_units floor (0 < units <
    n * min_units) proportional rounding cannot honor the floor — the
    re-plan must split evenly, not raise 'min_share infeasible' out of
    run_job on a residual total the caller never chose."""
    plan = AdaptivePlan(quantum=1.0, min_units=1)
    plan.estimator.observe("a", 4.0, 1.0)
    plan.estimator.observe("b", 1.0, 1.0)
    split = plan.split(["a", "b"], 1.24)       # 1 whole quantum < 2 floors
    assert split == _approx([0.62, 0.62])
    assert sum(split) == _approx(1.24)
    # live repro: a reskew cut folds ~1.2 quanta into the next stage
    nodes = [SimNode.constant("f", 1.0), SimNode.constant("s", 0.05)]
    specs = [StaticSpec(works=(0.5, 2.5),
                        mitigation=ReskewHandoff(cutoff_factor=1.0)),
             StaticSpec(works=(0.0, 0.0))]
    run_job_cache_clear()
    jplan = AdaptivePlan(quantum=1.0, min_units=1)
    sched = run_job(nodes, specs, adaptive=jplan)   # must not raise
    residual = 3.0 - sum(sched.stages[0].work.values())
    assert 1.0 < residual < 2.0                # the in-between window
    final = jplan.history[1].works
    assert sum(final) == _approx(residual)
    assert final[0] == _approx(final[1])


def test_adaptive_quantum_subquantum_residual_stage_survives():
    """Live composition: a reskew cut folds a sub-quantum residual into a
    zero-work stage — the quantized re-plan must split it evenly, not
    crash on an infeasible min_units floor."""
    nodes = [SimNode.constant("f", 1.0), SimNode.constant("s", 0.05)]
    specs = [StaticSpec(works=(0.5, 0.5),
                        mitigation=ReskewHandoff(cutoff_factor=1.0)),
             StaticSpec(works=(0.0, 0.0))]
    run_job_cache_clear()
    plan = AdaptivePlan(quantum=1.0, min_units=1)
    sched = run_job(nodes, specs, adaptive=plan)
    residual = 1.0 - sum(sched.stages[0].work.values())
    assert 0.0 < residual < 1.0                # sub-quantum fold happened
    final = plan.history[1].works
    assert sum(final) == _approx(residual)     # conserved
    assert final[0] == _approx(final[1])       # even, not all-on-fastest


def test_adaptive_observe_skips_idle_nodes():
    plan = AdaptivePlan()
    summ = StageSummary(0.0, 5.0, 0.0, {"a": 5.0, "b": 0.0},
                        {"a": 1, "b": 0}, {"a": 5.0, "b": 0.0})
    plan.observe(["a", "b"], summ)
    assert plan.estimator.speed("a") == _approx(1.0)
    assert plan.estimator.speed("b") is None


# --------------------------------------------------------------------------
# threading: scheduler, MultiStageJob, workloads, bench
# --------------------------------------------------------------------------

def test_scheduler_adaptive_job_shares_estimator():
    nodes = [SimNode.constant(f"n{i}", s, 0.1)
             for i, s in enumerate([1.0, 0.5])]
    sched = AdaptiveHeMTScheduler(["n0", "n1"])
    hist = sched.run_simulated_job(nodes, [10.0] * 4)
    assert len(hist) == 4
    assert hist[-1].completion < hist[0].completion
    # in-job barrier observations landed in the scheduler's own estimator,
    # so the NEXT submission plans skewed from the start
    split = sched.plan(10.0)
    assert split[0] > split[1]
    stale = AdaptiveHeMTScheduler(["n0", "n1"])
    hist_stale = stale.run_simulated_job(nodes, [10.0] * 4, adaptive=False)
    assert hist_stale[-1].completion > hist[-1].completion
    # ... but the stale run still observed (paper: estimates keep updating)
    assert stale.estimator.known()


def test_multistage_adaptive_beats_stale_and_rejects_records_mode():
    nodes = [SimNode.constant(f"n{i}", s, 0.1)
             for i, s in enumerate([1.0, 0.5, 0.25])]
    job = MultiStageJob([12.0] * 5)
    stale, _ = job.run(nodes, [1.0, 1.0, 1.0])
    adapt, stages = job.run(nodes, [1.0, 1.0, 1.0],
                            adaptive=AdaptivePlan())
    assert adapt < stale
    assert len(stages) == 5
    with pytest.raises(ValueError, match="records=True"):
        job.run(nodes, [1.0, 1.0, 1.0], records=True,
                adaptive=AdaptivePlan())


def test_workloads_adaptive_keeps_math_and_speeds_schedule():
    from repro.workloads.kmeans import KMeansJob, kmeans_reference
    from repro.workloads.pagerank import PageRankJob, pagerank_reference, \
        random_graph
    rng = np.random.default_rng(3)
    nodes = [SimNode.constant(f"n{i}", s, 0.02)
             for i, s in enumerate([1.0, 0.4])]
    pts = rng.normal(size=(200, 2))
    stale = KMeansJob(pts, 3, nodes, mode="hemt", seed=0)
    stale.run(5)
    adapt = KMeansJob(pts, 3, nodes, mode="hemt", seed=0,
                      adaptive=AdaptivePlan())
    cent = adapt.run(5)
    assert np.allclose(np.asarray(cent), kmeans_reference(pts, 3, 5, seed=0),
                       atol=1e-5)
    assert adapt.total_time() < stale.total_time()

    src, dst = random_graph(400, 4, seed=1)
    pstale = PageRankJob(src, dst, 400, nodes, mode="hemt")
    pstale.run(5)
    padapt = PageRankJob(src, dst, 400, nodes, mode="hemt",
                         adaptive=AdaptivePlan())
    ranks = padapt.run(5)
    assert np.allclose(ranks, pagerank_reference(src, dst, 400, 5),
                       atol=1e-8)
    assert padapt.total_time() < pstale.total_time()


def test_trainer_oa_hemt_window_adapts_and_keeps_math():
    """mode='oa-hemt': one adaptive run_job schedules the whole window
    (per-barrier grain re-splits, whole-grain quantum) while the math
    stays a real grain-accumulated update per step."""
    import dataclasses
    import jax
    from repro.configs import ArchBundle, TrainConfig, get_reduced
    from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
    from repro.runtime.train_loop import train_state_init

    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=50))
    slices = [SliceSpec("fast", [(0.0, 1.0)], 0.05),
              SliceSpec("slow", [(0.0, 0.4)], 0.05)]
    tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                     seq_len=16, mode="oa-hemt", grain_cost=2.0)
    st = train_state_init(jax.random.PRNGKey(0), cfg, bundle)
    st = tr.run_window(st, 5)
    assert int(st.step) == 5
    assert tr.grain_dispatches == 5
    assert len(tr.reports) == 5
    # unit consistency with the per-step path: the shared estimator holds
    # grains/sec, not work-units/sec (which would read ~2x higher at
    # grain_cost=2.0).  Window macrotasks pay ONE dispatch overhead per
    # barrier: fast ran 6 grains in 0.05 + 12.0 s
    assert tr.planner.estimator.speed("fast") == pytest.approx(
        6.0 / 12.05, rel=1e-3)      # AR(1)-smoothed over the window
    st, rep = tr.run_step(st)           # per-step path on the same state
    # per-grain overhead regime (6 grains in 12.3 s) blends in smoothly —
    # same unit, so the estimate stays in grains/sec, nowhere near the
    # 2x-off work-units/sec a unit mix would produce
    assert tr.planner.estimator.speed("fast") == pytest.approx(
        0.49, rel=0.05)
    # every step processes the full global batch, in whole grains
    for rep in tr.reports:
        assert sum(rep.grain_counts.values()) == tr.n_grains
        assert np.isfinite(rep.loss)
    # cold start is even; the barrier re-plans converge on the 1.0/0.4
    # speed ratio (integer grains: 6/2 of 8) and the makespan drops
    assert tr.reports[0].grain_counts == {"fast": 4, "slow": 4}
    assert tr.reports[-1].grain_counts["fast"] > \
        tr.reports[-1].grain_counts["slow"]
    assert tr.reports[-1].makespan < tr.reports[0].makespan


def test_bench_oa_hemt_reproduces_paper_ordering():
    """§5: OA-HeMT converges to within a few percent of the clairvoyant
    per-stage split and beats both HomT and stale static HeMT under
    AR(1)-drifting node speeds; composing ReskewHandoff rescues a
    mis-skewed cold start."""
    from benchmarks.bench_oa_hemt import drift_scenario
    s = drift_scenario()
    gap = s["oa"]["tail_mean"] / s["oracle"]["tail_mean"] - 1.0
    assert 0.0 <= gap < 0.06
    assert s["oa"]["completion"] < s["homt"]["completion"]
    assert s["oa"]["completion"] < s["stale"]["completion"]
    assert s["homt"]["completion"] < s["stale"]["completion"]
    assert s["oracle"]["completion"] < s["oa"]["completion"]
    assert s["oa_reskew"]["completion"] < s["oa_bad"]["completion"]
