import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# 512-device flag in-process); keep any user XLA_FLAGS out of the way
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
settings.load_profile("ci")
