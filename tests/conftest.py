import os

# smoke tests and benches must see ONE device (the dry-run sets its own
# 512-device flag in-process); keep any user XLA_FLAGS out of the way
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # Clean containers ship without hypothesis. Install a minimal stand-in
    # that covers the subset this suite uses (given + floats/integers/lists/
    # booleans/sampled_from/just/tuples strategies — tuples and sampled_from
    # are exercised by the randomized multi-stage differential tests in
    # test_engine.py, and the fault differential suites in test_faults.py
    # ride the same integer-seed pattern — plus profile registration as
    # no-ops) so collection
    # and the property tests still run: each @given test executes a fixed
    # number of deterministic pseudo-random examples instead of being
    # skipped.  Both branches are continuously exercised: the py3.12 leg of
    # .github/workflows/ci.yml installs the real hypothesis while the
    # py3.10 leg (and this container) runs the stub, so a strategy drifting
    # outside the stub's subset fails CI rather than lingering.  RETIRE
    # CONDITION: delete this whole except-branch the day the container
    # image bakes hypothesis in (i.e. the import above stops failing on a
    # clean container) — tracked as a ROADMAP.md open item; the CI matrix
    # leg keeps covering the real library either way.
    import random
    import sys
    import types
    import zlib

    _MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def _lists(elements, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10

        def draw(r):
            return [elements.draw(r) for _ in range(r.randint(min_size, hi))]

        return _Strategy(draw)

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def _sampled_from(seq):
        pool = list(seq)
        return _Strategy(lambda r: r.choice(pool))

    def _just(value):
        return _Strategy(lambda r: value)

    def _tuples(*strategies):
        return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))

    def _given(**named):
        def deco(fn):
            # NB: no functools.wraps — pytest would follow __wrapped__ to the
            # original signature and try to resolve the strategy names as
            # fixtures; the wrapper must present a bare () signature.
            def wrapper(*args, **kwargs):
                # str hash() is per-process randomized; crc32 keeps the
                # drawn examples deterministic across runs
                base = zlib.crc32(fn.__qualname__.encode())
                for example in range(_MAX_EXAMPLES):
                    rng = random.Random(base + example)
                    drawn = {k: s.draw(rng) for k, s in named.items()}
                    fn(*args, **drawn, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    class _HealthCheck:
        too_slow = "too_slow"
        filter_too_much = "filter_too_much"

    class _Settings:
        @staticmethod
        def register_profile(name, **kwargs):
            pass

        @staticmethod
        def load_profile(name):
            pass

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _st.lists = _lists
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.just = _just
    _st.tuples = _tuples

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.HealthCheck = _HealthCheck
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

    from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
# the nightly chaos leg (.github/workflows/ci.yml) runs the randomized
# differential suites under the real hypothesis with a date-derived
# --hypothesis-seed and a deeper example budget; select it with
# HYPOTHESIS_PROFILE=chaos (stub profiles are no-ops, so the env var is
# harmless on clean containers)
settings.register_profile(
    "chaos", max_examples=200, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
