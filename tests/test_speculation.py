"""Speculation & work-stealing subsystem vs. a straightforward oracle.

The oracle below re-implements the mitigation event semantics (specified
in the ``repro.core.speculation`` module docstring) as a naive
rescan-everything loop over ``SimNode`` full profile walks — none of the
engine's cursors, heaps, or version-skipped events.  Randomized
differential suites pin ``run_stage_events(mitigation=...)`` and the
``run_job`` policy threading against it at 1e-9, including cancel-vs-
finish ties and zero-benefit (homogeneous) cases where mitigation must be
a no-op.
"""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear, run_stage_events,
    simulate_stage,
)
from repro.core.scheduler import AdaptiveHeMTScheduler, MultiStageJob
from repro.core.simulator import (
    SimNode, SimTask, TaskRecord, _stage_result, run_pull_stage,
    run_static_stage,
)
from repro.core.speculation import (
    ReskewHandoff, RunningAttempt, Speculate, SpeculativeCopies,
    WorkStealing, fold_residual, quantile,
)

REL = ABS = 1e-9


def _approx(x):
    return pytest.approx(x, rel=REL, abs=ABS)


# --------------------------------------------------------------------------
# the oracle: naive per-event loop with the documented mitigation semantics
# --------------------------------------------------------------------------

def oracle_stage(nodes, queues, pull, mitigation=None, start_time=0.0):
    """Rescan-everything mitigation oracle (no cursors, no event heap)."""
    n = len(nodes)
    shared = list(queues[0]) if pull else None
    private = None if pull else [list(q) for q in queues]
    task = [None] * n            # task_id of the running attempt
    start = [0.0] * n
    launch = [0.0] * n
    work = [0.0] * n
    cpu_done = [0.0] * n
    busy = [False] * n
    twin = [-1] * n
    copied = set()
    done = []
    rechecks = {}                # node -> newest scheduled recheck time
    records = []
    node_finish = {nd.name: start_time for nd in nodes}

    def queue_empty(i):
        return not shared if pull else not private[i]

    def start_attempt(i, task_id, w, now):
        busy[i] = True
        task[i] = task_id
        start[i] = now
        launch[i] = now + nodes[i].task_overhead
        work[i] = w
        cpu_done[i] = nodes[i].finish_time(w, launch[i])
        rechecks.pop(i, None)    # any pending idle recheck is superseded

    def refill(i, now):
        if pull:
            if shared:
                tk = shared.pop(0)
                start_attempt(i, tk.task_id, tk.cpu_work, now)
        elif private[i]:
            tk = private[i].pop(0)
            start_attempt(i, tk.task_id, tk.cpu_work, now)

    def remaining(k, now):
        if now < launch[k]:
            return work[k]
        return nodes[k].work_between(now, cpu_done[k])

    def offer_all(now):
        while True:
            running = [RunningAttempt(k, task[k], start[k], work[k],
                                      remaining(k, now), task[k] in copied)
                       for k in range(n) if busy[k]]
            if not running:
                return
            by_node = {r.node: r for r in running}
            acted = False
            for k in range(n):
                if busy[k] or not queue_empty(k):
                    continue
                act = mitigation.offer(done, running, now)
                if act is None:
                    continue
                victim = by_node[act.victim]
                if isinstance(act, Speculate):
                    copied.add(victim.task_id)
                    start_attempt(k, victim.task_id, victim.work, now)
                    twin[k] = act.victim
                    twin[act.victim] = k
                else:
                    j = act.victim
                    work[j] -= act.amount
                    cpu_done[j] = nodes[j].finish_time(
                        victim.remaining - act.amount, max(now, launch[j]))
                    start_attempt(k, victim.task_id, act.amount, now)
                acted = True
                break
            if not acted:
                for k in range(n):
                    if busy[k] or not queue_empty(k):
                        continue
                    nc = mitigation.next_check(done, running, now)
                    if nc is not None:
                        rechecks[k] = nc
                return

    for i in range(n):
        refill(i, start_time)
    if mitigation is not None:
        offer_all(start_time)

    guard = 0
    while any(busy):
        guard += 1
        assert guard < 1_000_000, "oracle runaway"
        events = [(cpu_done[i], i, "done") for i in range(n) if busy[i]]
        events += [(t, i, "recheck") for i, t in rechecks.items()
                   if not busy[i]]
        t, i, kind = min(events, key=lambda e: (e[0], e[1]))
        if kind == "recheck":
            del rechecks[i]
            offer_all(t)
            continue
        records.append(TaskRecord(task[i], nodes[i].name, start[i], t,
                                  work[i]))
        node_finish[nodes[i].name] = t
        busy[i] = False
        done.append(t - start[i])
        loser = twin[i]
        if loser >= 0:
            twin[i] = twin[loser] = -1
            busy[loser] = False      # cancelled: no record, no node_finish
        refill(i, t)
        if loser >= 0:
            refill(loser, t)
        if mitigation is not None:
            offer_all(t)

    return _stage_result(records, node_finish, start_time)


def assert_mitigated_match(oracle, got):
    assert got.completion == _approx(oracle.completion)
    assert got.idle_time == _approx(oracle.idle_time)
    assert set(got.node_finish) == set(oracle.node_finish)
    for name, t in oracle.node_finish.items():
        assert got.node_finish[name] == _approx(t)
    # steal splits yield several records per task_id: compare as sorted
    # multisets (start is part of the key so split pieces pair up)
    ra = sorted(oracle.records, key=lambda r: (r.task_id, r.node, r.start))
    rb = sorted(got.records, key=lambda r: (r.task_id, r.node, r.start))
    assert len(ra) == len(rb)
    for a, b in zip(ra, rb):
        assert b.task_id == a.task_id and b.node == a.node
        assert b.start == _approx(a.start)
        assert b.end == _approx(a.end)
        assert b.cpu_work == _approx(a.cpu_work)


def random_cluster(rng, max_nodes=4, constant=False):
    n = int(rng.integers(2, max_nodes + 1))
    nodes = []
    for i in range(n):
        if constant:
            prof = [(0.0, float(rng.uniform(0.2, 3.0)))]
        else:
            n_seg = int(rng.integers(1, 4))
            breaks = np.concatenate(
                [[0.0], np.cumsum(rng.uniform(0.5, 5.0, n_seg - 1))])
            prof = [(float(t), float(rng.uniform(0.2, 3.0))) for t in breaks]
        nodes.append(SimNode(f"n{i}", prof, float(rng.uniform(0.0, 0.3))))
    return nodes


def random_policy(rng):
    if rng.random() < 0.5:
        return WorkStealing(grain=float(rng.choice([0.1, 0.25, 0.5, 1.0])))
    return SpeculativeCopies(
        quantile=float(rng.choice([0.5, 0.75, 0.9])),
        factor=float(rng.uniform(1.05, 3.0)),
        min_completed=int(rng.integers(1, 4)))


def random_tasks(rng, lo=1, hi=26):
    n_tasks = int(rng.integers(lo, hi))
    return [SimTask(float(rng.uniform(0.01, 5.0)), task_id=i)
            for i in range(n_tasks)]


# --------------------------------------------------------------------------
# randomized differential suites (engine vs. oracle at 1e-9)
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_differential_mitigated_pull(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    tasks = random_tasks(rng)
    pol = random_policy(rng)
    start = float(rng.uniform(0.0, 2.0))
    oracle = oracle_stage(nodes, [list(tasks)], pull=True, mitigation=pol,
                          start_time=start)
    got = run_stage_events(nodes, [tasks], pull=True, start_time=start,
                           mitigation=pol)
    assert_mitigated_match(oracle, got)


@given(seed=st.integers(0, 10_000))
def test_differential_mitigated_static(seed):
    """HeMT macrotasks (the paper's stale-estimate regime): random skewed
    splits, random policies, multi-segment profiles."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng)
    n = len(nodes)
    queues = [[SimTask(float(rng.uniform(0.0, 8.0)), task_id=i)]
              if rng.random() < 0.9 else [] for i in range(n)]
    pol = random_policy(rng)
    oracle = oracle_stage(nodes, [list(q) for q in queues], pull=False,
                          mitigation=pol)
    got = run_stage_events(nodes, queues, pull=False, mitigation=pol)
    assert_mitigated_match(oracle, got)
    # and the public entry points route to the same mitigated path
    assert_mitigated_match(
        oracle, run_static_stage(nodes, [list(q) for q in queues],
                                 mitigation=pol))


@given(seed=st.integers(0, 10_000))
def test_differential_run_job_mitigated(seed):
    """run_job threading event-level policies through whole jobs ==
    per-stage mitigated event loop with barriers carried by hand."""
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=bool(rng.random() < 0.7))
    n = len(nodes)
    pol = random_policy(rng)
    specs = []
    for _ in range(int(rng.integers(1, 5))):
        if rng.random() < 0.5:
            works = rng.uniform(0.0, 5.0, n)
            specs.append(StaticSpec(works=tuple(works), mitigation=pol))
        else:
            works = rng.uniform(0.01, 3.0, int(rng.integers(1, 20)))
            specs.append(PullSpec(works=tuple(works), mitigation=pol))
    run_job_cache_clear()
    sched = run_job(nodes, specs)
    t = 0.0
    for spec, summ in zip(specs, sched.stages):
        if isinstance(spec, StaticSpec):
            queues = [[SimTask(w, task_id=i)]
                      for i, w in enumerate(spec.works)]
            res = oracle_stage(nodes, queues, pull=False, mitigation=pol,
                               start_time=t)
        else:
            tasks = [SimTask(w, task_id=i) for i, w in enumerate(spec.works)]
            res = oracle_stage(nodes, [tasks], pull=True, mitigation=pol,
                               start_time=t)
        assert summ.completion == _approx(res.completion)
        assert summ.idle_time == _approx(res.idle_time)
        for nd in nodes:
            assert summ.node_finish[nd.name] == _approx(
                res.node_finish[nd.name])
        counts = {nd.name: 0 for nd in nodes}
        for r in res.records:
            counts[r.node] += 1
        assert summ.counts == counts
        t = res.completion
    assert sched.completion == _approx(t)


# --------------------------------------------------------------------------
# cancel-vs-finish ties and crafted scenarios
# --------------------------------------------------------------------------

def test_speculative_copy_beats_straggler():
    """The stale-estimate scenario: 3 fast + 1 degraded node, even HeMT
    split.  The idle fast node re-checks at the threshold instant, clones
    the straggler's macrotask, and wins."""
    nodes = [SimNode.constant(f"n{i}", s, 0.3)
             for i, s in enumerate([1.0, 1.0, 1.0, 0.25])]
    queues = [[SimTask(4.0, task_id=i)] for i in range(4)]
    pol = SpeculativeCopies(quantile=0.75, factor=1.2, min_completed=1)
    res = run_static_stage(nodes, [list(q) for q in queues], mitigation=pol)
    # fast nodes finish at 4.3; recheck at 1.2*4.3=5.16; copy on n0 runs
    # 0.3 overhead + 4.0 work -> 9.46; original would have taken 16.3
    assert res.completion == _approx(5.16 + 0.3 + 4.0)
    by_task = {}
    for r in res.records:
        by_task.setdefault(r.task_id, []).append(r)
    assert len(by_task[3]) == 1            # loser cancelled: one record
    assert by_task[3][0].node == "n0"      # the copy won
    assert_mitigated_match(
        oracle_stage(nodes, [list(q) for q in queues], pull=False,
                     mitigation=pol), res)


def test_cancel_vs_finish_tie_lower_index_wins():
    """Copy and original finish at the same instant: the engine's
    (time, node) event order lets the lower-indexed node's completion win;
    the other attempt is cancelled with no record."""
    nodes = [SimNode.constant("a", 2.0), SimNode.constant("b", 1.0)]
    # warmups both take 1s (done=[1,1], threshold 2*1); b starts task 0
    # (4 units) at t=1, finishing at 5; a re-checks at t=3, clones the
    # full 4 units at speed 2 -> also finishes at exactly 5.
    queues = [[SimTask(2.0, task_id=9)], [SimTask(1.0, task_id=8),
                                          SimTask(4.0, task_id=0)]]
    pol = SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=2)
    res = run_static_stage(nodes, [list(q) for q in queues], mitigation=pol)
    winners = [r for r in res.records if r.task_id == 0]
    assert len(winners) == 1
    assert winners[0].node == "a"          # tie: node 0 pops first
    assert winners[0].end == _approx(5.0)
    assert res.completion == _approx(5.0)
    assert_mitigated_match(
        oracle_stage(nodes, [list(q) for q in queues], pull=False,
                     mitigation=pol), res)


def test_steal_splits_at_grain_boundary():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    queues = [[SimTask(1.0, task_id=0)], [SimTask(10.0, task_id=1)]]
    pol = WorkStealing(grain=1.0)
    res = run_static_stage(nodes, [list(q) for q in queues], mitigation=pol)
    # a finishes at 1.0; b's remaining is 9.0 -> steal floor(4.5) = 4.0
    pieces = sorted((r for r in res.records if r.task_id == 1),
                    key=lambda r: r.cpu_work)
    assert [p.cpu_work for p in pieces] == [4.0, 6.0]
    # b executed 1.0 by the steal instant; 5.0 more work ends at t=6
    assert res.completion == _approx(6.0)
    assert_mitigated_match(
        oracle_stage(nodes, [list(q) for q in queues], pull=False,
                     mitigation=pol), res)


def test_mitigation_noop_on_homogeneous_cluster():
    """Zero-benefit cases: balanced split / uniform pull on identical
    nodes — mitigation must change nothing (records identical to the
    unmitigated run)."""
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.1) for i in range(4)]
    queues = [[SimTask(2.0, task_id=i)] for i in range(4)]
    base = run_static_stage(nodes, [list(q) for q in queues])
    for pol in (WorkStealing(grain=1.5),
                SpeculativeCopies(quantile=0.5, factor=1.5, min_completed=1)):
        got = run_static_stage(nodes, [list(q) for q in queues],
                               mitigation=pol)
        assert got.records == base.records
        assert got.completion == base.completion
    tasks = [SimTask(0.5, task_id=i) for i in range(13)]
    base = run_pull_stage(nodes, tasks)
    for pol in (WorkStealing(grain=0.3),
                SpeculativeCopies(quantile=0.5, factor=2.0, min_completed=3)):
        got = run_pull_stage(nodes, tasks, mitigation=pol)
        assert got.completion == _approx(base.completion)
        assert got.idle_time == _approx(base.idle_time)
        assert {r.task_id: r.node for r in got.records} \
            == {r.task_id: r.node for r in base.records}


def test_pull_tail_stealing_splits_last_task():
    """Pull mode: stealing only engages once the shared queue drains (the
    tiny-tasks tail), where an idle node halves the remaining work."""
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    tasks = [SimTask(8.0, task_id=0)]
    pol = WorkStealing(grain=1.0)
    res = run_pull_stage(nodes, tasks, mitigation=pol)
    # a starts task 0 at t=0; b idles and steals 4.0 immediately
    assert res.completion == _approx(4.0)
    assert sorted(r.cpu_work for r in res.records) == [4.0, 4.0]
    assert_mitigated_match(
        oracle_stage(nodes, [list(tasks)], pull=True, mitigation=pol), res)


# --------------------------------------------------------------------------
# validation errors
# --------------------------------------------------------------------------

def test_mitigation_accepts_effective_io():
    """The old 'mitigation requires a CPU-governed stage' ValueError is
    gone: a mitigated stage with effective I/O now runs on the event
    calendar (duplicate readers re-fetch through the flow-shared uplink —
    tests/test_speculation_io.py pins the semantics)."""
    nodes = [SimNode.constant("a", 1.0)]
    tasks = [SimTask(1.0, io_mb=5.0, datanode=0, task_id=0)]
    res = run_stage_events(nodes, [tasks], pull=True, uplink_bw=10.0,
                           mitigation=WorkStealing(grain=0.1))
    # one node, nothing to steal: completion = max(io 0.5, cpu 1.0)
    assert res.completion == _approx(1.0)
    # infinite uplink = no effective I/O: unchanged
    res = run_stage_events(nodes, [tasks], pull=True, uplink_bw=None,
                           mitigation=WorkStealing(grain=0.1))
    assert res.completion == _approx(1.0)


def test_mitigation_replica_ring_must_cover_datanodes():
    """The remaining unsupported combination raises with an accurate
    message: a replica placement whose ring does not cover every datanode
    the stage reads from (ring arithmetic would alias)."""
    from repro.core.hdfs_model import DuplicatePlacement

    nodes = [SimNode.constant("a", 1.0)]
    tasks = [SimTask(1.0, io_mb=5.0, datanode=3, task_id=0)]
    pol = SpeculativeCopies(placement=DuplicatePlacement("replica", 2))
    with pytest.raises(ValueError, match="replica placement ring"):
        run_stage_events(nodes, [tasks], pull=True, uplink_bw=10.0,
                         mitigation=pol)
    # no effective I/O: placement is never consulted, stage runs
    res = run_stage_events(nodes, [tasks], pull=True, uplink_bw=None,
                           mitigation=pol)
    assert res.completion == _approx(1.0)
    with pytest.raises(ValueError, match="n_datanodes"):
        DuplicatePlacement("replica", 1)
    with pytest.raises(ValueError, match="placement policy"):
        DuplicatePlacement("elsewhere", 4)


def test_barrier_policy_rejected_at_stage_level():
    nodes = [SimNode.constant("a", 1.0)]
    with pytest.raises(ValueError, match="event-level"):
        simulate_stage(nodes, [[SimTask(1.0, task_id=0)]], pull=True,
                       mitigation=ReskewHandoff())
    with pytest.raises(ValueError, match="StaticSpec"):
        PullSpec(n_tasks=2, task_work=1.0, mitigation=ReskewHandoff())


# --------------------------------------------------------------------------
# barrier-level re-skew hand-off (run_job) vs. naive restatement
# --------------------------------------------------------------------------

def naive_reskew_job(nodes, works_list, cutoff_factor):
    """Independent restatement of the documented barrier semantics using
    per-stage mitigation-free oracle runs + explicit clip/fold."""
    t, spans, works_list = 0.0, [], [list(w) for w in works_list]
    for k, works in enumerate(works_list):
        queues = [[SimTask(w, task_id=i)] for i, w in enumerate(works)]
        res = oracle_stage(nodes, queues, pull=False, start_time=t)
        offs = [res.node_finish[nd.name] - t for nd in nodes]
        if k + 1 < len(works_list):
            cutoff = cutoff_factor * quantile(offs, 0.5)
            residual, executed, clipped = 0.0, [], []
            for nd, off, w in zip(nodes, offs, works):
                if off > cutoff + 1e-9:
                    r = min(nd.work_between(t + cutoff, t + off), w)
                    residual += r
                    executed.append(w - r)
                    clipped.append(cutoff)
                else:
                    executed.append(w)
                    clipped.append(off)
            if residual > 0.0:
                vhat = [x / c if c > 0 else 0.0
                        for x, c in zip(executed, clipped)]
                works_list[k + 1] = fold_residual(works_list[k + 1],
                                                  residual, vhat)
                offs = clipped
        spans.append(max(offs))
        t += max(offs)
    return t, spans


@given(seed=st.integers(0, 10_000))
def test_reskew_handoff_matches_naive_restatement(seed):
    rng = np.random.default_rng(seed)
    nodes = random_cluster(rng, constant=bool(rng.random() < 0.6))
    n = len(nodes)
    n_stages = int(rng.integers(2, 5))
    works_list = [rng.uniform(0.1, 6.0, n).tolist() for _ in range(n_stages)]
    pol = ReskewHandoff(cutoff_factor=float(rng.uniform(1.0, 2.0)))
    specs = [StaticSpec(works=tuple(w), mitigation=pol) for w in works_list]
    run_job_cache_clear()
    sched = run_job(nodes, specs)
    total, spans = naive_reskew_job(nodes, works_list, pol.cutoff_factor)
    assert sched.completion == _approx(total)
    for summ, span in zip(sched.stages, spans):
        assert summ.span == _approx(span)


def test_reskew_noop_when_balanced():
    """Homogeneous finishes: cutoff >= max finish, nothing is cut, the
    next stage's split is untouched."""
    nodes = [SimNode.constant(f"n{i}", 1.0, 0.1) for i in range(3)]
    spec = StaticSpec(works=(2.0, 2.0, 2.0), mitigation=ReskewHandoff(1.25))
    run_job_cache_clear()
    sched = run_job(nodes, [spec, spec])
    plain = run_job(nodes, [StaticSpec(works=(2.0, 2.0, 2.0))] * 2)
    assert sched.completion == _approx(plain.completion)


def test_reskew_improves_straggler_job():
    """Stale split on a degraded node: folding the straggler's residual
    forward beats running every stage to the straggler's own finish."""
    nodes = [SimNode.constant(f"n{i}", s, 0.1)
             for i, s in enumerate([1.0, 1.0, 0.2])]
    works = (3.0, 3.0, 3.0)                 # stale: believes n2 is fast
    pol = ReskewHandoff(cutoff_factor=1.5)
    run_job_cache_clear()
    mitigated = run_job(nodes, [StaticSpec(works=works, mitigation=pol)] * 4)
    plain = run_job(nodes, [StaticSpec(works=works)] * 4)
    assert mitigated.completion < plain.completion


# --------------------------------------------------------------------------
# scheduler / MultiStageJob / policy-object surfaces
# --------------------------------------------------------------------------

def test_adaptive_scheduler_with_stealing_rescues_first_job():
    """OA-HeMT's blind first job (even split) on a skewed cluster: work
    stealing bounds the damage; later jobs learn the skew either way."""
    speeds = [1.0, 1.0, 0.25]

    def factory(_k):
        return [SimNode.constant(f"e{i}", v, 0.05)
                for i, v in enumerate(speeds)]

    plain = AdaptiveHeMTScheduler([f"e{i}" for i in range(3)])
    plain.run_simulated_sequence(factory, 3, total_work=9.0)
    mitigated = AdaptiveHeMTScheduler([f"e{i}" for i in range(3)],
                                      mitigation=WorkStealing(grain=0.25))
    mitigated.run_simulated_sequence(factory, 3, total_work=9.0)
    assert mitigated.history[0].completion < plain.history[0].completion
    # estimator still converges: last job near the balanced optimum
    opt = 9.0 / sum(speeds)
    assert mitigated.history[-1].completion == pytest.approx(opt, rel=0.2)


def test_multistage_job_threads_mitigation():
    nodes = [SimNode.constant(f"n{i}", s, 0.05)
             for i, s in enumerate([1.0, 1.0, 0.25])]
    job = MultiStageJob(stage_works=[6.0] * 3)
    weights = [1.0, 1.0, 1.0]               # stale: even skew
    total_plain, _ = job.run(nodes, weights)
    total_steal, _ = job.run(nodes, weights,
                             mitigation=WorkStealing(grain=0.25))
    total_reskew, _ = job.run(nodes, weights,
                              mitigation=ReskewHandoff(cutoff_factor=1.25))
    assert total_steal < total_plain
    assert total_reskew < total_plain
    # records mode agrees with the spec path for event-level policies
    total_rec, results = job.run(nodes, weights, records=True,
                                 mitigation=WorkStealing(grain=0.25))
    assert total_rec == _approx(total_steal)
    assert all(res.records for res in results)


def test_policy_objects_hashable_and_validated():
    assert hash(SpeculativeCopies()) == hash(SpeculativeCopies())
    assert hash(WorkStealing(grain=0.5)) == hash(WorkStealing(grain=0.5))
    assert hash(ReskewHandoff()) == hash(ReskewHandoff())
    with pytest.raises(ValueError):
        WorkStealing(grain=0.0)
    with pytest.raises(ValueError):
        SpeculativeCopies(factor=0.0)
    with pytest.raises(ValueError):
        SpeculativeCopies(min_completed=0)
    with pytest.raises(ValueError):
        ReskewHandoff(cutoff_factor=0.9)
    with pytest.raises(ValueError):
        quantile([], 0.5)
    assert quantile([3.0, 1.0, 2.0], 0.5) == 2.0
    assert quantile([1.0, 2.0], 0.75) == _approx(1.75)


def test_fleet_monitor_speculation_candidates():
    from repro.runtime.ft import FleetMonitor
    m = FleetMonitor(["a", "b"], speculation=SpeculativeCopies(
        quantile=0.5, factor=2.0, min_completed=1))
    done = [1.0, 1.2]
    assert m.speculation_candidates(1.5, done, {"t2": 0.5}) == []
    assert m.speculation_candidates(3.0, done, {"t2": 0.5}) == ["t2"]


def test_legacy_speculative_copies_helper():
    """Away from the threshold boundary the legacy helper behaves as it
    always did; the boundary itself is unified with the engine (see
    test_trigger_boundary_unified_across_exposures)."""
    from repro.core.straggler import speculative_copies
    done = {0: 1.0, 1: 1.2, 2: None}
    assert speculative_copies(done, 1.5, {2: 0.5}) == []
    assert speculative_copies(done, 3.0, {2: 0.5}) == [2]


@pytest.mark.parametrize("factor,q,done", [
    (2.0, 0.5, [1.0, 1.2]),
    (1.5, 0.75, [0.5, 2.0, 3.0]),
    (1.2, 0.5, [4.0]),
])
def test_trigger_boundary_unified_across_exposures(factor, q, done):
    """A task running EXACTLY factor * quantile(done) gets the same
    at-threshold verdict from all three exposures: the legacy
    straggler helper, FleetMonitor.speculation_candidates, and the
    engine-side SpeculativeCopies trigger — plus just-under stays False
    everywhere."""
    from repro.core.straggler import speculative_copies
    from repro.runtime.ft import FleetMonitor

    pol = SpeculativeCopies(quantile=q, factor=factor, min_completed=1)
    thr = pol.threshold(done)
    eps = 1e-6 * thr
    for elapsed, verdict in ((thr, True), (thr - eps, False)):
        now = 10.0
        st = now - elapsed
        # engine-side rule (run_stage_events applies it via offer())
        assert pol.should_speculate(done, elapsed) is verdict
        act = pol.offer(done, [RunningAttempt(0, 7, st, 4.0, 1.0, False)],
                        now)
        assert (act is not None) is verdict
        # runtime monitor
        mon = FleetMonitor(["a"], speculation=pol)
        got = mon.speculation_candidates(now, done, {"t": st})
        assert (got == ["t"]) is verdict
        # legacy helper exposes quantile 0.5 only
        if q == 0.5:
            legacy = speculative_copies({i: d for i, d in enumerate(done)},
                                        now, {9: st},
                                        timeout_factor=factor)
            assert (legacy == [9]) is verdict


def test_bench_speculation_reproduces_paper_ordering():
    """Acceptance row: learned-capacity HeMT plus cheap mitigation beats
    both pure baselines under stale estimates and under burstable-credit
    exhaustion (benchmarks/bench_speculation.py scenarios)."""
    from benchmarks.bench_speculation import scenario_completions

    for scenario in ("stale", "burstable"):
        c = scenario_completions(scenario)
        best = min(c["hemt_spec"], c["hemt_steal"])
        assert best < c["homt"] < c["hemt"], (scenario, c)
        assert c["hemt_spec"] < c["hemt"]
        assert c["hemt_steal"] < c["hemt"]
        assert c["hemt_reskew"] < c["hemt"]


def test_pagerank_job_threads_mitigation():
    """Workload surface: a skewed-hash PageRank whose learned weights went
    stale (one node degraded) recovers most of the loss with stealing,
    and the math is unchanged."""
    from repro.workloads.pagerank import PageRankJob, random_graph

    src, dst = random_graph(300, 4, seed=3)
    # straggler work must dwarf the per-task overhead for stealing to pay
    # (a stolen sliver still costs a full launch)
    nodes = [SimNode.constant(f"e{i}", s, 0.01)
             for i, s in enumerate([1.0, 1.0, 0.25])]
    stale_weights = [1.0, 1.0, 1.0]
    plain = PageRankJob(src, dst, 300, nodes, mode="hemt",
                        weights=stale_weights, work_per_edge=2e-3)
    ranks_plain = plain.run(3)
    mitigated = PageRankJob(src, dst, 300, nodes, mode="hemt",
                            weights=stale_weights, work_per_edge=2e-3,
                            mitigation=WorkStealing(grain=0.05))
    ranks_mit = mitigated.run(3)
    assert mitigated.total_time() < plain.total_time()
    np.testing.assert_allclose(ranks_mit, ranks_plain, rtol=1e-6)


def test_adaptive_scheduler_with_speculation_still_learns():
    """A straggler whose every attempt is cancelled by a winning copy
    leaves no records; the scheduler must still credit its partial
    progress so the estimator observes the degraded speed (else the
    adaptive loop stays pinned at the blind even split forever)."""
    speeds = [1.0, 1.0, 0.25]

    def factory(_k):
        return [SimNode.constant(f"e{i}", v, 0.05)
                for i, v in enumerate(speeds)]

    sched = AdaptiveHeMTScheduler(
        [f"e{i}" for i in range(3)],
        mitigation=SpeculativeCopies(quantile=0.5, factor=1.2,
                                     min_completed=1))
    sched.run_simulated_sequence(factory, 5, total_work=9.0)
    opt = 9.0 / sum(speeds)
    assert sched.history[-1].completion == pytest.approx(opt, rel=0.25)
    # and it converged: clearly better than the blind even split's 6.7+
    assert sched.history[-1].completion < 5.5


def test_multistage_records_mode_rejects_reskew_up_front():
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 0.5)]
    job = MultiStageJob(stage_works=[4.0] * 2)
    with pytest.raises(ValueError, match="records=False"):
        job.run(nodes, [1.0, 1.0], records=True,
                mitigation=ReskewHandoff(cutoff_factor=1.25))
