"""K-Means / PageRank (paper §7): partition-invariance of the math and the
paper's completion-time ordering."""
import numpy as np
import pytest

from repro.core.simulator import SimNode
from repro.workloads.kmeans import KMeansJob, kmeans_reference
from repro.workloads.pagerank import PageRankJob, pagerank_reference, random_graph


def _nodes(overhead=0.05):
    return [SimNode.constant("a", 1.0, overhead),
            SimNode.constant("b", 0.4, overhead)]


def test_kmeans_partitioning_invariance():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(400, 4))
    ref = kmeans_reference(pts, k=5, iters=8, seed=3)
    job = KMeansJob(pts, 5, _nodes(), mode="hemt", weights=[1.0, 0.4], seed=3)
    got = np.asarray(job.run(8))
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_kmeans_hemt_faster_than_even():
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(1400, 4))
    times = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("even", {}), ("homt", {"n_tasks": 16})):
        job = KMeansJob(pts, 4, _nodes(), mode=mode, seed=1, **kw)
        job.run(6)
        times[mode] = job.total_time()
    assert times["hemt"] < times["even"]
    assert times["hemt"] < times["homt"]     # per-task overhead regime


def test_pagerank_partitioning_invariance():
    src, dst = random_graph(300, 5, seed=2)
    ref = pagerank_reference(src, dst, 300, iters=10)
    job = PageRankJob(src, dst, 300, _nodes(), mode="hemt",
                      weights=[1.0, 0.4])
    got = job.run(10)
    np.testing.assert_allclose(got, ref, atol=1e-6)
    assert got.sum() == pytest.approx(1.0, abs=0.2)


def test_pagerank_skewed_buckets_match_capacity():
    src, dst = random_graph(4000, 4, seed=0)
    job = PageRankJob(src, dst, 4000, _nodes(), mode="hemt",
                      weights=[1.0, 0.4])
    sizes = np.bincount(job.owner, minlength=2)
    assert sizes[0] / sizes.sum() == pytest.approx(1.0 / 1.4, abs=0.02)


def test_pagerank_hemt_beats_homt_short_stages():
    """Fig 18: short iterations + overhead -> microtasking loses."""
    src, dst = random_graph(3000, 4, seed=4)
    t = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("homt", {"n_tasks": 32}), ("even", {})):
        job = PageRankJob(src, dst, 3000, _nodes(overhead=0.1), mode=mode, **kw)
        job.run(10)
        t[mode] = job.total_time()
    assert t["hemt"] < t["homt"] and t["hemt"] < t["even"]
