"""Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing).

The Pallas kernels target TPU; on this CPU container we time the *XLA
twin* of each kernel (chunked attention / SSD scan / Algorithm 1 bucket
map) and allclose-check the Pallas interpret path, so the numbers are a
functional sanity record, not TPU performance."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import BenchRow, timed
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rows() -> List[BenchRow]:
    out = []
    # attention: XLA chunked path timing + pallas-vs-ref error
    from repro.models.attention import chunked_attention
    q = jax.random.normal(KEY, (1, 512, 8, 64))
    k = jax.random.normal(KEY, (1, 512, 2, 64))
    v = jax.random.normal(KEY, (1, 512, 2, 64))
    f = jax.jit(lambda q, k, v: chunked_attention(
        q, k, v, causal=True, window=0, scale=0.125))
    f(q, k, v)  # warm
    _, us = timed(lambda: jax.block_until_ready(f(q, k, v)))
    small = [x[:, :64] for x in (q, k, v)]
    pall = ops.flash_attention(*small, causal=True, block_q=32, block_k=32)
    want = jnp.swapaxes(ref.flash_attention_ref(
        *(jnp.swapaxes(x, 1, 2) for x in small), causal=True), 1, 2)
    err = float(jnp.max(jnp.abs(pall - want)))
    out.append(BenchRow("kernel/attention_512", us,
                        f"pallas_interpret_maxerr={err:.1e}"))

    # ssd scan
    x = jax.random.normal(KEY, (1, 512, 8, 32)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(KEY, (1, 512, 8)))
    a_log = jnp.log(jnp.linspace(1., 8., 8))
    B = jax.random.normal(KEY, (1, 512, 2, 16)) * 0.3
    C = jax.random.normal(KEY, (1, 512, 2, 16)) * 0.3
    from repro.models.ssm import ssd_chunked
    g = jax.jit(lambda *a: ssd_chunked(*a, chunk=64))
    g(x, dt, a_log, B, C)
    _, us = timed(lambda: jax.block_until_ready(g(x, dt, a_log, B, C)[0]))
    y_p, f_p = ops.ssd_scan(x[:, :64], dt[:, :64], a_log, B[:, :64],
                            C[:, :64], chunk=32)
    y_r, f_r = ref.ssd_scan_ref(x[:, :64], dt[:, :64], a_log, B[:, :64],
                                C[:, :64])
    err = float(jnp.max(jnp.abs(y_p - y_r)))
    out.append(BenchRow("kernel/ssd_512", us,
                        f"pallas_interpret_maxerr={err:.1e}"))

    # Algorithm 1 bucket map
    caps = jnp.asarray([715, 285], jnp.int32)      # 1.0 : 0.4
    hashes = jax.random.randint(KEY, (1 << 16,), 0, 1 << 30)
    bk = ops.skewed_bucket(hashes, caps)
    br = ref.skewed_bucket_ref(hashes, caps)
    h = jax.jit(ref.skewed_bucket_ref)
    h(hashes, caps)
    _, us = timed(lambda: jax.block_until_ready(h(hashes, caps)))
    out.append(BenchRow("kernel/skewed_bucket_64k", us,
                        f"pallas_match={bool((bk == br).all())}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
