"""Fig 8 / Fig 9: executors provisioned at 1.0 and 0.4 cores.

Fig 8: OA-HeMT learns the optimal split online in ~2 trials (map-stage
time drops to the a-priori optimum of Fig 9).
Fig 9: the HomT U-curve over task counts vs HeMT hitting the minimum
without search (per-task overhead makes both ends of the U bad)."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, timed
from repro.core.scheduler import AdaptiveHeMTScheduler, HomTScheduler
from repro.core.simulator import SimNode, SimTask, run_static_stage

WORK = 140.0
OVERHEAD = 0.4


def _nodes():
    return [SimNode.constant("a", 1.0, OVERHEAD),
            SimNode.constant("b", 0.4, OVERHEAD)]


def rows() -> List[BenchRow]:
    out = []
    # ---- Fig 8: online learning -------------------------------------------
    sched = AdaptiveHeMTScheduler(["a", "b"], alpha=0.0)
    hist, us = timed(sched.run_simulated_sequence, lambda k: _nodes(),
                     6, WORK, repeat=1)
    for k in (0, 1, 2, 5):
        out.append(BenchRow(
            f"fig8/trial{k}", us / 6,
            f"stage_s={hist[k].completion:.1f};"
            f"split={hist[k].split[0]:.0f}:{hist[k].split[1]:.0f}"))
    opt = WORK / 1.4 + OVERHEAD
    out.append(BenchRow("fig8/optimum", 0.0, f"stage_s={opt:.1f}"))

    # ---- Fig 9: HomT U-curve vs HeMT ---------------------------------------
    for n_tasks in [2, 4, 8, 16, 32, 64, 128]:
        res, _ = timed(HomTScheduler(n_tasks).run_simulated, _nodes(), WORK,
                       repeat=1)
        out.append(BenchRow(f"fig9/homt_tasks{n_tasks}", 0.0,
                            f"stage_s={res.completion:.1f}"))
    # HeMT: one macrotask per node, 1:0.4 informed split
    res = run_static_stage(_nodes(), [[SimTask(WORK / 1.4, task_id=0)],
                                      [SimTask(WORK * 0.4 / 1.4, task_id=1)]])
    out.append(BenchRow("fig9/hemt", 0.0, f"stage_s={res.completion:.1f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
