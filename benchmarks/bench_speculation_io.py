"""Claim 2 x mitigation cross experiment: I/O-aware speculation & stealing
through the flow-shared uplink (paper §3 Claim 2, §8 mitigation survey;
``repro.core.speculation`` I/O-aware duplicates).

One scenario, the paper's two failure axes at once: a shuffle stage whose
input sits behind a shared datanode uplink (Claim 2's contention regime)
on a cluster whose capacity estimates went stale (one node degraded to a
quarter speed after the HeMT split was learned).  Variants on identical
stages:

* **homt_io**: fine microtasks through the shared queue.  Pull
  self-balances the straggler away, but every microtask pays the launch
  overhead and adds a concurrent same-block reader — the tiny-tasks
  granularity tax the paper's Claim 2 quantifies, and at this overhead the
  worst policy of the sweep.
* **hemt_io**: stale even macrotasks, unmitigated.  The straggler strands
  a quarter of the work; everything waits at the barrier.
* **hemt_io_spec / hemt_io_spec_replica**: the same stale split rescued by
  a speculative copy that must RE-FETCH the straggler's input as a new
  flow through the uplink model (same datanode vs ring-adjacent replica
  placement, ``repro.core.hdfs_model.DuplicatePlacement``).
* **hemt_io_steal**: work stealing; the thief re-fetches the stolen
  range's byte share.

The paper-predicted ordering — mitigated < stale unmitigated HeMT < HomT —
is returned by ``scenario_completions`` and pinned by the tier-1 suite
(tests/test_speculation_io.py); the timed rows land in the
``speculation_io`` section of BENCH_sim.json and are gated by ``run.py
--check`` alongside the sim_engine rows.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear,
)
from repro.core.hdfs_model import DuplicatePlacement
from repro.core.simulator import SimNode
from repro.core.speculation import SpeculativeCopies, WorkStealing

TOTAL_WORK = 16.0
IO_TOTAL_MB = 32.0          # stage input behind the shared uplink
UPLINK_BW = 4.0             # MB/s per datanode uplink
DATANODE = 0
OVERHEAD = 0.6              # the tiny-tasks regime where HomT's tax bites
N_MICRO = 128               # HomT microtask count
STAGES = 3                  # stages per job (mitigation compounds)

SPEC = SpeculativeCopies(quantile=0.75, factor=1.2, min_completed=1)
SPEC_REPLICA = SpeculativeCopies(quantile=0.75, factor=1.2, min_completed=1,
                                 placement=DuplicatePlacement("replica", 2))
STEAL = WorkStealing(grain=0.25)


def _stale_nodes() -> List[SimNode]:
    """Estimates said [1, 1, 1, 1]; one node has since degraded to 0.25."""
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate([1.0, 1.0, 1.0, 0.25])]


def _variants() -> Dict[str, List]:
    even = (TOTAL_WORK / 4,) * 4
    homt = PullSpec(n_tasks=N_MICRO, task_work=TOTAL_WORK / N_MICRO,
                    io_mb=IO_TOTAL_MB / N_MICRO, datanode=DATANODE)
    return {
        "homt_io": [homt] * STAGES,
        "hemt_io": [StaticSpec(works=even, io_mb=IO_TOTAL_MB,
                               datanode=DATANODE)] * STAGES,
        "hemt_io_spec": [StaticSpec(works=even, io_mb=IO_TOTAL_MB,
                                    datanode=DATANODE,
                                    mitigation=SPEC)] * STAGES,
        "hemt_io_spec_replica": [StaticSpec(works=even, io_mb=IO_TOTAL_MB,
                                            datanode=DATANODE,
                                            mitigation=SPEC_REPLICA)
                                 ] * STAGES,
        "hemt_io_steal": [StaticSpec(works=even, io_mb=IO_TOTAL_MB,
                                     datanode=DATANODE,
                                     mitigation=STEAL)] * STAGES,
    }


def scenario_completions() -> Dict[str, float]:
    """Completion time of the multi-stage job per policy variant."""
    nodes = _stale_nodes()
    out = {}
    for name, specs in _variants().items():
        run_job_cache_clear()
        out[name] = run_job(nodes, specs, uplink_bw=UPLINK_BW).completion
    return out


def rows() -> List[BenchRow]:
    out = []
    comps = {}
    for name, specs in _variants().items():

        def _solve(s=specs):
            run_job_cache_clear()   # time the solve, not the LRU hit
            return run_job(_stale_nodes(), s, uplink_bw=UPLINK_BW)

        sched, us = timed(_solve, repeat=5)
        comps[name] = sched.completion
        out.append(BenchRow(
            f"speculation_io/stale_{name}", us,
            f"completion={sched.completion:.3f};stages={STAGES}"))
    best = min(comps["hemt_io_spec"], comps["hemt_io_spec_replica"],
               comps["hemt_io_steal"])
    out.append(BenchRow(
        "speculation_io/stale_ordering", 0.0,
        f"mitigated_beats_stale={best < comps['hemt_io']};"
        f"stale_beats_homt={comps['hemt_io'] < comps['homt_io']};"
        f"best={min(comps, key=comps.get)}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
