"""Claim 1: pull-based HomT idle time <= one task duration on the slowest
node — simulated idle vs analytic bound over heterogeneous clusters."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.straggler import verify_claim1


def rows() -> List[BenchRow]:
    out = []
    rng = np.random.default_rng(0)
    for n_nodes, n_tasks in [(2, 8), (4, 32), (8, 64), (16, 256)]:
        speeds = rng.uniform(0.2, 2.0, n_nodes).tolist()
        (idle, bound, ok), us = timed(verify_claim1, 200.0, n_tasks, speeds)
        out.append(BenchRow(
            f"claim1/nodes{n_nodes}_tasks{n_tasks}", us,
            f"idle={idle:.3f};bound={bound:.3f};holds={ok};"
            f"tightness={idle / bound:.2f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
