"""Shared benchmark plumbing: every module exposes rows() -> [BenchRow]."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List


@dataclass
class BenchRow:
    name: str
    us_per_call: float          # wall time of the measured operation
    derived: str                # paper-comparable derived quantities

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"

    def as_dict(self) -> dict:
        return {"name": self.name, "us_per_call": round(self.us_per_call, 1),
                "derived": self.derived}


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """Returns (result, us_per_call)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def print_rows(rows: List[BenchRow]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
