"""Online-adaptive HeMT under AR(1)-drifting node speeds (paper §5).

The paper's complete OA-HeMT story: capacity estimates are learned across
program barriers and every stage's split is re-planned from them
(``engine.run_job(adaptive=AdaptivePlan(...))``).  This benchmark puts the
loop in the regime where adaptivity pays — node speeds *drift* while the
job runs, so any static split goes stale — and reproduces the §5 ordering:

    oracle  <~  OA-HeMT  <  HomT  <  stale static HeMT

* every node starts at speed 1.0 (that is what the stale estimates were
  learned on) and its speed then drifts by a per-interval AR(1) process
  toward a node-specific mean, so heterogeneity *emerges* while the job
  runs;
* **stale**: keeps the even time-0 split for all stages (static HeMT with
  estimates that were true once);
* **homt**: microtasks over the shared queue — self-balancing, but paying
  the per-task overhead tax on every one of ``N_MICRO`` tasks;
* **oa**: ``AdaptivePlan`` re-splits every stage at its barrier from the
  AR(1)-estimated speeds observed so far (first stage: the same stale even
  split — the paper's k=1 rule);
* **oa_bad** / **oa_reskew**: the adaptive loop handed a *mis-skewed*
  first split (proportions reversed against the drift targets), without /
  with barrier-level ``ReskewHandoff`` composed in — the cut straggler's
  residual is folded into the next stage and re-skewed together with the
  re-planned split, so reskew rescues the bad cold start while the
  estimator converges;
* **oracle**: per-stage clairvoyant split — at each barrier the works are
  chosen so every node finishes simultaneously given the *true* future
  speed profiles (bisection on the balanced finish time).  This is the
  completion-time floor for per-stage static splits.

``drift_scenario()`` returns completions plus the converged tail spans so
the tier-1 suite pins the ordering and the OA-vs-oracle gap (a few
percent); rows land in the ``oa_hemt`` section of BENCH_sim.json.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.engine import (
    AdaptivePlan, PullSpec, StaticSpec, run_job, run_job_cache_clear,
)
from repro.core.simulator import SimNode, SimTask, run_static_stage
from repro.core.speculation import ReskewHandoff

N_NODES = 4
MU = (1.4, 1.0, 0.7, 0.4)   # drift targets: heterogeneity emerges over time
RHO = 0.6                   # AR(1) pull toward the mean per interval
SIGMA = 0.02                # per-interval speed noise
DT = 40.0                   # seconds between speed re-samples
HORIZON = 6000.0            # profile length (>> any variant's completion)
OVERHEAD = 0.3              # per-task scheduling/launch cost (seconds)
W_STAGE = 160.0             # work per stage (~46 s per stage at sum(MU))
N_STAGES = 12
N_MICRO = 64                # HomT microtask count per stage
TAIL = 6                    # "converged" stages for the OA-vs-oracle gap
ALPHA = 0.2                 # AR(1) forgetting factor of the OA estimator


def drift_nodes(seed: int = 0) -> List[SimNode]:
    """Piecewise-constant AR(1) speed walks: v(0)=1.0 for every node, then
    ``v <- mu + RHO * (v - mu) + SIGMA * eps`` every DT seconds."""
    rng = np.random.default_rng(seed)
    nodes = []
    n_seg = int(HORIZON / DT)
    for i, mu in enumerate(MU):
        v = 1.0
        profile: List[Tuple[float, float]] = [(0.0, v)]
        for k in range(1, n_seg):
            v = mu + RHO * (v - mu) + SIGMA * rng.standard_normal()
            v = float(np.clip(v, 0.1, 2.0))
            profile.append((k * DT, v))
        nodes.append(SimNode(f"n{i}", profile, OVERHEAD))
    return nodes


def _oracle_split(nodes: List[SimNode], t: float, total: float,
                  ) -> List[float]:
    """Clairvoyant balanced split at barrier ``t``: bisect the common
    finish time T with ``sum_i work_between(t + oh_i, T) = total``, then
    give each node exactly what it can execute by T."""
    lo, hi = t, t + total / min(nd.speed_at(t) for nd in nodes) + 1.0
    while sum(nd.work_between(t + nd.task_overhead, hi) for nd in nodes) \
            < total:
        hi += (hi - t)
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        cap = sum(nd.work_between(t + nd.task_overhead, mid) for nd in nodes)
        if cap >= total:
            hi = mid
        else:
            lo = mid
    return [nd.work_between(t + nd.task_overhead, hi) for nd in nodes]


def oracle_completion(nodes: List[SimNode], summaries_out: List[float],
                      ) -> float:
    """Per-stage clairvoyant HeMT: re-split at every barrier from the TRUE
    profiles; ``summaries_out`` collects per-stage spans."""
    t = 0.0
    for _ in range(N_STAGES):
        works = _oracle_split(nodes, t, W_STAGE)
        res = run_static_stage(
            nodes, [[SimTask(w, task_id=i)] for i, w in enumerate(works)],
            start_time=t)
        summaries_out.append(res.completion - t)
        t = res.completion
    return t


def drift_scenario(seed: int = 0) -> Dict[str, Dict]:
    """Completion + per-stage spans for every variant on the same drifting
    cluster.  Returns {variant: {"completion", "spans", "tail_mean"}}."""
    even = (W_STAGE / N_NODES,) * N_NODES
    out: Dict[str, Dict] = {}

    def put(name: str, completion: float, spans: List[float]) -> None:
        out[name] = {"completion": completion, "spans": list(spans),
                     "tail_mean": float(np.mean(spans[-TAIL:]))}

    homt = PullSpec(n_tasks=N_MICRO, task_work=W_STAGE / N_MICRO)
    sched = run_job(drift_nodes(seed), [homt] * N_STAGES)
    put("homt", sched.completion, [s.span for s in sched.stages])

    sched = run_job(drift_nodes(seed), [StaticSpec(works=even)] * N_STAGES)
    put("stale", sched.completion, [s.span for s in sched.stages])

    sched = run_job(drift_nodes(seed), [StaticSpec(works=even)] * N_STAGES,
                    adaptive=AdaptivePlan(alpha=ALPHA))
    put("oa", sched.completion, [s.span for s in sched.stages])

    # mis-skewed cold start: proportions reversed against the drift
    # targets, so the first stage has genuine stragglers for reskew to cut
    rev = tuple(W_STAGE * m / sum(MU) for m in reversed(MU))
    sched = run_job(drift_nodes(seed), [StaticSpec(works=rev)] * N_STAGES,
                    adaptive=AdaptivePlan(alpha=ALPHA))
    put("oa_bad", sched.completion, [s.span for s in sched.stages])

    reskew = StaticSpec(works=rev, mitigation=ReskewHandoff(1.3))
    sched = run_job(drift_nodes(seed), [reskew] * N_STAGES,
                    adaptive=AdaptivePlan(alpha=ALPHA))
    put("oa_reskew", sched.completion, [s.span for s in sched.stages])

    spans: List[float] = []
    put("oracle", oracle_completion(drift_nodes(seed), spans), spans)
    return out


def rows() -> List[BenchRow]:
    out = []
    scen: Dict[str, Dict] = {}

    def _run():
        run_job_cache_clear()
        return drift_scenario()

    scen, us = timed(_run, repeat=3)
    total_us = us
    for name in ("oracle", "oa", "oa_bad", "oa_reskew", "homt", "stale"):
        v = scen[name]
        out.append(BenchRow(
            f"oa_hemt/drift_{name}", 0.0,
            f"completion={v['completion']:.2f};"
            f"tail_span={v['tail_mean']:.3f}"))
    gap = scen["oa"]["tail_mean"] / scen["oracle"]["tail_mean"] - 1.0
    out.append(BenchRow(
        "oa_hemt/drift_ordering", total_us,
        f"oa_vs_oracle_tail_gap={gap:.4f};"
        f"oa_beats_homt={scen['oa']['completion'] < scen['homt']['completion']};"
        f"oa_beats_stale={scen['oa']['completion'] < scen['stale']['completion']};"
        f"homt_beats_stale={scen['homt']['completion'] < scen['stale']['completion']};"
        f"reskew_rescues_cold_start="
        f"{scen['oa_reskew']['completion'] < scen['oa_bad']['completion']}"))

    # adaptive run_job throughput on a constant-speed cluster: 64 barriers,
    # every stage re-planned + re-solved (no O(n) shift reuse possible)
    nodes = [SimNode.constant(f"c{i}", s, 0.05)
             for i, s in enumerate((1.0, 0.8, 0.6, 0.4))]
    specs = [StaticSpec(works=(4.0, 4.0, 4.0, 4.0))] * 64

    def _adaptive_job():
        run_job_cache_clear()
        return run_job(nodes, specs, adaptive=AdaptivePlan(alpha=0.3))

    sched, us = timed(_adaptive_job, repeat=5)
    out.append(BenchRow(
        "oa_hemt/adaptive_job_64x4", us,
        f"completion={sched.completion:.2f};stages=64"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
