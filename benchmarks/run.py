"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                 # all, CSV to stdout
  PYTHONPATH=src python -m benchmarks.run fig17           # substring filter
  PYTHONPATH=src python -m benchmarks.run --json          # + BENCH_sim.json
  PYTHONPATH=src python -m benchmarks.run --json out.json
  PYTHONPATH=src python -m benchmarks.run --check         # CI perf gate

``--json`` persists the perf-trajectory rows — simulator engine throughput
at 1k/10k/100k tasks (benchmarks.bench_sim_engine) and the kernel rows
(benchmarks.bench_kernels) — so successive PRs can diff BENCH_sim.json.

``--check [PATH]`` re-runs only the gated sections — the sim_engine,
speculation_io, faults, resident, serving, and batched rows — and exits
non-zero if any timed row
regressed by more than the threshold against the committed baseline (or
vanished from the fresh run) — the ROADMAP CI gate.  The
threshold defaults to 2x and can be overridden per environment —
``--threshold 4`` beats the ``BENCH_CHECK_THRESHOLD`` env var beats the
default — because hardcoded headroom is wrong for noisy shared CI
runners.  Derived-only rows (us_per_call == 0) are skipped; a PR that
intentionally changes the row set regenerates the baseline with
``--json`` in the same change.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

MODULES = [
    "benchmarks.bench_claim1",
    "benchmarks.bench_fig5_network",
    "benchmarks.bench_fig7_adaptive",
    "benchmarks.bench_fig8_provisioned",
    "benchmarks.bench_fig13_burstable",
    "benchmarks.bench_fig17_kmeans",
    "benchmarks.bench_fig18_pagerank",
    "benchmarks.bench_hemt_dp",
    "benchmarks.bench_speculation",
    "benchmarks.bench_speculation_io",
    "benchmarks.bench_faults",
    "benchmarks.bench_resident",
    "benchmarks.bench_serving",
    "benchmarks.bench_oa_hemt",
    "benchmarks.bench_sim_engine",
    "benchmarks.bench_batched",
    "benchmarks.bench_kernels",
]

# modules whose rows land in the --json perf-trajectory file
JSON_SECTIONS = {
    "benchmarks.bench_speculation": "speculation",
    "benchmarks.bench_speculation_io": "speculation_io",
    "benchmarks.bench_faults": "faults",
    "benchmarks.bench_resident": "resident",
    "benchmarks.bench_serving": "serving",
    "benchmarks.bench_oa_hemt": "oa_hemt",
    "benchmarks.bench_sim_engine": "sim",
    "benchmarks.bench_batched": "batched",
    "benchmarks.bench_kernels": "kernels",
}

# sections the --check gate re-runs live and compares against the baseline
GATED_SECTIONS = {
    "sim": "benchmarks.bench_sim_engine",
    "speculation_io": "benchmarks.bench_speculation_io",
    "faults": "benchmarks.bench_faults",
    "resident": "benchmarks.bench_resident",
    "serving": "benchmarks.bench_serving",
    "batched": "benchmarks.bench_batched",
}

DEFAULT_THRESHOLD = 2.0


def resolve_threshold(cli: "float | None" = None) -> float:
    """--check regression threshold: CLI flag > BENCH_CHECK_THRESHOLD env
    var > the 2x default.  A malformed, non-positive, or NaN value is a
    configuration error, not something to silently paper over — a zero or
    NaN threshold would make the gate always-fail or always-pass."""
    if cli is not None:
        return _valid_threshold(float(cli), f"--threshold {cli}")
    env = os.environ.get("BENCH_CHECK_THRESHOLD")
    if env is None or env == "":
        return DEFAULT_THRESHOLD
    try:
        val = float(env)
    except ValueError:
        raise SystemExit(
            f"BENCH_CHECK_THRESHOLD={env!r} is not a number") from None
    return _valid_threshold(val, f"BENCH_CHECK_THRESHOLD={env!r}")


def _valid_threshold(val: float, label: str) -> float:
    if val != val:                            # NaN: every comparison False
        raise SystemExit(f"{label} is NaN")
    if val <= 0.0:
        raise SystemExit(f"{label} must be positive")
    return val


def compare_rows(baseline_rows, fresh_rows,
                 threshold: float = DEFAULT_THRESHOLD):
    """Regression messages for fresh sim_engine rows vs. a baseline.

    A baseline row regresses when its fresh ``us_per_call`` exceeds
    ``threshold`` times the committed one, or when it is missing from the
    fresh run (renames must regenerate the baseline in the same PR).
    Derived-only rows (``us_per_call`` <= 0) and rows that exist only in
    the fresh run (newly added) are ignored.
    """
    fresh = {r["name"]: r for r in fresh_rows}
    msgs = []
    for base in baseline_rows:
        base_us = base.get("us_per_call", 0.0)
        if base_us <= 0.0:
            continue
        got = fresh.get(base["name"])
        if got is None:
            msgs.append(f"{base['name']}: missing from fresh run")
        elif got["us_per_call"] > threshold * base_us:
            msgs.append(f"{base['name']}: {got['us_per_call']:.0f}us vs "
                        f"baseline {base_us:.0f}us "
                        f"(>{threshold:g}x regression)")
    return msgs


def run_check(baseline_path: str, fresh_rows=None,
              threshold: "float | None" = None) -> int:
    """The ``--check`` CI gate: fresh rows of every gated section
    (``GATED_SECTIONS``: sim_engine + speculation_io + faults +
    resident + serving + batched) vs. the
    committed
    baseline.  ``fresh_rows`` can be injected for tests — either a dict
    ``{section: [row dicts]}`` (only the given sections are compared) or
    a plain list of ``BenchRow.as_dict`` dicts, compared as the ``sim``
    section; by default the gated benchmarks run live.
    ``threshold=None`` resolves via :func:`resolve_threshold` (env var or
    the 2x default)."""
    threshold = resolve_threshold(threshold)
    try:
        with open(baseline_path) as fh:
            baseline = json.load(fh)
    except OSError as exc:
        print(f"cannot read baseline {baseline_path}: {exc}", file=sys.stderr)
        return 1
    except json.JSONDecodeError as exc:
        print(f"baseline {baseline_path} is not valid JSON: {exc}",
              file=sys.stderr)
        return 1
    if fresh_rows is None:
        fresh_by = {}
        for section, modname in GATED_SECTIONS.items():
            mod = __import__(modname, fromlist=["rows"])
            fresh_by[section] = [r.as_dict() for r in mod.rows()]
    elif isinstance(fresh_rows, dict):
        fresh_by = fresh_rows
    else:
        fresh_by = {"sim": fresh_rows}
    msgs = []
    for section, fresh in fresh_by.items():
        msgs.extend(compare_rows(baseline.get(section, []), fresh,
                                 threshold))
    for m in msgs:
        print(f"REGRESSION {m}", file=sys.stderr)
    if msgs:
        print(f"{len(msgs)} gated row(s) regressed vs {baseline_path}",
              file=sys.stderr)
        return 1
    n_timed = sum(1 for section in fresh_by
                  for r in baseline.get(section, [])
                  if r.get("us_per_call", 0.0) > 0.0)
    print(f"OK: {n_timed} timed gated row(s) within {threshold:g}x "
          f"of {baseline_path}")
    return 0


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("filter", nargs="?", default="",
                        help="substring filter on module names")
    parser.add_argument("--json", nargs="?", const="BENCH_sim.json",
                        default=None, metavar="PATH",
                        help="also write perf-trajectory rows as JSON "
                             "(default path: BENCH_sim.json; path must end "
                             "in .json — write `run.py <filter> --json`, a "
                             "bare word after --json is taken as the path)")
    parser.add_argument("--check", nargs="?", const="BENCH_sim.json",
                        default=None, metavar="PATH",
                        help="re-run the gated rows (sim_engine + "
                             "speculation_io + faults + resident + "
                             "serving + batched) and exit non-zero on "
                             "us_per_call regressions beyond the "
                             "threshold vs the given baseline JSON "
                             "(default: BENCH_sim.json)")
    parser.add_argument("--threshold", type=float, default=None,
                        metavar="X",
                        help="--check regression threshold (default: "
                             "BENCH_CHECK_THRESHOLD env var, else "
                             f"{DEFAULT_THRESHOLD:g}x) — loaded CI runners "
                             "want more headroom than a quiet laptop")
    args = parser.parse_args()
    if args.check is not None:
        raise SystemExit(run_check(args.check, threshold=args.threshold))
    if args.json is not None and not args.json.endswith(".json"):
        parser.error(f"--json path {args.json!r} must end in .json "
                     f"(did you mean `run.py {args.json} --json`?)")

    print("name,us_per_call,derived")
    failures = 0
    sections: dict = {name: [] for name in JSON_SECTIONS.values()}
    for modname in MODULES:
        if args.filter and args.filter not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["rows"])
            mod_rows = list(mod.rows())
            for row in mod_rows:
                print(row.csv(), flush=True)
            section = JSON_SECTIONS.get(modname)
            if section is not None:
                sections[section].extend(r.as_dict() for r in mod_rows)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc()
    if args.json is not None:
        # never clobber the tracked trajectory file with a partial view:
        # only write when every JSON-section module ran and none failed
        ran_all = all(not args.filter or args.filter in m for m in JSON_SECTIONS)
        if failures:
            print(f"not writing {args.json}: {failures} module(s) failed",
                  file=sys.stderr)
        elif not ran_all:
            print(f"not writing {args.json}: filter {args.filter!r} excludes "
                  "perf-trajectory modules", file=sys.stderr)
        else:
            with open(args.json, "w") as fh:
                json.dump({"schema": 1, **sections}, fh, indent=1)
                fh.write("\n")
            print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
