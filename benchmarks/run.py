"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run                 # all, CSV to stdout
  PYTHONPATH=src python -m benchmarks.run fig17           # substring filter
  PYTHONPATH=src python -m benchmarks.run --json          # + BENCH_sim.json
  PYTHONPATH=src python -m benchmarks.run --json out.json

``--json`` persists the perf-trajectory rows — simulator engine throughput
at 1k/10k/100k tasks (benchmarks.bench_sim_engine) and the kernel rows
(benchmarks.bench_kernels) — so successive PRs can diff BENCH_sim.json.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback

MODULES = [
    "benchmarks.bench_claim1",
    "benchmarks.bench_fig5_network",
    "benchmarks.bench_fig7_adaptive",
    "benchmarks.bench_fig8_provisioned",
    "benchmarks.bench_fig13_burstable",
    "benchmarks.bench_fig17_kmeans",
    "benchmarks.bench_fig18_pagerank",
    "benchmarks.bench_hemt_dp",
    "benchmarks.bench_sim_engine",
    "benchmarks.bench_kernels",
]

# modules whose rows land in the --json perf-trajectory file
JSON_SECTIONS = {
    "benchmarks.bench_sim_engine": "sim",
    "benchmarks.bench_kernels": "kernels",
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("filter", nargs="?", default="",
                        help="substring filter on module names")
    parser.add_argument("--json", nargs="?", const="BENCH_sim.json",
                        default=None, metavar="PATH",
                        help="also write perf-trajectory rows as JSON "
                             "(default path: BENCH_sim.json; path must end "
                             "in .json — write `run.py <filter> --json`, a "
                             "bare word after --json is taken as the path)")
    args = parser.parse_args()
    if args.json is not None and not args.json.endswith(".json"):
        parser.error(f"--json path {args.json!r} must end in .json "
                     f"(did you mean `run.py {args.json} --json`?)")

    print("name,us_per_call,derived")
    failures = 0
    sections: dict = {name: [] for name in JSON_SECTIONS.values()}
    for modname in MODULES:
        if args.filter and args.filter not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["rows"])
            mod_rows = list(mod.rows())
            for row in mod_rows:
                print(row.csv(), flush=True)
            section = JSON_SECTIONS.get(modname)
            if section is not None:
                sections[section].extend(r.as_dict() for r in mod_rows)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc()
    if args.json is not None:
        # never clobber the tracked trajectory file with a partial view:
        # only write when every JSON-section module ran and none failed
        ran_all = all(not args.filter or args.filter in m for m in JSON_SECTIONS)
        if failures:
            print(f"not writing {args.json}: {failures} module(s) failed",
                  file=sys.stderr)
        elif not ran_all:
            print(f"not writing {args.json}: filter {args.filter!r} excludes "
                  "perf-trajectory modules", file=sys.stderr)
        else:
            with open(args.json, "w") as fh:
                json.dump({"schema": 1, **sections}, fh, indent=1)
                fh.write("\n")
            print(f"wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
