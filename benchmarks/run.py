"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig17      # substring filter
"""
from __future__ import annotations

import sys
import traceback

MODULES = [
    "benchmarks.bench_claim1",
    "benchmarks.bench_fig5_network",
    "benchmarks.bench_fig7_adaptive",
    "benchmarks.bench_fig8_provisioned",
    "benchmarks.bench_fig13_burstable",
    "benchmarks.bench_fig17_kmeans",
    "benchmarks.bench_fig18_pagerank",
    "benchmarks.bench_hemt_dp",
    "benchmarks.bench_kernels",
]


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if flt and flt not in modname:
            continue
        try:
            mod = __import__(modname, fromlist=["rows"])
            for row in mod.rows():
                print(row.csv(), flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{modname},ERROR,", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
