"""Fig 7: fifty same-class jobs; interfering processes injected at two
points (jobs 15 and 35) on node b; OA-HeMT with zero forgetting factor
re-balances within ~2 jobs."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, timed
from repro.core.scheduler import AdaptiveHeMTScheduler
from repro.core.simulator import SimNode


def _cluster(k: int):
    vb = 1.0
    if k >= 15:
        vb = 0.5          # first interference injection
    if k >= 35:
        vb = 0.25         # second injection
    return [SimNode.constant("a", 1.0), SimNode.constant("b", vb)]


def rows() -> List[BenchRow]:
    sched = AdaptiveHeMTScheduler(["a", "b"], alpha=0.0)
    hist, us = timed(sched.run_simulated_sequence, _cluster, 50, 150.0,
                     repeat=1)
    out = []
    for probe in (0, 14, 15, 17, 34, 35, 37, 49):
        h = hist[probe]
        out.append(BenchRow(
            f"fig7/job{probe:02d}", us / 50,
            f"completion_s={h.completion:.1f};idle_s={h.idle_time:.1f};"
            f"split={h.split[0]:.0f}:{h.split[1]:.0f}"))
    # recovery: jobs after each injection until within 5% of new optimum
    opt1, opt2 = 150.0 / 1.5, 150.0 / 1.25
    rec1 = next(i for i in range(15, 35) if hist[i].completion < 1.05 * opt1)
    rec2 = next(i for i in range(35, 50) if hist[i].completion < 1.05 * opt2)
    out.append(BenchRow("fig7/recovery", 0.0,
                        f"jobs_to_recover_inj1={rec1 - 15};"
                        f"jobs_to_recover_inj2={rec2 - 35}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
