"""Fig 18: PageRank, 100 iterations, short stages (the scheduling-overhead
sensitive regime). Skewed-hash (Algorithm 1) HeMT buckets vs even hash vs
HomT microtasks. Real JAX rank math."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.simulator import SimNode
from repro.workloads.pagerank import PageRankJob, pagerank_reference, random_graph

ITERS = 100
N = 4000


def _nodes():
    return [SimNode.constant("a", 1.0, overhead=0.15),
            SimNode.constant("b", 0.4, overhead=0.15)]


def rows() -> List[BenchRow]:
    src, dst = random_graph(N, 5, seed=0)
    ref = pagerank_reference(src, dst, N, iters=ITERS)

    out = []
    times = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("even", {}),
                     ("homt16", {"n_tasks": 16}),
                     ("homt64", {"n_tasks": 64})):
        m = mode.rstrip("0123456789")
        job = PageRankJob(src, dst, N, _nodes(), mode=m, **kw)
        ranks, us = timed(job.run, ITERS, repeat=1)
        err = float(np.max(np.abs(ranks - ref)))
        times[mode] = job.total_time()
        out.append(BenchRow(f"fig18/{mode}", us,
                            f"finish_s={job.total_time():.1f};"
                            f"rank_err={err:.1e}"))
    gain = (times["even"] - times["hemt"]) / times["even"] * 100
    best_homt = min(times["homt16"], times["homt64"])
    gain_homt = (best_homt - times["hemt"]) / best_homt * 100
    out.append(BenchRow("fig18/summary", 0.0,
                        f"hemt_vs_even_pct={gain:.1f};"
                        f"hemt_vs_best_homt_pct={gain_homt:.1f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
