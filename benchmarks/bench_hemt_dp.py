"""Beyond-paper: HeMT-DP in the training runtime — real gradient math on a
reduced LM, fleet timing from the calibrated slice model (one slice at 0.4x:
a contended/burstable pod). Reports steady-state step makespan, barrier
idle and the loss trajectory (identical across modes by construction)."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

import jax

from benchmarks.common import BenchRow, timed
from repro.configs import ArchBundle, TrainConfig, get_reduced
from repro.runtime.hemt_driver import HeMTTrainer, SliceSpec
from repro.runtime.train_loop import train_state_init

STEPS = 8


def rows() -> List[BenchRow]:
    cfg = dataclasses.replace(get_reduced("granite-3-8b"), n_layers=2)
    bundle = ArchBundle(model=cfg, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=STEPS * 2))
    slices = [SliceSpec("fast", [(0.0, 1.0)], 0.05),
              SliceSpec("slow", [(0.0, 0.4)], 0.05)]

    out = []
    losses = {}
    for mode in ("hemt", "homt", "static-even"):
        tr = HeMTTrainer(cfg, bundle, slices, grain_batch=2, global_batch=16,
                         seq_len=32, mode=mode, grain_cost=1.0)
        st = train_state_init(jax.random.PRNGKey(0), cfg, bundle)
        st, us = timed(tr.run, st, STEPS, repeat=1)
        steady = tr.reports[2:]
        losses[mode] = [r.loss for r in tr.reports]
        out.append(BenchRow(
            f"hemt_dp/{mode}", us / STEPS,
            f"steady_makespan_s={np.mean([r.makespan for r in steady]):.2f};"
            f"barrier_idle_s={np.mean([r.idle_time for r in steady]):.2f};"
            f"final_loss={tr.reports[-1].loss:.4f};"
            f"grains={tr.reports[-1].grain_counts}"))
    drift = max(abs(a - b) for a, b in zip(losses["hemt"], losses["homt"]))
    out.append(BenchRow("hemt_dp/math_equivalence", 0.0,
                        f"max_loss_drift_across_modes={drift:.2e}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
