"""Fig 5: stage completion time vs partition count when datanode uplink
bandwidth is the universal bottleneck (n=4 datanodes, r=2, 64 Mbps).

Paper observation: completion time INCREASES with the number of tasks —
finer partitions co-read the same block and collide on one uplink
(Claim 2: p1 = 1/r >= p2)."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, timed
from repro.core.simulator import SimNode, homt_job


def rows() -> List[BenchRow]:
    out = []
    nodes = [SimNode.constant(f"w{i}", 1.0, overhead=0.1) for i in range(2)]
    # 2 GB over a 64 Mbit/s == 8 MB/s uplink; tiny CPU work (network-bound)
    for n_tasks in [2, 4, 8, 16, 32, 64]:
        res, us = timed(homt_job, nodes, total_work=4.0, n_tasks=n_tasks,
                        io_mb_total=2048.0, uplink_bw=8.0, n_datanodes=4,
                        replica=2, repeat=1)
        out.append(BenchRow(
            f"fig5/tasks{n_tasks}", us,
            f"stage_s={res.completion:.1f};idle_s={res.idle_time:.1f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
