"""Simulation-engine throughput — the perf tentpole's trajectory rows.

Sweeps the HomT microtask regime (4 heterogeneous nodes) at 1k/10k/100k
tasks on the fast path, times the event-calendar path on an I/O-bound
stage, and pins the legacy ``_run_stage`` rescan loop against the fast
path at 10k tasks.  The closed-form rows added with the whole-job engine
(``pull_hetero_*``, ``pull_io_sym_*``, ``job_*``) each carry their own
event-calendar comparison in the derived column (the >= 5x acceptance
rows).  ``run.py --json`` persists these rows (plus the kernel rows) to
BENCH_sim.json, and ``run.py --check`` gates regressions against it.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear, run_stage_events,
)
from repro.core.simulator import SimNode, SimTask, _run_stage, run_pull_stage

SPEEDS = [1.0, 0.8, 0.5, 0.4]
OVERHEAD = 0.01
TOTAL_WORK = 100.0


def _nodes() -> List[SimNode]:
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate(SPEEDS)]


def _tasks(n: int) -> List[SimTask]:
    per = TOTAL_WORK / n
    return [SimTask(per, task_id=i) for i in range(n)]


def _hetero_works(n: int, seed: int = 0, blocks: int = 0) -> np.ndarray:
    """Heterogeneous task sizes; ``blocks`` > 0 groups them into runs of
    equal sizes (the Fig 18 skewed-shuffle shape: tasks of one partition
    share a size), the regime the run-length batched merge targets."""
    rng = np.random.default_rng(seed)
    if blocks:
        return np.repeat((TOTAL_WORK / n) * rng.uniform(0.5, 1.5, blocks),
                         n // blocks)
    return (TOTAL_WORK / n) * rng.uniform(0.5, 1.5, n)


def rows() -> List[BenchRow]:
    out = []
    nodes = _nodes()

    fast_us = {}
    for n in (1_000, 10_000, 100_000):
        tasks = _tasks(n)
        res, us = timed(run_pull_stage, nodes, tasks, repeat=5)
        fast_us[n] = us
        out.append(BenchRow(
            f"sim_engine/pull_{n}", us,
            f"tasks_per_s={n / (us / 1e6):.0f};"
            f"completion={res.completion:.3f};idle={res.idle_time:.4f}"))

    # event-calendar path (multi-datanode flow-shared I/O keeps it off
    # every closed form)
    n = 10_000
    io_tasks = [SimTask(TOTAL_WORK / n, io_mb=0.05, datanode=i % 4, task_id=i)
                for i in range(n)]
    res, us = timed(run_pull_stage, nodes, io_tasks, uplink_bw=50.0, repeat=5)
    out.append(BenchRow(
        f"sim_engine/pull_io_{n}", us,
        f"tasks_per_s={n / (us / 1e6):.0f};completion={res.completion:.3f}"))

    # heterogeneous task sizes (the Fig 18 skewed-shuffle regime: 32
    # partitions, tasks within a partition share a size): the run-length
    # batched merged-grid scan vs. the event calendar.  The headline row
    # measures the record-free whole-job summary (what Fig 18-style sweeps
    # consume); records_speedup is the full-records run_pull_stage
    # comparison, heap_us the pure-heap scan on fully distinct sizes
    # (run length 1, where the batched path declines).
    n = 10_000
    hworks = _hetero_works(n, blocks=32)
    htasks = [SimTask(float(w), task_id=i) for i, w in enumerate(hworks)]
    hspec = PullSpec(works=tuple(float(w) for w in hworks))
    dspec = PullSpec(works=tuple(float(w) for w in _hetero_works(n)))
    sched, us = timed(lambda: run_job(_nodes(), [hspec]), repeat=9)
    _, us_heap = timed(lambda: run_job(_nodes(), [dspec]), repeat=5)
    _, us_rec = timed(run_pull_stage, nodes, htasks, repeat=5)
    _, us_evt = timed(run_stage_events, nodes, [htasks], True, repeat=5)
    out.append(BenchRow(
        f"sim_engine/pull_hetero_{n}", us,
        f"event_us={us_evt:.0f};speedup={us_evt / us:.1f}x;"
        f"heap_us={us_heap:.0f};batch_speedup={us_heap / us:.1f}x;"
        f"records_speedup={us_evt / us_rec:.1f}x;"
        f"completion={sched.completion:.3f}"))

    # symmetric co-reader I/O (equal io_mb, one datanode, network-governed):
    # piecewise-linear closed form vs. the event calendar
    sym_tasks = [SimTask(TOTAL_WORK / n, io_mb=1.0, datanode=0, task_id=i)
                 for i in range(n)]
    res, us = timed(run_pull_stage, nodes, sym_tasks, uplink_bw=50.0,
                    repeat=5)
    _, us_evt = timed(run_stage_events, nodes, [sym_tasks], True, 50.0,
                      repeat=3)
    out.append(BenchRow(
        f"sim_engine/pull_io_sym_{n}", us,
        f"event_us={us_evt:.0f};speedup={us_evt / us:.1f}x;"
        f"completion={res.completion:.3f}"))

    # whole jobs: run_job carrying finish vectors across barriers vs.
    # re-entering the event calendar once per stage (Fig 18-style sweep:
    # 10 stages x 1k skewed tasks = 10k tasks)
    stages, per_stage = 10, 1_000
    jworks = _hetero_works(per_stage, seed=1)
    jspec = PullSpec(works=tuple(float(w) for w in jworks))
    jtasks = [SimTask(float(w), task_id=i) for i, w in enumerate(jworks)]

    def _job_events() -> float:
        t, nds = 0.0, _nodes()
        for _ in range(stages):
            t = run_stage_events(nds, [jtasks], True, None, t).completion
        return t

    def _job_solve():
        run_job_cache_clear()     # measure the solve, not the LRU hit
        return run_job(_nodes(), [jspec] * stages)

    sched, us = timed(_job_solve, repeat=5)
    t_evt, us_evt = timed(_job_events, repeat=3)
    assert abs(sched.completion - t_evt) < 1e-6 * t_evt
    out.append(BenchRow(
        f"sim_engine/job_pull_{stages}x{per_stage}", us,
        f"event_us={us_evt:.0f};speedup={us_evt / us:.1f}x;"
        f"completion={sched.completion:.3f}"))

    # warm module-LRU path: repeated benchmark invocations / adaptive
    # schedulers resolving the same (cluster, spec) job
    _, us_lru = timed(lambda: run_job(_nodes(), [jspec] * stages), repeat=9)
    out.append(BenchRow(
        f"sim_engine/job_pull_lru_{stages}x{per_stage}", us_lru,
        f"solve_us={us:.0f};lru_speedup={us / us_lru:.1f}x"))

    # HeMT macrotask job: 1000 static stages over 4 nodes
    stages = 1_000
    sspec = StaticSpec(works=(40.0, 30.0, 20.0, 10.0))

    def _static_events() -> float:
        t, nds = 0.0, _nodes()
        queues = [[SimTask(w, task_id=i)] for i, w in enumerate(sspec.works)]
        for _ in range(stages):
            t = run_stage_events(nds, queues, False, None, t).completion
        return t

    def _static_solve():
        run_job_cache_clear()
        return run_job(_nodes(), [sspec] * stages)

    sched, us = timed(_static_solve, repeat=5)
    t_evt, us_evt = timed(_static_events, repeat=3)
    assert abs(sched.completion - t_evt) < 1e-6 * t_evt
    out.append(BenchRow(
        f"sim_engine/job_static_{stages}x4", us,
        f"event_us={us_evt:.0f};speedup={us_evt / us:.1f}x;"
        f"completion={sched.completion:.3f}"))

    # acceptance row: legacy rescan loop vs. fast path at 10k microtasks
    # (_run_stage drains its queues, so each repeat gets a fresh copy)
    n = 10_000
    _, us_legacy = timed(
        lambda: _run_stage(_nodes(), [_tasks(n)], pull=True), repeat=3)
    out.append(BenchRow(
        f"sim_engine/speedup_pull_{n}", us_legacy,
        f"legacy_us={us_legacy:.0f};fast_us={fast_us[n]:.0f};"
        f"speedup={us_legacy / fast_us[n]:.1f}x"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
