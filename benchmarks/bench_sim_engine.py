"""Simulation-engine throughput — the perf tentpole's trajectory rows.

Sweeps the HomT microtask regime (4 heterogeneous nodes) at 1k/10k/100k
tasks on the fast path, times the event-calendar path on an I/O-bound
stage, and pins the legacy ``_run_stage`` rescan loop against the fast
path at 10k tasks (the acceptance row: >= 5x).  ``run.py --json`` persists
these rows (plus the kernel rows) to BENCH_sim.json.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow, timed
from repro.core.simulator import SimNode, SimTask, _run_stage, run_pull_stage

SPEEDS = [1.0, 0.8, 0.5, 0.4]
OVERHEAD = 0.01
TOTAL_WORK = 100.0


def _nodes() -> List[SimNode]:
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate(SPEEDS)]


def _tasks(n: int) -> List[SimTask]:
    per = TOTAL_WORK / n
    return [SimTask(per, task_id=i) for i in range(n)]


def rows() -> List[BenchRow]:
    out = []
    nodes = _nodes()

    fast_us = {}
    for n in (1_000, 10_000, 100_000):
        tasks = _tasks(n)
        res, us = timed(run_pull_stage, nodes, tasks, repeat=5)
        fast_us[n] = us
        out.append(BenchRow(
            f"sim_engine/pull_{n}", us,
            f"tasks_per_s={n / (us / 1e6):.0f};"
            f"completion={res.completion:.3f};idle={res.idle_time:.4f}"))

    # event-calendar path (flow-shared I/O forces it off the closed form)
    n = 10_000
    io_tasks = [SimTask(TOTAL_WORK / n, io_mb=0.05, datanode=i % 4, task_id=i)
                for i in range(n)]
    res, us = timed(run_pull_stage, nodes, io_tasks, uplink_bw=50.0, repeat=5)
    out.append(BenchRow(
        f"sim_engine/pull_io_{n}", us,
        f"tasks_per_s={n / (us / 1e6):.0f};completion={res.completion:.3f}"))

    # acceptance row: legacy rescan loop vs. fast path at 10k microtasks
    # (_run_stage drains its queues, so each repeat gets a fresh copy)
    n = 10_000
    _, us_legacy = timed(
        lambda: _run_stage(_nodes(), [_tasks(n)], pull=True), repeat=3)
    out.append(BenchRow(
        f"sim_engine/speedup_pull_{n}", us_legacy,
        f"legacy_us={us_legacy:.0f};fast_us={fast_us[n]:.0f};"
        f"speedup={us_legacy / fast_us[n]:.1f}x"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
