"""Batched many-solve planner vs. the scalar closed-form loop.

Each batched row solves a B-row stack of clusters in one vectorized pass
(``repro.core.batched``) and carries the honest scalar comparison in its
derived column: the same rows solved one ``run_job`` at a time — nodes
constructed per row, solve LRU cleared so every row is a genuine solve,
exactly what a Monte-Carlo planner pays today.  The acceptance bar is
``speedup >= 5x`` (us-per-solve) at B=1000 on all three solvers.  The
``dedup`` row measures the cross-batch de-dup (the batched demotion of
the solve LRU) on a batch with few distinct rows, and ``plan_capacity``
times the end-to-end Monte-Carlo planner sweep.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.batched import (
    batched_closed_pull, batched_closed_pull_hetero, batched_closed_static,
    plan_capacity,
)
from repro.core.engine import PullSpec, StaticSpec, run_job, run_job_cache_clear
from repro.core.simulator import SimNode

B = 1_000
N = 8            # nodes per cluster row
T = 256          # microtasks per pull row
OVERHEAD = 0.01


def _speeds(b: int = B, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).uniform(0.3, 2.0, (b, N))


def _scalar_static(sp: np.ndarray, wk: np.ndarray) -> np.ndarray:
    run_job_cache_clear()     # every row is a distinct solve; measure it
    out = np.empty(sp.shape[0])
    for b in range(sp.shape[0]):
        nodes = [SimNode.constant(f"n{i}", s, OVERHEAD)
                 for i, s in enumerate(sp[b])]
        out[b] = run_job(nodes, [StaticSpec(works=tuple(wk[b]))]).completion
    return out


def _scalar_pull(sp: np.ndarray, specs: List[PullSpec]) -> np.ndarray:
    run_job_cache_clear()
    out = np.empty(sp.shape[0])
    for b in range(sp.shape[0]):
        nodes = [SimNode.constant(f"n{i}", s, OVERHEAD)
                 for i, s in enumerate(sp[b])]
        out[b] = run_job(nodes, [specs[b]]).completion
    return out


def rows() -> List[BenchRow]:
    out = []
    rng = np.random.default_rng(1)
    sp = _speeds()

    # --- closed-static: B x N macrotask splits ---------------------------
    wk = rng.uniform(0.5, 5.0, (B, N))
    res, us = timed(batched_closed_static, sp, wk, OVERHEAD, repeat=5)
    scalar, us_sc = timed(_scalar_static, sp, wk, repeat=3)
    assert np.allclose(res.makespan, scalar, rtol=0, atol=1e-9)
    out.append(BenchRow(
        f"batched/static_B{B}", us,
        f"us_per_solve={us / B:.2f};scalar_us_per_solve={us_sc / B:.1f};"
        f"speedup={us_sc / us:.1f}x"))

    # --- closed-pull (uniform): B rows x T microtasks --------------------
    twork = rng.uniform(0.1, 2.0, B)
    uspecs = [PullSpec(n_tasks=T, task_work=float(w)) for w in twork]
    res, us = timed(batched_closed_pull, sp, T, twork, OVERHEAD, repeat=3)
    scalar, us_sc = timed(_scalar_pull, sp, uspecs, repeat=3)
    assert np.allclose(res.makespan, scalar, rtol=0, atol=1e-9)
    out.append(BenchRow(
        f"batched/pull_uniform_B{B}", us,
        f"us_per_solve={us / B:.2f};scalar_us_per_solve={us_sc / B:.1f};"
        f"speedup={us_sc / us:.1f}x"))

    # --- closed-pull-hetero: B rows x [T] work grids ---------------------
    hwork = rng.uniform(0.1, 2.0, (B, T))
    hspecs = [PullSpec(works=tuple(w)) for w in hwork]
    res, us = timed(batched_closed_pull_hetero, sp, hwork, OVERHEAD, repeat=3)
    scalar, us_sc = timed(_scalar_pull, sp, hspecs, repeat=3)
    assert np.allclose(res.makespan, scalar, rtol=0, atol=1e-9)
    out.append(BenchRow(
        f"batched/pull_hetero_B{B}", us,
        f"us_per_solve={us / B:.2f};scalar_us_per_solve={us_sc / B:.1f};"
        f"speedup={us_sc / us:.1f}x"))

    # --- cross-batch de-dup: B=10k rows, 16 distinct ---------------------
    big = 10_000
    base_sp = _speeds(16, seed=2)
    base_wk = rng.uniform(0.1, 2.0, (16, T))
    rep_sp = np.tile(base_sp, (big // 16, 1))
    rep_wk = np.tile(base_wk, (big // 16, 1))
    _, us_dd = timed(batched_closed_pull_hetero, rep_sp, rep_wk, OVERHEAD,
                     repeat=3)
    _, us_full = timed(
        lambda: batched_closed_pull_hetero(rep_sp, rep_wk, OVERHEAD,
                                           dedup=False), repeat=3)
    out.append(BenchRow(
        f"batched/dedup_B{big}", us_dd,
        f"distinct=16;full_us={us_full:.0f};"
        f"dedup_speedup={us_full / us_dd:.1f}x"))

    # --- plan_capacity: Monte-Carlo planner sweep ------------------------
    rep, us = timed(
        lambda: plan_capacity((2.0, 1.0, 1.0, 0.5), 100.0, target=16.0,
                              n_range=range(2, 13), samples=1_000, seed=7),
        repeat=3)
    solves = 1_000 * len(rep.quantiles)
    out.append(BenchRow(
        "batched/plan_capacity_11x1k", us,
        f"chosen={rep.chosen};us_per_solve={us / solves:.2f};"
        f"p99_at_chosen={rep.quantiles.get(rep.chosen, float('nan')):.2f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
