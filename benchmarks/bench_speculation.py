"""Straggler-mitigation sweep: HomT / HeMT / HeMT+speculation /
HeMT+stealing completion times under stale estimates and burstable-credit
exhaustion (paper §3 Claim 1, §5 OA-HeMT; ``repro.core.speculation``).

Two scenarios, each comparing four policies on the same cluster:

* **stale**: capacity estimates were learned before one node degraded to a
  quarter speed, so the HeMT split is even.  Pure HeMT strands a quarter
  of the job on the straggler; pure HomT re-balances but pays the
  microtask overhead tax; HeMT with speculative copies or work stealing
  keeps the macrotask overhead profile *and* rescues the straggler — the
  paper's claim that learned-capacity HeMT plus cheap mitigation beats
  both pure baselines.
* **burstable**: token-bucket nodes split by peak rate; one node's credits
  run out mid-macrotask (paper §6.2's stale-capacity failure mode) and its
  tail crawls at the baseline rate until mitigation moves the work.

``scenario_completions`` returns the raw completion times so the tier-1
suite pins the orderings (HeMT+mitigation < HomT < HeMT-stale); the rows
land in the ``speculation`` section of BENCH_sim.json via ``run.py
--json``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core.capacity import BurstableNode
from repro.core.engine import (
    PullSpec, StaticSpec, run_job, run_job_cache_clear,
)
from repro.core.simulator import SimNode
from repro.core.speculation import (
    ReskewHandoff, SpeculativeCopies, WorkStealing,
)

TOTAL_WORK = 16.0
OVERHEAD = 0.3              # the tiny-tasks regime where HomT's tax bites
N_MICRO = 64                # HomT microtask count
STAGES = 4                  # stages per job (mitigation compounds)

SPEC = SpeculativeCopies(quantile=0.75, factor=1.2, min_completed=1)
STEAL = WorkStealing(grain=0.25)
RESKEW = ReskewHandoff(cutoff_factor=1.5)


def _stale_nodes() -> List[SimNode]:
    """Estimates said [1, 1, 1, 1]; one node has since degraded to 0.25."""
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate([1.0, 1.0, 1.0, 0.25])]


def _burstable_nodes() -> List[SimNode]:
    """Split by peak speed 1.0; n3's credits die mid-macrotask and it
    drops to its 0.2 baseline."""
    spec = [BurstableNode(credits=60.0, baseline=0.2),
            BurstableNode(credits=60.0, baseline=0.2),
            BurstableNode(credits=60.0, baseline=0.2),
            BurstableNode(credits=2.0, baseline=0.2)]
    return [SimNode.burstable(f"b{i}", bn, OVERHEAD)
            for i, bn in enumerate(spec)]


def _variants(believed_even_works) -> Dict[str, List]:
    homt = PullSpec(n_tasks=N_MICRO, task_work=TOTAL_WORK / N_MICRO)
    return {
        "homt": [homt] * STAGES,
        "hemt": [StaticSpec(works=believed_even_works)] * STAGES,
        "hemt_spec": [StaticSpec(works=believed_even_works,
                                 mitigation=SPEC)] * STAGES,
        "hemt_steal": [StaticSpec(works=believed_even_works,
                                  mitigation=STEAL)] * STAGES,
        "hemt_reskew": [StaticSpec(works=believed_even_works,
                                   mitigation=RESKEW)] * STAGES,
    }


def scenario_completions(scenario: str) -> Dict[str, float]:
    """Completion time of the four-stage job per policy variant."""
    nodes = _stale_nodes() if scenario == "stale" else _burstable_nodes()
    even = (TOTAL_WORK / 4,) * 4
    out = {}
    for name, specs in _variants(even).items():
        run_job_cache_clear()
        out[name] = run_job(nodes, specs).completion
    return out


def rows() -> List[BenchRow]:
    out = []
    for scenario in ("stale", "burstable"):
        nodes_fn = _stale_nodes if scenario == "stale" else _burstable_nodes
        even = (TOTAL_WORK / 4,) * 4
        comps = {}
        for name, specs in _variants(even).items():

            def _solve(s=specs):
                run_job_cache_clear()   # time the solve, not the LRU hit
                return run_job(nodes_fn(), s)

            sched, us = timed(_solve, repeat=5)
            comps[name] = sched.completion
            out.append(BenchRow(
                f"speculation/{scenario}_{name}", us,
                f"completion={sched.completion:.3f};stages={STAGES}"))
        best_mitigated = min(comps["hemt_spec"], comps["hemt_steal"])
        out.append(BenchRow(
            f"speculation/{scenario}_ordering", 0.0,
            f"mitigated_beats_homt={best_mitigated < comps['homt']};"
            f"mitigated_beats_hemt={best_mitigated < comps['hemt']};"
            f"best={min(comps, key=comps.get)}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
