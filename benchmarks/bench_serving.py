"""Fleet-scale request serving: open-loop arrival traces through the
resident calendar (``repro.core.arrivals`` + ``repro.runtime.serving``),
HeMT vs HomT batch sizing on tail latency and SLO attainment.

**Latency scenario** — a Poisson trace (2.5 req/s, 120 s) batches every
2 s onto a four-replica fleet with 4:3:2:1 speeds.  Each batch decodes
as one macrotask split across the replicas; the split policy is the
experiment:

* **hemt**: splits sized per AR(1)-estimated replica throughput (one
  shared estimator, warm-started by a t=0 probe per replica, updated at
  every batch barrier);
* **even**: the HomT-like baseline — equal shares, every batch waits on
  the 0.5x replica's oversized slice;
* **oracle**: clairvoyant splits pinned to true mean speeds.

``p99_hemt < p99_even`` and ``att_hemt >= att_even`` (with
``p99_oracle <= p99_hemt`` up to estimator noise) is the tentpole
ordering, pinned by tests/test_serving.py.

**Burstable variant** — the fastest replica exhausts its CPU credits at
t=40 s and drops to 0.6x; the AR(1) loop tracks the fall within a few
batches while the even split keeps overloading the throttled machine.

**Preemption variant** — the slowest replica is spot-preempted
mid-trace; killed decode attempts checkpoint (grain 0.25) and requeue,
and later batches split across the three survivors.

**Generator rows** — million-request traces for each arrival regime
(Poisson / diurnal thinning / 2-state MMPP), timing ``times()`` alone:
the open-loop front end must never be the bottleneck of a fleet sweep.

Timed rows land in the ``serving`` section of BENCH_sim.json and are
gated by ``run.py --check``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core.arrivals import DiurnalTrace, MMPPTrace, PoissonTrace
from repro.core.faults import FaultTrace, SpotPreemption
from repro.core.simulator import SimNode
from repro.runtime.serving import RequestModel, ServingScenario

SPEEDS = (2.0, 1.5, 1.0, 0.5)
OVERHEAD = 0.01
WINDOW = 2.0
RATE = 2.5
HORIZON = 120.0
SLO = 4.0
TRACE = PoissonTrace(RATE, HORIZON, seed=11)
MODEL = RequestModel(decode_work=1.0, seed=7)

THROTTLE_AT = 40.0               # replica 0 credit-exhaustion instant
THROTTLE_TO = 0.6                # post-exhaustion speed
PREEMPT = FaultTrace((SpotPreemption(node=3, at=50.0, warning=1.0),),
                     checkpoint_grain=0.25)

MILLION = PoissonTrace(10_000.0, 100.0, seed=3)          # ~1e6 arrivals
MILLION_DIURNAL = DiurnalTrace(6_000.0, 14_000.0, 50.0, 100.0, seed=3)
MILLION_MMPP = MMPPTrace((4_000.0, 28_000.0), (20.0, 5.0), 100.0, seed=3)


def _nodes(variant: str = "flat") -> List[SimNode]:
    nodes = []
    for i, s in enumerate(SPEEDS):
        if variant == "burstable" and i == 0:
            nodes.append(SimNode(f"n{i}", [(0.0, s),
                                           (THROTTLE_AT, THROTTLE_TO)],
                                 OVERHEAD))
        else:
            nodes.append(SimNode(f"n{i}", [(0.0, s)], OVERHEAD))
    return nodes


def _scenario(mode: str, variant: str = "flat") -> ServingScenario:
    return ServingScenario(
        _nodes(variant), window=WINDOW, mode=mode, slo=SLO, model=MODEL,
        faults=PREEMPT if variant == "preempt" else None)


def _run(mode: str, variant: str = "flat"):
    return _scenario(mode, variant).run(TRACE)


def scenario_metrics() -> Dict[str, float]:
    """p99 / attainment per batching mode and fleet variant — the
    numbers the tier-1 ordering test pins."""
    out: Dict[str, float] = {}
    for variant in ("flat", "burstable", "preempt"):
        for mode in ("hemt", "even", "oracle"):
            rep = _run(mode, variant)
            key = f"{variant}_{mode}"
            out[f"p99_{key}"] = rep.p99
            out[f"att_{key}"] = rep.attainment
    return out


def rows() -> List[BenchRow]:
    out = []
    mets: Dict[str, float] = {}
    for variant in ("flat", "burstable", "preempt"):
        for mode in ("hemt", "even", "oracle"):
            rep, us = timed(_run, mode, variant, repeat=3)
            key = f"{variant}_{mode}"
            mets[f"p99_{key}"] = rep.p99
            mets[f"att_{key}"] = rep.attainment
            out.append(BenchRow(
                f"serving/{variant}_{mode}", us,
                f"p50={rep.p50:.3f};p99={rep.p99:.3f};"
                f"att={rep.attainment:.3f};good={rep.goodput:.3f};"
                f"n={rep.n_requests}"))
    for name, trace in (("poisson", MILLION),
                        ("diurnal", MILLION_DIURNAL),
                        ("mmpp", MILLION_MMPP)):
        times, us = timed(trace.times, repeat=3)
        out.append(BenchRow(
            f"serving/gen_{name}_1e6", us,
            f"n={times.size};rate={trace.mean_rate:.0f}/s"))
    out.append(BenchRow(
        "serving/orderings", 0.0,
        f"hemt_beats_even_p99="
        f"{mets['p99_flat_hemt'] < mets['p99_flat_even']};"
        f"hemt_beats_even_att="
        f"{mets['att_flat_hemt'] >= mets['att_flat_even']};"
        f"oracle_le_hemt="
        f"{mets['p99_flat_oracle'] <= mets['p99_flat_hemt'] + 1e-6};"
        f"burst_hemt_beats_even="
        f"{mets['p99_burstable_hemt'] < mets['p99_burstable_even']};"
        f"preempt_hemt_beats_even="
        f"{mets['p99_preempt_hemt'] < mets['p99_preempt_even']}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
