"""Figs 13-15: burstable (token-bucket) executors under three bandwidth
regimes. Node a: credit-rich (full speed); node b: zero credits (baseline
0.4 advertised, ~0.32 effective due to cache/TLB contention — the paper's
learned fudge factor).

Fig 13 (~600 Mbps) and Fig 14 (~480 Mbps): CPU-bound — fudge-corrected
HeMT beats the best HomT. Fig 15 (~250 Mbps): datanode uplink becomes the
bottleneck for the fast node — HeMT >> HomT because microtasks collide on
uplinks (Claim 2)."""
from __future__ import annotations

from typing import List

from benchmarks.common import BenchRow
from repro.core.simulator import SimNode, hemt_job, homt_job

# Calibrated so the credit-rich node processes ~45 MB/s of input: CPU-bound
# at 600/480 Mbps uplinks (75/60 MB/s), network-bound at 250 Mbps (31 MB/s)
# — the paper's three regimes.
WORK = 45.0           # CPU work units (seconds at full speed)
IO_MB = 2048.0        # 2 GB input
OVERHEAD = 0.3


def _nodes(true_slow: float):
    return [SimNode.constant("a", 1.0, OVERHEAD),
            SimNode.constant("b", true_slow, OVERHEAD)]


def _regime(name: str, bw_mbps: float) -> List[BenchRow]:
    out = []
    bw = bw_mbps / 8.0              # MB/s per uplink
    nodes = _nodes(0.32)            # TRUE effective speed
    for n_tasks in (2, 8, 32):
        res = homt_job(nodes, WORK, n_tasks, io_mb_total=IO_MB, uplink_bw=bw)
        out.append(BenchRow(f"{name}/homt_tasks{n_tasks}", 0.0,
                            f"stage_s={res.completion:.1f}"))
    naive = hemt_job(nodes, WORK, [1.0, 0.4], io_mb_total=IO_MB, uplink_bw=bw)
    out.append(BenchRow(f"{name}/hemt_naive_1:0.4", 0.0,
                        f"stage_s={naive.completion:.1f};"
                        f"idle_s={naive.idle_time:.1f}"))
    fudged = hemt_job(nodes, WORK, [1.0, 0.32], io_mb_total=IO_MB, uplink_bw=bw)
    out.append(BenchRow(f"{name}/hemt_fudged_1:0.32", 0.0,
                        f"stage_s={fudged.completion:.1f};"
                        f"idle_s={fudged.idle_time:.1f}"))
    return out


def rows() -> List[BenchRow]:
    out = []
    out += _regime("fig13_600mbps", 600.0)
    out += _regime("fig14_480mbps", 480.0)
    out += _regime("fig15_250mbps", 250.0)
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
