"""Fig 17: K-Means, 30 iterations, two executors at 1.0 / 0.4 cores.
Real JAX math; completion times from the calibrated executor model.
Paper: HeMT ~10% faster than the default even split end-to-end."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import BenchRow, timed
from repro.core.simulator import SimNode
from repro.workloads.kmeans import KMeansJob, kmeans_reference

ITERS = 30


def _nodes():
    return [SimNode.constant("a", 1.0, overhead=0.2),
            SimNode.constant("b", 0.4, overhead=0.2)]


def rows() -> List[BenchRow]:
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(2000, 8))
    ref = kmeans_reference(pts, k=8, iters=ITERS)

    out = []
    times = {}
    for mode, kw in (("hemt", {"weights": [1.0, 0.4]}),
                     ("even", {}),
                     ("homt8", {"n_tasks": 8}),
                     ("homt32", {"n_tasks": 32})):
        m = mode.rstrip("0123456789")
        job = KMeansJob(pts, 8, _nodes(), mode=m, work_per_point=2e-3, **kw)
        cent, us = timed(job.run, ITERS, repeat=1)
        err = float(np.max(np.abs(np.asarray(cent) - ref)))
        times[mode] = job.total_time()
        out.append(BenchRow(f"fig17/{mode}", us,
                            f"finish_s={job.total_time():.1f};"
                            f"centroid_err={err:.1e}"))
    gain = (times["even"] - times["hemt"]) / times["even"] * 100
    best_homt = min(times["homt8"], times["homt32"])
    gain_homt = (best_homt - times["hemt"]) / best_homt * 100
    out.append(BenchRow("fig17/summary", 0.0,
                        f"hemt_vs_even_pct={gain:.1f};"
                        f"hemt_vs_best_homt_pct={gain_homt:.1f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
