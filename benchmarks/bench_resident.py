"""Resident cluster loop: splice-in recovery vs restart-per-event, and
per-job SLO attainment across the three scheduling regimes
(``repro.core.resident``; paper §5's barrier re-planning made resident,
§8's revocable-capacity setting).

Two scenarios:

**Recovery** — two equal-priority jobs share a two-node cluster (one node
each under the weighted fair share); the second job's node crashes
mid-stage and recovers a second later, with a checkpoint grain of one
work unit.  Under ``recovery="splice"`` the calendar folds the lost tail
forward — checkpointed work survives, the survivor job never re-plans —
while the ``"restart"`` baseline re-materializes every open stage from
scratch at *each* capacity event (the crash and the recovery), so both
jobs pay twice.  ``splice_makespan < restart_makespan`` is the tentpole
claim, pinned by tests/test_resident.py.

**SLO** — three deadline-carrying jobs arrive one after another on an
idle heterogeneous cluster (speeds 2:1:1) and each runs the same total
work through one of three regimes:

* **oa_hemt**: even static splits plus the online-adaptive loop — stage
  one pays the cold-start even split, then AR(1) estimates re-skew every
  later barrier toward the 2x node.
* **homt**: fine microtasks through the shared pull queue — the split is
  implicitly speed-proportional, but every microtask pays the dispatch
  overhead tax.
* **hemt_stale**: the even split pinned via ``proportions`` and never
  re-planned — every stage waits on the slow nodes' oversized shares.

The jobs' deadlines are staggered (tight, medium, loose) so attainment
separates the regimes: OA-HeMT meets all three, HomT only the looser
two, stale HeMT only the loosest — the paper-predicted
``slo_oa_hemt >= slo_homt >= slo_stale`` ordering (strict at the ends)
returned by ``scenario_completions`` and pinned by the tier-1 suite; the
timed rows land in the ``resident`` section of BENCH_sim.json and are
gated by ``run.py --check``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core.engine import AdaptivePlan, PullSpec, StaticSpec
from repro.core.faults import FaultTrace, NodeCrash
from repro.core.resident import ResidentCalendar, ResidentJob
from repro.core.simulator import SimNode

# --- SLO scenario ---------------------------------------------------------
SPEEDS = (2.0, 1.0, 1.0)         # heterogeneous resident cluster
OVERHEAD = 0.05
STAGES = 3
STAGE_WORK = 8.0
N_MICRO = 16                     # HomT microtask count per stage
ARRIVALS = (0.0, 12.0, 24.0)     # sequential: each job sees the idle cluster
MARGINS = (7.0, 7.8, 8.6)        # deadline = arrival + margin (tight..loose)

# --- recovery scenario ----------------------------------------------------
REC_WORK = 4.0                   # per stage, per single-node job
REC_STAGES = 2
REC_TRACE = FaultTrace((NodeCrash(1, 2.0, recover_at=3.0),),
                       checkpoint_grain=1.0)


def _nodes() -> List[SimNode]:
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate(SPEEDS)]


def _slo_jobs(regime: str) -> List[ResidentJob]:
    even = tuple(STAGE_WORK / len(SPEEDS) for _ in SPEEDS)
    jobs = []
    for k, (arr, margin) in enumerate(zip(ARRIVALS, MARGINS)):
        if regime == "homt":
            stages: tuple = (PullSpec(n_tasks=N_MICRO,
                                      task_work=STAGE_WORK / N_MICRO),
                             ) * STAGES
            adaptive = None
            proportions = None
        else:
            stages = (StaticSpec(works=even),) * STAGES
            adaptive = AdaptivePlan() if regime == "oa_hemt" else None
            # the stale regime pins the even split for the calendar's
            # whole life — heterogeneity is never learned
            proportions = (None if regime == "oa_hemt"
                           else {f"n{i}": 1.0 for i in range(len(SPEEDS))})
        jobs.append(ResidentJob(f"j{k}", stages=stages, arrival=arr,
                                deadline=arr + margin, adaptive=adaptive,
                                proportions=proportions))
    return jobs


def _slo_result(regime: str):
    return ResidentCalendar(_nodes()).run(_slo_jobs(regime))


def _recovery_jobs() -> List[ResidentJob]:
    spec = StaticSpec(works=(REC_WORK,))
    return [ResidentJob(name, stages=(spec,) * REC_STAGES)
            for name in ("p", "q")]


def _recovery_result(recovery: str):
    nodes = [SimNode.constant("a", 1.0), SimNode.constant("b", 1.0)]
    return ResidentCalendar(nodes, faults=REC_TRACE,
                            recovery=recovery).run(_recovery_jobs())


def scenario_completions() -> Dict[str, float]:
    """Makespans/attainments per recovery mode and scheduling regime."""
    out = {}
    out["splice_makespan"] = _recovery_result("splice").makespan
    out["restart_makespan"] = _recovery_result("restart").makespan
    out["slo_oa_hemt"] = _slo_result("oa_hemt").attainment()
    out["slo_homt"] = _slo_result("homt").attainment()
    out["slo_stale"] = _slo_result("hemt_stale").attainment()
    return out


def rows() -> List[BenchRow]:
    out = []
    comps: Dict[str, float] = {}
    for mode in ("splice", "restart"):
        res, us = timed(_recovery_result, mode, repeat=5)
        comps[f"{mode}_makespan"] = res.makespan
        out.append(BenchRow(
            f"resident/recovery_{mode}", us,
            f"makespan={res.makespan:.3f};jobs=2;stages={REC_STAGES}"))
    for regime in ("oa_hemt", "homt", "hemt_stale"):
        res, us = timed(_slo_result, regime, repeat=5)
        comps[f"slo_{regime.replace('hemt_stale', 'stale')}"] = \
            res.attainment()
        out.append(BenchRow(
            f"resident/slo_{regime}", us,
            f"attainment={res.attainment():.3f};"
            f"makespan={res.makespan:.3f};jobs={len(ARRIVALS)}"))
    out.append(BenchRow(
        "resident/orderings", 0.0,
        f"splice_beats_restart="
        f"{comps['splice_makespan'] < comps['restart_makespan']};"
        f"slo_ordering="
        f"{comps['slo_oa_hemt'] >= comps['slo_homt'] >= comps['slo_stale']};"
        f"slo_gap={comps['slo_oa_hemt'] - comps['slo_stale']:.3f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
