"""Fault injection through the whole-job engine: spot preemption vs the
three scheduling regimes (``repro.core.faults``; paper §5.1's replacement
rule, §8's revocable-capacity setting).

One scenario, capacity revoked mid-job: a 4-node cluster whose fastest
node is a spot instance that gets preempted (0.5 s warning) during the
first of four identical HeMT stages.  The preempted macrotask re-runs
from scratch on a survivor (no checkpoint), and every later stage has one
node fewer.  Variants on identical work:

* **homt**: fine microtasks through the shared queue.  Pull degrades
  gracefully — the dead node simply stops pulling — but pays the
  per-microtask overhead tax on every stage, dead node or not.
* **hemt_stale**: the pre-fault HeMT split, unmitigated and never
  re-planned.  Every post-fault stage still hands the dead node its 40%
  share, which sheds to a single least-loaded survivor and serializes
  behind that node's own macrotask: the stage span roughly triples, and
  the job collapses to ~3x its clean run.
* **oa_hemt**: the online-adaptive loop under the same trace.  The crash
  stage eats the re-execution, then every barrier re-splits the whole
  stage over the survivors (alive-masked re-plan; the dead node gets a
  zero-work macrotask) while survivors keep their AR(1) estimates.
* **clairvoyant**: the post-failure clairvoyant yardstick — a schedule
  that writes the doomed node off entirely and splits every stage over
  the three survivors, fault-free.  (An upper bound on the true
  clairvoyant optimum, which would also use the spot node's pre-kill
  capacity; the gap assertion is conservative.)

The paper-predicted ordering — HomT degrades gracefully, stale static
HeMT collapses, OA-HeMT lands within a small gap of the post-failure
clairvoyant — is returned by ``scenario_completions`` and pinned by the
tier-1 suite (tests/test_faults.py); the timed rows land in the
``faults`` section of BENCH_sim.json and are gated by ``run.py --check``.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import BenchRow, timed
from repro.core.engine import (
    AdaptivePlan, PullSpec, StaticSpec, run_job, run_job_cache_clear,
)
from repro.core.faults import FaultTrace, SpotPreemption
from repro.core.simulator import SimNode
from repro.core.speculation import ReskewHandoff

TOTAL_WORK = 16.0
STAGES = 4
OVERHEAD = 0.05
SPEEDS = (1.0, 1.0, 1.0, 2.0)    # the spot instance is the fastest node
SPOT = 3                         # ... and the one that gets preempted
N_MICRO = 64                     # HomT microtask count per stage

TRACE = FaultTrace((SpotPreemption(SPOT, 2.0, warning=0.5),))


def _nodes() -> List[SimNode]:
    return [SimNode.constant(f"n{i}", s, OVERHEAD)
            for i, s in enumerate(SPEEDS)]


def _hemt_works() -> tuple:
    total_speed = sum(SPEEDS)
    return tuple(TOTAL_WORK * s / total_speed for s in SPEEDS)


def _homt_specs() -> List[PullSpec]:
    return [PullSpec(n_tasks=N_MICRO, task_work=TOTAL_WORK / N_MICRO)
            ] * STAGES


def _hemt_specs(mitigation=None) -> List[StaticSpec]:
    return [StaticSpec(works=_hemt_works(), mitigation=mitigation)] * STAGES


def scenario_completions() -> Dict[str, float]:
    """Completion time per scheduling regime, clean and under the trace."""
    nodes = _nodes()
    out = {}
    run_job_cache_clear()
    out["homt_clean"] = run_job(nodes, _homt_specs()).completion
    run_job_cache_clear()
    out["homt_faults"] = run_job(nodes, _homt_specs(),
                                 faults=TRACE).completion
    run_job_cache_clear()
    out["hemt_clean"] = run_job(nodes, _hemt_specs()).completion
    run_job_cache_clear()
    out["hemt_stale_faults"] = run_job(nodes, _hemt_specs(),
                                       faults=TRACE).completion
    run_job_cache_clear()
    out["oa_hemt_faults"] = run_job(
        nodes, _hemt_specs(mitigation=ReskewHandoff()),
        adaptive=AdaptivePlan(), faults=TRACE).completion
    # post-failure clairvoyant: survivors only, fault-free
    survivors = [nd for i, nd in enumerate(_nodes()) if i != SPOT]
    share = TOTAL_WORK / len(survivors)
    run_job_cache_clear()
    out["clairvoyant_faults"] = run_job(
        survivors,
        [StaticSpec(works=(share,) * len(survivors))] * STAGES).completion
    return out


def rows() -> List[BenchRow]:
    out = []
    comps = {}
    variants = {
        "homt_clean": (_homt_specs(), None, None),
        "homt_faults": (_homt_specs(), None, TRACE),
        "hemt_clean": (_hemt_specs(), None, None),
        "hemt_stale_faults": (_hemt_specs(), None, TRACE),
        "oa_hemt_faults": (_hemt_specs(mitigation=ReskewHandoff()),
                           AdaptivePlan, TRACE),
    }
    for name, (specs, adaptive_cls, trace) in variants.items():

        def _solve(s=specs, a=adaptive_cls, f=trace):
            run_job_cache_clear()   # time the solve, not the LRU hit
            return run_job(_nodes(), s,
                           adaptive=a() if a is not None else None,
                           faults=f)

        sched, us = timed(_solve, repeat=5)
        comps[name] = sched.completion
        out.append(BenchRow(
            f"faults/{name}", us,
            f"completion={sched.completion:.3f};stages={STAGES}"))
    comps.update((k, v) for k, v in scenario_completions().items()
                 if k == "clairvoyant_faults")
    out.append(BenchRow(
        "faults/spot_ordering", 0.0,
        f"oa_beats_stale={comps['oa_hemt_faults'] < comps['hemt_stale_faults']};"
        f"homt_graceful={comps['homt_faults'] < 2.0 * comps['homt_clean']};"
        f"stale_collapses={comps['hemt_stale_faults'] > 2.0 * comps['hemt_clean']};"
        f"oa_vs_clairvoyant="
        f"{comps['oa_hemt_faults'] / comps['clairvoyant_faults']:.3f}"))
    return out


def main() -> None:
    from benchmarks.common import print_rows
    print_rows(rows())


if __name__ == "__main__":
    main()
