"""AdamW with decoupled weight decay and global-norm clipping.

Moments default to fp32; ``moment_dtype='bfloat16'`` gives the Gopher-style
memory-lean variant used by the ≥100B configs (dbrx, jamba) so optimizer
state fits the per-device HBM budget under FSDP (stochastic-rounding-free:
the update math runs in fp32 and only storage is bf16).

Pure functions over pytrees — no optax dependency.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray      # () int32
    mu: Pytree             # first moment
    nu: Pytree             # second moment


def adamw_init(params: Pytree, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.bfloat16 if moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Pytree, max_norm: float,
                        ) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(grads: Pytree, state: AdamWState, params: Pytree, *,
                 lr: jnp.ndarray, beta1: float = 0.9, beta2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 grad_clip: float = 0.0,
                 ) -> Tuple[Pytree, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, pre-clip grad norm)."""
    if grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * beta1 + (1 - beta1) * gf
        vf = v.astype(jnp.float32) * beta2 + (1 - beta2) * jnp.square(gf)
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2), standard LM recipe
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(m.dtype), vf.astype(v.dtype)

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), gnorm
