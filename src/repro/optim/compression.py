"""Gradient compression for the cross-pod (DCN) all-reduce.

The `pod` mesh axis rides data-center network (~16x less bandwidth than
ICI), so the collective-roofline term there dominates multi-pod scaling.
Two standard schemes, both with error feedback so compression error is
re-injected next step (EF-SGD convergence guarantee):

  * top-k sparsification (keep the k largest-|g| entries per leaf),
  * int8 stochastic-free linear quantization (per-leaf scale).

Applied *only* on the pod axis: the in-slice (ICI) reduction stays exact.
Simulated compression (`compress_decompress`) runs inside jit — the wire
format never materializes on CPU; on a real fleet the same functions
bracket the `psum` over the "pod" axis.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class CompressionState(NamedTuple):
    error: Pytree     # EF accumulator, same structure/dtype as grads (fp32)


def compression_init(grads_like: Pytree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _topk_leaf(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    if k >= flat.shape[0]:
        return g
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def _int8_leaf(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Pytree, state: CompressionState, *,
                        scheme: str, topk_frac: float = 0.01,
                        ) -> Tuple[Pytree, CompressionState]:
    """EF compress->decompress round trip (what the DCN wire would carry).

    Returns (decompressed grads to feed the pod-axis psum, new EF state).
    scheme: "none" | "topk" | "int8".
    """
    if scheme == "none":
        return grads, state

    def per_leaf(g, e):
        acc = g.astype(jnp.float32) + e
        if scheme == "topk":
            sent = _topk_leaf(acc, topk_frac)
        elif scheme == "int8":
            sent = _int8_leaf(acc)
        else:
            raise ValueError(f"unknown compression scheme {scheme!r}")
        return sent.astype(g.dtype), acc - sent

    pairs = jax.tree.map(per_leaf, grads, state.error)
    sent = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], pairs,
                       is_leaf=lambda t: isinstance(t, tuple))
    return sent, CompressionState(err)


def wire_bytes(grads: Pytree, scheme: str, topk_frac: float = 0.01) -> int:
    """Bytes one pod-axis all-reduce would move per step (for the roofline
    collective term; exact dense bf16 = 2 bytes/param)."""
    n = sum(int(x.size) for x in jax.tree.leaves(grads))
    if scheme == "none":
        return 2 * n
    if scheme == "int8":
        return n + 4 * len(jax.tree.leaves(grads))
    if scheme == "topk":
        k = int(n * topk_frac)
        return k * (4 + 4)  # value + index
    raise ValueError(scheme)
