"""Optimizer substrate: AdamW, LR schedules, DCN gradient compression."""
from repro.optim.adamw import AdamWState, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.compression import (  # noqa: F401
    CompressionState, compress_decompress, compression_init,
)
