"""Learning-rate schedules (trace-safe: step may be a tracer)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step: jnp.ndarray, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, floor: float = 0.1) -> jnp.ndarray:
    """Linear warmup to peak, cosine decay to floor*peak."""
    s = step.astype(jnp.float32)
    # (s+1)/W so the FIRST step trains (an optimizer step at lr exactly 0
    # silently wastes the step and breaks single-step smoke tests)
    warm = (s + 1.0) / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(s < warmup_steps, warm, cos)


def constant(step: jnp.ndarray, *, lr: float) -> jnp.ndarray:
    return jnp.full((), lr, jnp.float32)
