"""Deterministic synthetic corpus + storage-aware feeder placement.

Design constraints (from the paper, adapted per DESIGN.md §2):

* **Index-addressed, not file-addressed.** Any worker can materialize any
  global sample index from (seed, index) alone — this is what lets HeMT
  re-skew shard boundaries between steps (and elastic resharding after a
  node loss) without any data movement. A Spark repartition becomes a
  pure index-range re-assignment.
* **Claim 2 analogue.** When grains *are* backed by remote storage shards,
  `FeederPlacement` spreads concurrent readers over shard replicas using
  the paper's same-block/different-block contention model
  (`repro.core.hdfs_model`): consecutive grains map to consecutive ranges
  of the same shard, so scheduling many tiny grains concurrently creates
  same-shard co-reads exactly like HDFS microtasks (Fig 5). The placement
  picker minimizes expected uplink collisions.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np


from repro.core.hdfs_model import p_diff_block, p_same_block


def _fold_seed(*parts: int) -> int:
    h = hashlib.blake2b(digest_size=8)
    for p in parts:
        h.update(int(p).to_bytes(8, "little", signed=False))
    return int.from_bytes(h.digest(), "little")


@dataclass(frozen=True)
class SyntheticCorpus:
    """Deterministic infinite LM corpus.

    Sample ``i`` is a function of (seed, i) only. Tokens follow a Zipfian
    unigram draw with a per-sample Markov perturbation so the loss is
    learnable (quickstart's ~100M model visibly descends) yet cheap.
    """
    vocab_size: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    # per-shape [G, B, seq] grain-block buffers for batch_block, lazily
    # allocated and reused across steps (excluded from eq/hash)
    _blocks: Dict[Tuple[int, int], Dict[str, np.ndarray]] = field(
        default_factory=dict, init=False, repr=False, compare=False)

    def _tokens(self, index: int) -> np.ndarray:
        rng = np.random.default_rng(_fold_seed(self.seed, index))
        # zipf over [1, vocab): rejection-free via bounded zipf
        raw = rng.zipf(self.zipf_a, size=self.seq_len + 1)
        toks = (raw % (self.vocab_size - 1)) + 1
        # short deterministic motif makes next-token structure learnable
        motif = rng.integers(1, self.vocab_size, size=8)
        pos = rng.integers(0, max(1, self.seq_len - 8), size=4)
        for p in pos:
            toks[p:p + 8] = motif
        return toks

    def sample(self, index: int) -> Dict[str, np.ndarray]:
        toks = self._tokens(index)
        return {"tokens": toks[:-1].astype(np.int32),
                "labels": toks[1:].astype(np.int32)}

    def batch(self, indices: Sequence[int]) -> Dict[str, np.ndarray]:
        samples = [self.sample(i) for i in indices]
        return {k: np.stack([s[k] for s in samples]) for k in samples[0]}

    def batch_block(self, indices: np.ndarray) -> Dict[str, np.ndarray]:
        """A [G, B] index grid materialized as [G, B, seq] token/label
        arrays, written in place into a preallocated per-shape buffer.

        This is the grain fast path: a training step's whole grain block is
        produced with zero intermediate per-sample dicts or ``np.stack``
        copies.  The returned arrays are REUSED by the next ``batch_block``
        call of the same shape — callers must transfer/copy (e.g.
        ``jnp.asarray``) before requesting the next block.
        """
        indices = np.asarray(indices)
        buf = self._blocks.get(indices.shape)
        if buf is None:
            shape = (*indices.shape, self.seq_len)
            buf = {"tokens": np.empty(shape, np.int32),
                   "labels": np.empty(shape, np.int32)}
            self._blocks[indices.shape] = buf
        tok, lab = buf["tokens"], buf["labels"]
        for g in range(indices.shape[0]):
            for b in range(indices.shape[1]):
                toks = self._tokens(int(indices[g, b]))
                tok[g, b] = toks[:-1]
                lab[g, b] = toks[1:]
        return buf


def make_batch_specs(cfg, shape, *, dtype_tokens=np.int32) -> Dict[str, Tuple]:
    """(shape, dtype) pairs for every model input at a given ShapeConfig —
    single source of truth shared by the data pipeline and input_specs()."""
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Tuple] = {}
    if cfg.frontend == "vision":
        from repro.models.frontends import frontend_feature_dim
        specs["input_embeds"] = ((b, s, frontend_feature_dim(cfg)), np.float32)
        specs["labels"] = ((b, s), dtype_tokens)
    elif cfg.frontend == "audio":
        from repro.models.frontends import frontend_feature_dim
        specs["tokens"] = ((b, s), dtype_tokens)
        specs["labels"] = ((b, s), dtype_tokens)
        specs["enc_feats"] = ((b, cfg.max_source_positions,
                               frontend_feature_dim(cfg)), np.float32)
    else:
        specs["tokens"] = ((b, s), dtype_tokens)
        specs["labels"] = ((b, s), dtype_tokens)
    return specs


class FeederPlacement:
    """Storage-shard reader placement using the paper's contention model.

    n_shards storage shards, each replicated `replica` ways over `n_feeders`
    feeder hosts (random placement, as HDFS). `readers_for` assigns each
    concurrent grain a feeder, preferring the replica with the fewest
    already-assigned readers — the deterministic analogue of Spark's
    sequential scheduling that the paper credits with reducing same-block
    contention (§3).
    """

    def __init__(self, n_feeders: int, n_shards: int, replica: int = 2,
                 seed: int = 0):
        if replica > n_feeders:
            raise ValueError("replica factor exceeds feeder count")
        rng = np.random.default_rng(seed)
        self.n_feeders = n_feeders
        self.placement = [rng.choice(n_feeders, size=replica, replace=False)
                          for _ in range(n_shards)]
        self.replica = replica
        self.n_shards = n_shards

    def readers_for(self, grain_shards: Sequence[int]) -> List[int]:
        load = np.zeros(self.n_feeders, np.int64)
        out = []
        for s in grain_shards:
            reps = self.placement[s % self.n_shards]
            pick = int(reps[np.argmin(load[reps])])
            load[pick] += 1
            out.append(pick)
        return out

    def expected_collision_prob(self, same_shard: bool) -> float:
        """Paper Claim 2 quantities for this placement's (n, r)."""
        if same_shard:
            return p_same_block(self.replica)
        return p_diff_block(self.n_feeders, self.replica)

    def max_concurrent_readers(self, grain_shards: Sequence[int]) -> int:
        counts = np.bincount(self.readers_for(grain_shards),
                             minlength=self.n_feeders)
        return int(counts.max())
