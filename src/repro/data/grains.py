"""Grains — the framework's "tasks".

A grain is a fixed-shape microbatch (grain_batch sequences). The HeMT
planner sizes each slice's *grain count* per step (macrotask = k_i grains);
the HomT baseline puts all grains in a shared queue and slices pull.

Grains are index ranges into the deterministic corpus, so reassigning a
grain (HeMT re-skew, work stealing, elastic replan) moves no data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence

import numpy as np

from repro.data.pipeline import SyntheticCorpus


@dataclass(frozen=True)
class Grain:
    """One microtask: global sample indices [start, start + size)."""
    step: int
    start: int
    size: int

    def indices(self) -> range:
        return range(self.start, self.start + self.size)


@dataclass
class GrainAssignment:
    """Per-slice grain lists for one global step."""
    step: int
    per_slice: Dict[str, List[Grain]]

    def counts(self) -> Dict[str, int]:
        return {k: len(v) for k, v in self.per_slice.items()}


def plan_grain_ranges(step: int, global_batch: int, grain_batch: int,
                      slice_names: Sequence[str], grain_counts: Sequence[int],
                      ) -> GrainAssignment:
    """Slice the step's index range [step*B, (step+1)*B) into grains and
    hand k_i consecutive grains to slice i (consecutive ranges = sequential
    reads on a storage-backed corpus — the paper's I/O-locality argument)."""
    n_grains = global_batch // grain_batch
    if sum(grain_counts) != n_grains:
        raise ValueError(f"grain counts {grain_counts} != {n_grains}")
    base = step * global_batch
    per: Dict[str, List[Grain]] = {}
    g = 0
    for name, k in zip(slice_names, grain_counts):
        per[name] = [Grain(step, base + (g + j) * grain_batch, grain_batch)
                     for j in range(k)]
        g += k
    return GrainAssignment(step, per)


class GrainSource:
    """Materializes grains for one slice from the deterministic corpus."""

    def __init__(self, corpus: SyntheticCorpus, grain_batch: int):
        self.corpus = corpus
        self.grain_batch = grain_batch

    def load(self, grain: Grain) -> Dict[str, np.ndarray]:
        return self.corpus.batch(list(grain.indices()))

    def load_many(self, grains: Sequence[Grain]) -> Iterator[Dict[str, np.ndarray]]:
        for g in grains:
            yield self.load(g)

    def load_stacked(self, grains: Sequence[Grain]) -> Dict[str, np.ndarray]:
        """A whole step's grains as [G, grain_batch, seq] arrays, filled
        into the corpus's preallocated block buffers — the trainer's
        one-dispatch-per-step path stacks nothing on the host.

        The arrays are reused by the next same-shape call: transfer or copy
        (e.g. ``jnp.asarray``) before loading the next step's block.
        """
        if any(g.size != self.grain_batch for g in grains):
            raise ValueError("load_stacked needs uniform grain_batch grains")
        starts = np.asarray([g.start for g in grains])
        return self.corpus.batch_block(
            starts[:, None] + np.arange(self.grain_batch))
