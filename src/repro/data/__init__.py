"""Data pipeline: deterministic synthetic corpus + HeMT grain sharding."""
from repro.data.pipeline import (  # noqa: F401
    FeederPlacement, SyntheticCorpus, make_batch_specs,
)
from repro.data.grains import Grain, GrainAssignment, GrainSource, plan_grain_ranges  # noqa: F401
