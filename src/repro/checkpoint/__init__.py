"""Fault-tolerant checkpointing: atomic writes, rotation, async, auto-resume."""
from repro.checkpoint.checkpointer import (  # noqa: F401
    load_pytree, restore_checkpoint, save_checkpoint,
)
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
