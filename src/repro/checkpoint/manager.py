"""Checkpoint lifecycle: rotation, async save, auto-resume.

Fault-tolerance contract (DESIGN.md §8): training must survive
kill-at-any-instant. Saves are atomic (see checkpointer); the manager keeps
the last `keep` complete checkpoints, prunes stragglers from crashed
writers, and `latest()`/`restore_latest()` always return the newest
*committed* step. `save_async` offloads serialization to a worker thread so
the train loop only blocks on the previous save (double-buffering — the
standard overlap trick).
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint.checkpointer import (
    restore_checkpoint, save_checkpoint,
)

Pytree = Any

_STEP_RE = re.compile(r"^step_(\d{8})$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._pending_err: List[BaseException] = []

    # -- discovery ---------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "_COMPLETE")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path_for(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Pytree, metadata: Optional[Dict] = None,
             ) -> str:
        path = save_checkpoint(self.directory, step, tree, metadata)
        self._rotate()
        return path

    def save_async(self, step: int, tree: Pytree,
                   metadata: Optional[Dict] = None) -> None:
        """Non-blocking save; blocks only if the previous one is unfinished.
        Caller must hand a host-side snapshot (jax.device_get) or accept the
        copy being taken here."""
        self.wait()
        import jax
        snapshot = jax.device_get(tree)   # host copy, frees devices to run on

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot, metadata)
                self._rotate()
            except BaseException as e:   # surfaced on next wait()
                self._pending_err.append(e)

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._pending_err:
            raise self._pending_err.pop()

    # -- restore -------------------------------------------------------------
    def restore_latest(self, like: Pytree) -> Optional[Tuple[int, Pytree, Dict]]:
        latest = self.latest()
        if latest is None:
            return None
        return restore_checkpoint(self.path_for(latest), like)

    # -- housekeeping ----------------------------------------------------------
    def _rotate(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.path_for(s), ignore_errors=True)
        # prune uncommitted debris from crashed writers
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith(".tmp_ckpt_"):
                shutil.rmtree(full, ignore_errors=True)
            m = _STEP_RE.match(name)
            if m and not os.path.exists(os.path.join(full, "_COMPLETE")):
                shutil.rmtree(full, ignore_errors=True)
