"""Atomic pytree checkpointing.

Layout: <dir>/step_<N>/ containing
  arrays.npz   — flattened pytree leaves keyed by '/'-joined key path
  meta.json    — step, leaf treedef info, user metadata, integrity digest
  _COMPLETE    — commit marker written LAST (atomic rename); readers treat
                 a step dir without the marker as garbage from a crashed
                 writer (restart-safe, the paper's revocable-instance case)

Works for arbitrary nested dict/list/tuple/NamedTuple pytrees of jnp/np
arrays + scalars. On multi-host fleets each host saves its addressable
shards (path suffix per process) — here single-process covers the dry-run
and examples.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

Pytree = Any

_SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat: Dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            # npz has no bf16: store the raw bits (dtype restored from the
            # `like` tree on load)
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    metadata: Optional[Dict] = None) -> str:
    """Atomically write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        digest = sum(int(np.sum(np.abs(v).astype(np.float64)) * 1000) % (1 << 31)
                     for v in flat.values()) % (1 << 31)
        meta = {"step": step, "n_leaves": len(flat), "digest": digest,
                "user": metadata or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        open(os.path.join(tmp, "_COMPLETE"), "w").close()
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_pytree(path: str, like: Pytree) -> Pytree:
    """Restore arrays into the structure of `like` (shape/dtype-checked)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    paths_like = jax.tree_util.tree_flatten_with_path(like)[0]
    leaves = []
    for path_e, leaf in paths_like:
        key = _SEP.join(_path_str(p) for p in path_e)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        want = jnp.asarray(leaf).dtype
        if want == jnp.bfloat16 and arr.dtype == np.uint16:
            arr = arr.view(jnp.bfloat16)
        leaves.append(jnp.asarray(arr, dtype=want))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(path: str, like: Pytree) -> Tuple[int, Pytree, Dict]:
    """Returns (step, tree, user metadata). Validates the commit marker."""
    if not os.path.exists(os.path.join(path, "_COMPLETE")):
        raise FileNotFoundError(f"{path} has no commit marker (partial write?)")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    tree = load_pytree(path, like)
    return meta["step"], tree, meta.get("user", {})
