"""Config dataclasses for models, shapes, meshes and training.

Every assigned architecture gets one module in ``repro.configs`` exporting a
``CONFIG: ModelConfig`` with the exact published hyper-parameters, plus a
``reduced()`` constructor used by CPU smoke tests (same family, tiny sizes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # Which layers carry an MoE FFN: layer_idx % every == offset.
    every: int = 1
    offset: int = 0
    # Capacity factor for dispatch buffers (per-expert slots = tokens/E * factor).
    capacity_factor: float = 1.25
    # HeMT-EP: per-expert-shard relative capacities (None = homogeneous).
    # The skewed router (paper Algorithm 1) uses these to bucket tokens.
    shard_capacities: Optional[Tuple[float, ...]] = None
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyper-parameters."""
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2            # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256           # SSD chunk length
    n_groups: int = 1          # B/C groups (like GQA for SSM)


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    # 0 = full attention. >0 = sliding-window size for *local* layers.
    sliding_window: int = 0
    # local:global pattern, e.g. (5, 1) = 5 local then 1 global per period.
    local_global: Tuple[int, int] = (0, 0)
    rope_style: str = "full"   # "full" | "half" (chatglm 2d-rope) | "none"
    rope_theta: float = 10_000.0
    causal: bool = True
    # softmax scale override (None -> 1/sqrt(head_dim))
    scale: Optional[float] = None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid interleave: 1 attention layer per `attn_period` layers (jamba 1:7 -> 8).
    # 0 => pure attention (or pure ssm if attention is None).
    attn_period: int = 0
    attn_offset: int = 0       # which index inside the period is the attention layer
    # Encoder-decoder (whisper): encoder_layers > 0 enables cross-attention decoder.
    encoder_layers: int = 0
    max_source_positions: int = 0
    frontend: str = "none"     # none | audio | vision  (stubs supply embeddings)
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    act: str = "silu"          # silu (SwiGLU) | gelu (plain MLP, whisper)
    glu: bool = True
    max_seq_len: int = 131_072
    sub_quadratic: bool = False  # eligible for long_500k decode
    dtype: str = "bfloat16"

    # ---- derived ----------------------------------------------------------
    @property
    def layer_period(self) -> int:
        """Structural repeat period for scan-over-layers grouping."""
        p = 1
        if self.attn_period:
            p = self.attn_period
        if self.moe is not None and self.moe.every > 1:
            import math
            p = p * self.moe.every // math.gcd(p, self.moe.every)
        if self.attention is not None and self.attention.local_global != (0, 0):
            lg = sum(self.attention.local_global)
            import math
            p = p * lg // math.gcd(p, lg)
        return p

    def layer_kind(self, idx: int) -> str:
        """'attn' | 'ssm' for layer `idx` of the decoder stack."""
        if self.ssm is not None and self.attention is None:
            return "ssm"
        if self.attn_period:
            return "attn" if idx % self.attn_period == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe.every == self.moe.offset

    def layer_is_global_attn(self, idx: int) -> bool:
        """For local:global sliding-window patterns (gemma3)."""
        if self.attention is None or self.attention.local_global == (0, 0):
            return True
        loc, glb = self.attention.local_global
        return idx % (loc + glb) >= loc


def padded_vocab_size(cfg: ModelConfig, multiple: int = 256) -> int:
    """Embedding tables are padded to a multiple of 256 so the vocab dim
    shards over a 16-way model axis for every arch (granite 49155, whisper
    51865, mamba2 50280 are not otherwise divisible). Pad logits are masked
    to -inf in the loss/serve paths."""
    v = cfg.vocab_size
    return ((v + multiple - 1) // multiple) * multiple


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count N (embedding included once if tied)."""
    n = 0
    d = cfg.d_model
    emb = cfg.vocab_size * d
    n += emb
    if not cfg.tie_embeddings:
        n += emb

    def attn_params() -> int:
        a = cfg.attention
        q = d * a.n_heads * a.head_dim
        kv = 2 * d * a.n_kv_heads * a.head_dim
        o = a.n_heads * a.head_dim * d
        return q + kv + o + 2 * d  # + pre/post norm scales

    def mlp_params(d_ff: int) -> int:
        per = (3 if cfg.glu else 2) * d * d_ff
        return per

    def moe_params() -> int:
        m = cfg.moe
        return m.n_experts * mlp_params(cfg.d_ff) + d * m.n_experts  # + router

    def ssm_params() -> int:
        s = cfg.ssm
        d_in = s.expand * d
        n_heads = d_in // s.head_dim
        zxbcdt = d * (2 * d_in + 2 * s.n_groups * s.state_dim + n_heads)
        conv = s.conv_width * (d_in + 2 * s.n_groups * s.state_dim)
        out = d_in * d
        extra = 2 * n_heads + d_in  # A_log, dt_bias, gate-norm scale
        return zxbcdt + conv + out + extra + 2 * d

    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            n += attn_params()
        else:
            n += ssm_params()
        if cfg.ssm is not None and cfg.attention is None:
            continue  # pure-SSM blocks (mamba2) have no separate FFN
        if cfg.layer_is_moe(i):
            n += moe_params()
        else:
            n += mlp_params(cfg.d_ff)
    # encoder stack (whisper)
    for _ in range(cfg.encoder_layers):
        n += attn_params() + mlp_params(cfg.d_ff)
        n += attn_params()  # decoder cross-attention paired per layer
    n += d  # final norm
    return n


def active_param_count(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: only top_k experts count)."""
    if cfg.moe is None:
        return param_count(cfg)
    full = param_count(cfg)
    d, m = cfg.d_model, cfg.moe
    per_exp = (3 if cfg.glu else 2) * d * cfg.d_ff
    n_moe_layers = sum(cfg.layer_is_moe(i) for i in range(cfg.n_layers))
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_exp
    return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


@dataclass(frozen=True)
class MeshConfig:
    """Production mesh + per-arch distribution strategy."""
    # Parallelism strategy knobs (consumed by runtime.sharding).
    fsdp: bool = False            # shard params over data axis too (ZeRO-3)
    fsdp_pod: bool = False        # let FSDP span the DCN "pod" axis too
                                  # (off: param gathers stay on ICI; the pod
                                  # axis only carries the grad all-reduce)
    bf16_optimizer: bool = False  # Gopher-style bf16 adam moments (>=100B models)
    remat: str = "none"           # none | dots | full
    sequence_parallel: bool = False
    expert_parallel: bool = False
    # HeMT-DP defaults
    grain_batch: int = 8          # per-grain micro-batch size (fixed shape)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    seed: int = 0
    # gradient compression on the cross-pod (DCN) axis
    compression: str = "none"     # none | topk | int8


@dataclass(frozen=True)
class ArchBundle:
    """Everything the launcher needs for one assigned architecture."""
    model: ModelConfig
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)

    def replace(self, **kw) -> "ArchBundle":
        return dataclasses.replace(self, **kw)
