"""Architecture registry: ``--arch <id>`` resolution for launcher & tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    ArchBundle, AttentionConfig, MeshConfig, ModelConfig, MoEConfig,
    SSMConfig, ShapeConfig, TrainConfig, active_param_count, param_count,
)
from repro.configs.shapes import (  # noqa: F401
    ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
    applicable_shapes, shape_skip_reason,
)

# arch id -> module name
_ARCH_MODULES: Dict[str, str] = {
    "dbrx-132b": "dbrx_132b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "gemma3-12b": "gemma3_12b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "granite-3-8b": "granite_3_8b",
    "chatglm3-6b": "chatglm3_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-medium": "whisper_medium",
    "mamba2-2.7b": "mamba2_2_7b",
    "pixtral-12b": "pixtral_12b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_bundle(arch: str) -> ArchBundle:
    return _module(arch).BUNDLE


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def all_bundles() -> Dict[str, ArchBundle]:
    return {a: get_bundle(a) for a in ARCH_IDS}
