"""chatglm3-6b — 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024,
2d-RoPE (rotary applied to half the head dims), GQA.  [arXiv:2406.12793; hf]
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    d_ff=13696,
    vocab_size=65_024,
    attention=AttentionConfig(n_heads=32, n_kv_heads=2, head_dim=128,
                              rope_style="half"),
    tie_embeddings=False,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=False, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  rope_style="half"),
        tie_embeddings=False,
        max_seq_len=128,
    )
