"""granite-moe-1b-a400m — 24L d_model=1024 16H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import (
    ArchBundle, AttentionConfig, MeshConfig, ModelConfig, MoEConfig,
)

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    d_ff=512,
    vocab_size=49_155,
    attention=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=64),
    moe=MoEConfig(n_experts=32, top_k=8),
    tie_embeddings=True,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=False, remat="full", sequence_parallel=True, expert_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        d_ff=32,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2),
        tie_embeddings=True,
        max_seq_len=128,
    )
