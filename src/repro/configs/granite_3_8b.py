"""granite-3-8b — 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155, GQA.
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    d_ff=12800,
    vocab_size=49_155,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    tie_embeddings=True,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=True, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        tie_embeddings=True,
        max_seq_len=128,
    )
