"""The four assigned input-shape sets (same for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), NOT ``train_step``. ``long_500k`` requires a
sub-quadratic architecture (cfg.sub_quadratic) and is skipped otherwise —
the skip is recorded as an explicit roofline-table row.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.configs.base import ModelConfig, ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES: List[ShapeConfig] = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPES: Dict[str, ShapeConfig] = {s.name: s for s in ALL_SHAPES}


def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a reason string if (cfg, shape) must be skipped, else None."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skipped(full-attn): 512k decode requires sub-quadratic attention"
    return None


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    return [s for s in ALL_SHAPES if shape_skip_reason(cfg, s) is None]
