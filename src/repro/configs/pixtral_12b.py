"""pixtral-12b — 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072,
pixtral-ViT frontend (STUB per the brief — ``input_specs()`` provides
precomputed patch embeddings) + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    d_ff=14336,
    vocab_size=131_072,
    attention=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                              rope_theta=1_000_000.0),
    frontend="vision",
    tie_embeddings=False,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=True, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-reduced",
        family="vlm",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        frontend="vision",
        tie_embeddings=False,
        max_seq_len=128,
    )
