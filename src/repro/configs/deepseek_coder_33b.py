"""deepseek-coder-33b — 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256,
llama-arch.  [arXiv:2401.14196; hf]
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    d_ff=19200,
    vocab_size=32_256,
    attention=AttentionConfig(n_heads=56, n_kv_heads=8, head_dim=128,
                              rope_theta=100_000.0),
    tie_embeddings=False,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=True, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        d_ff=160,
        vocab_size=256,
        attention=AttentionConfig(n_heads=8, n_kv_heads=2, head_dim=8),
        tie_embeddings=False,
        max_seq_len=128,
    )
