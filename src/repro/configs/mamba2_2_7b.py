"""mamba2-2.7b — 64L d_model=2560 (attention-free) vocab=50280 ssm_state=128,
SSD (state-space duality).  [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchBundle, MeshConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,            # mamba2 blocks have no separate FFN
    vocab_size=50_280,
    attention=None,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
    tie_embeddings=True,
    max_seq_len=1_048_576,
    sub_quadratic=True,
)

MESH = MeshConfig(fsdp=False, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-reduced",
        family="ssm",
        n_layers=2,
        d_model=64,
        d_ff=0,
        vocab_size=256,
        attention=None,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
        tie_embeddings=True,
        max_seq_len=128,
        sub_quadratic=True,
    )
