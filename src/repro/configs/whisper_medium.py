"""whisper-medium — enc-dec, 24L(enc)+24L(dec) d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865, conv audio frontend (STUB per the brief —
``input_specs()`` provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]

Decode shapes lower the *decoder* (self-attn KV cache + cross-attn over the
1500-frame encoder output). long_500k is skipped (full attention).
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    d_ff=4096,
    vocab_size=51_865,
    attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=64,
                              rope_style="none"),  # whisper: learned/sinusoidal pos
    encoder_layers=24,
    max_source_positions=1500,
    frontend="audio",
    act="gelu",
    glu=False,
    tie_embeddings=True,
    max_seq_len=448,   # whisper decoder max target positions
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=False, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-reduced",
        family="audio",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                                  rope_style="none"),
        encoder_layers=2,
        max_source_positions=32,
        frontend="audio",
        act="gelu",
        glu=False,
        tie_embeddings=True,
        max_seq_len=64,
    )
