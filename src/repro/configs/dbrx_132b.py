"""dbrx-132b — 40L d_model=6144 48H (GQA kv=8) d_ff=10752/expert, vocab=100352,
fine-grained MoE 16 experts top-4.  [hf:databricks/dbrx-base; unverified]
"""
from repro.configs.base import (
    ArchBundle, AttentionConfig, MeshConfig, ModelConfig, MoEConfig,
)

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    d_ff=10752,
    vocab_size=100_352,
    attention=AttentionConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    moe=MoEConfig(n_experts=16, top_k=4),
    tie_embeddings=False,
    sub_quadratic=False,
)

MESH = MeshConfig(fsdp=True, bf16_optimizer=True, remat="full", sequence_parallel=True,
                  expert_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return ModelConfig(
        name="dbrx-132b-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        d_ff=96,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2),
        tie_embeddings=False,
        max_seq_len=128,
    )
