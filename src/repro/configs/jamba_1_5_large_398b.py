"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave (1 attention layer
per 8), MoE every other layer.  [arXiv:2403.19887; hf]
"""
from repro.configs.base import (
    ArchBundle, AttentionConfig, MeshConfig, ModelConfig, MoEConfig, SSMConfig,
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65_536,
    attention=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                              rope_style="none"),  # jamba uses no positional enc
    moe=MoEConfig(n_experts=16, top_k=2, every=2, offset=1),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
    attn_period=8,           # 1 attention : 7 mamba
    attn_offset=4,           # attention mid-period, per the jamba paper
    tie_embeddings=False,
    max_seq_len=262_144,
    sub_quadratic=True,
)

MESH = MeshConfig(fsdp=True, bf16_optimizer=True, remat="full", sequence_parallel=True,
                  expert_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b-reduced",
        family="hybrid",
        n_layers=8,   # one full attn:mamba period
        d_model=64,
        d_ff=96,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  rope_style="none"),
        moe=MoEConfig(n_experts=4, top_k=2, every=2, offset=1),
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
        attn_period=8,
        attn_offset=4,
        tie_embeddings=False,
        max_seq_len=128,
        sub_quadratic=True,
    )
