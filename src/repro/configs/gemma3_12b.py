"""gemma3-12b — 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Sub-quadratic eligibility: 5/6 of layers are sliding-window (1024) local
attention; decode cost is O(window) for those and O(L) for the 1-in-6 global
layers, so long_500k decode is lowered for this arch (see DESIGN.md §5).
"""
from repro.configs.base import ArchBundle, AttentionConfig, MeshConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    d_ff=15360,
    vocab_size=262_144,
    attention=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=256,
                              sliding_window=1024, local_global=(5, 1),
                              rope_theta=1_000_000.0),
    act="gelu",
    glu=True,
    tie_embeddings=True,
    max_seq_len=131_072,
    sub_quadratic=True,
)

MESH = MeshConfig(fsdp=True, remat="full", sequence_parallel=True)

BUNDLE = ArchBundle(model=CONFIG, mesh=MESH)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b-reduced",
        family="dense",
        n_layers=6,   # one full 5:1 local:global period
        d_model=64,
        d_ff=128,
        vocab_size=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                                  sliding_window=16, local_global=(5, 1)),
        act="gelu",
        tie_embeddings=True,
        max_seq_len=128,
        sub_quadratic=True,
    )
