"""repro.analysis — hemt-lint, the engine's contract-enforcing analyzer.

Run it::

    PYTHONPATH=src python -m repro.analysis.lint src          # text
    PYTHONPATH=src python -m repro.analysis.lint --format=json src

See :mod:`repro.analysis.base` for the rule protocol and waiver syntax,
and the README "Static analysis" section for the rule table.
"""
from .base import (Finding, FileContext, Rule, all_rules, apply_waivers,
                   get_rule, parse_waivers, register)

__all__ = [
    "Finding", "FileContext", "Rule", "register", "all_rules", "get_rule",
    "parse_waivers", "apply_waivers",
    "LintReport", "lint_paths", "lint_source", "main", "self_check",
]

_LINT_NAMES = {"LintReport", "lint_paths", "lint_source", "main",
               "self_check"}


def __getattr__(name):
    # lazy: `python -m repro.analysis.lint` must not find the submodule
    # pre-imported by its own package (runpy RuntimeWarning)
    if name in _LINT_NAMES:
        from . import lint
        return getattr(lint, name)
    raise AttributeError(name)
