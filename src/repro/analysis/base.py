"""hemt-lint core: findings, file context, waivers, and the rule registry.

The engine's correctness story rests on conventions no type checker sees:
solve caches key by *value* on frozen hashable specs, differential oracles
pin paths at 1e-9 and therefore need seeded-``Generator``-only randomness,
and the jax twins must stay tracer-safe for the Pallas port.  ``hemt-lint``
makes those conventions machine-checked: each invariant is a :class:`Rule`
with a stable ``HLxxx`` code, precise ``file:line:col`` diagnostics, and
inline waivers.

Waiver syntax (checked by :func:`parse_waivers`)::

    x = t.io_mb != m   # hemt-lint: disable=HL004  exact-routing guard, ...
    # hemt-lint: disable=HL003  justification for the NEXT line
    t0 = time.time()

A waiver comment on its own line covers the following line (for statements
that would overflow the line-length budget); codes are comma-separated.
Waivers that suppress nothing are reported by the runner so they cannot
rot silently.

Adding a rule is three steps: subclass-free — write a class with ``code`` /
``name`` / ``description`` attributes and a ``check(ctx)`` generator,
decorate it with :func:`register`, and import the module from
``repro.analysis.rules``.  The CLI, JSON output, waivers, and the repo
self-check pick it up automatically.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass
from pathlib import PurePosixPath
from typing import Dict, Iterable, List, Optional, Protocol, Tuple, runtime_checkable

__all__ = [
    "Finding", "FileContext", "Rule", "register", "all_rules", "get_rule",
    "parse_waivers", "apply_waivers", "CODE_RE",
]

CODE_RE = re.compile(r"^HL\d{3}$")

_WAIVER_RE = re.compile(
    r"#\s*hemt-lint:\s*disable=([A-Z]{2}\d{3}(?:\s*,\s*[A-Z]{2}\d{3})*)")


@dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: where + which rule + why."""
    path: str
    line: int
    col: int
    code: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def to_json(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "col": self.col,
                "code": self.code, "message": self.message}


class FileContext:
    """Everything a rule gets to see about one file: source, parsed tree,
    and the (posix, repo-relative) path it uses for scoping decisions."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = PurePosixPath(path).as_posix()
        self.source = source
        self.tree = tree

    @classmethod
    def from_source(cls, source: str, path: str) -> "FileContext":
        return cls(path, source, ast.parse(source))

    @property
    def parts(self) -> Tuple[str, ...]:
        return PurePosixPath(self.path).parts

    @property
    def name(self) -> str:
        return PurePosixPath(self.path).name

    def in_dir(self, *names: str) -> bool:
        """True when any path component (not the filename) matches."""
        return any(n in self.parts[:-1] for n in names)

    @property
    def is_test(self) -> bool:
        return self.name.startswith("test_") or self.in_dir("tests")

    def finding(self, node: ast.AST, code: str, message: str) -> Finding:
        return Finding(self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), code, message)


@runtime_checkable
class Rule(Protocol):
    """The plugin protocol: stateless, one instance per registry entry.

    ``check`` yields raw findings; waiver filtering happens in the runner
    so rules never need to know the suppression syntax.
    """
    code: str
    name: str
    description: str

    def check(self, ctx: FileContext) -> Iterable[Finding]: ...


_REGISTRY: Dict[str, Rule] = {}


def register(rule_cls):
    """Class decorator: instantiate and index the rule by its code."""
    rule = rule_cls()
    if not CODE_RE.match(rule.code):
        raise ValueError(f"rule code {rule.code!r} must match HLxxx")
    if rule.code in _REGISTRY:
        raise ValueError(f"duplicate rule code {rule.code}")
    _REGISTRY[rule.code] = rule
    return rule_cls


def all_rules() -> List[Rule]:
    return [_REGISTRY[c] for c in sorted(_REGISTRY)]


def get_rule(code: str) -> Rule:
    return _REGISTRY[code]


def parse_waivers(source: str) -> Dict[int, frozenset]:
    """Map line number -> codes waived there.

    A waiver on a comment-only line also covers the next line, so long
    statements can carry their justification above themselves.  Real
    comment tokens only — a waiver spelled inside a string/docstring
    (like the examples in this module's docstring) does not count.
    """
    waivers: Dict[int, set] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return {}
    lines = source.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _WAIVER_RE.search(tok.string)
        if not m:
            continue
        lineno = tok.start[0]
        codes = {c.strip() for c in m.group(1).split(",")}
        waivers.setdefault(lineno, set()).update(codes)
        text = lines[lineno - 1] if lineno <= len(lines) else ""
        if text.lstrip().startswith("#"):          # standalone comment line
            waivers.setdefault(lineno + 1, set()).update(codes)
    return {ln: frozenset(cs) for ln, cs in waivers.items()}


def apply_waivers(findings: Iterable[Finding], waivers: Dict[int, frozenset],
                  ) -> Tuple[List[Finding], List[Finding],
                             List[Tuple[int, str]]]:
    """Split findings into (kept, suppressed) and report unused waivers
    as ``(line, code)`` pairs — a stale waiver is itself a smell."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    used: set = set()
    for f in findings:
        if f.code in waivers.get(f.line, frozenset()):
            suppressed.append(f)
            used.add((f.line, f.code))
        else:
            kept.append(f)
    unused: List[Tuple[int, str]] = []
    for ln, codes in sorted(waivers.items()):
        for code in sorted(codes):
            # a comment-only waiver registers for two lines; count it used
            # if either registration fired
            if (ln, code) in used or (ln - 1, code) in used \
                    or (ln + 1, code) in used:
                continue
            unused.append((ln, code))
    # the two-line registration of standalone comments would double-report
    seen: set = set()
    deduped: List[Tuple[int, str]] = []
    for ln, code in unused:
        if (ln - 1, code) in seen:
            continue
        seen.add((ln, code))
        deduped.append((ln, code))
    return kept, suppressed, deduped


# ---------------------------------------------------------------------------
# shared AST helpers used by several rules
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> set:
    """All bare Name identifiers appearing anywhere under ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def import_aliases(tree: ast.Module, module: str) -> set:
    """Local aliases bound to ``import <module>`` (e.g. numpy -> {np})."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    out.add(a.asname or a.name.split(".")[0])
    return out


def from_imports(tree: ast.Module, module: str) -> Dict[str, ast.ImportFrom]:
    """Names bound by ``from <module> import x [as y]`` -> their node."""
    out: Dict[str, ast.ImportFrom] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == module:
            for a in node.names:
                out[a.asname or a.name] = node
    return out
