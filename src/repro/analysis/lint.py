"""hemt-lint runner + CLI (``python -m repro.analysis.lint``).

Exit codes: 0 clean, 1 findings (or unused waivers), 2 usage/internal
error — so the CI job and the tier-1 self-check test can gate on it the
same way ``benchmarks/run.py --check`` gates perf.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Tuple

from .base import (CODE_RE, FileContext, Finding, all_rules, apply_waivers,
                   parse_waivers)
from . import rules as _rules  # noqa: F401  (imports register the rules)

SKIP_DIRS = {"__pycache__", ".git", ".hypothesis", ".ruff_cache"}


@dataclass
class LintReport:
    """Everything one run produced, in a JSON-able shape."""
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    unused_waivers: List[Tuple[str, int, str]] = field(default_factory=list)
    files_checked: int = 0

    @property
    def exit_code(self) -> int:
        return 1 if (self.findings or self.unused_waivers) else 0

    def to_json(self) -> dict:
        return {
            "version": 1,
            "files_checked": self.files_checked,
            "findings": [f.to_json() for f in self.findings],
            "suppressed": len(self.suppressed),
            "unused_waivers": [
                {"path": p, "line": ln, "code": c}
                for p, ln, c in self.unused_waivers],
            "counts": self.counts(),
        }

    def counts(self) -> dict:
        out: dict = {}
        for f in self.findings:
            out[f.code] = out.get(f.code, 0) + 1
        return out


def iter_python_files(paths: Sequence[str]) -> Iterable[Path]:
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in f.parts):
                    yield f
        elif p.suffix == ".py":
            yield p


def lint_source(source: str, path: str,
                select: Optional[Sequence[str]] = None) -> LintReport:
    """Lint one in-memory file.  ``path`` drives rule scoping, so tests
    hand fixture snippets virtual paths like ``src/repro/core/x.py``."""
    report = LintReport(files_checked=1)
    try:
        ctx = FileContext.from_source(source, path)
    except SyntaxError as exc:
        report.findings.append(Finding(
            path, exc.lineno or 1, exc.offset or 0, "HL000",
            f"syntax error: {exc.msg}"))
        return report
    raw: List[Finding] = []
    for rule in all_rules():
        if select and rule.code not in select:
            continue
        raw.extend(rule.check(ctx))
    waivers = parse_waivers(source)
    kept, suppressed, unused = apply_waivers(sorted(raw), waivers)
    report.findings = kept
    report.suppressed = suppressed
    # only police waivers for rules that actually ran, so a
    # --select run doesn't report every other rule's waiver as unused
    active = {r.code for r in all_rules()
              if not select or r.code in select}
    report.unused_waivers = [(ctx.path, ln, code) for ln, code in unused
                             if code in active]
    return report


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None) -> LintReport:
    total = LintReport()
    for f in iter_python_files(paths):
        sub = lint_source(f.read_text(encoding="utf-8"), f.as_posix(),
                          select)
        total.findings.extend(sub.findings)
        total.suppressed.extend(sub.suppressed)
        total.unused_waivers.extend(sub.unused_waivers)
        total.files_checked += 1
    total.findings.sort()
    return total


def repo_root() -> Path:
    """src/repro/analysis/lint.py -> the repo checkout root."""
    return Path(__file__).resolve().parents[3]


def self_check() -> LintReport:
    """The tree-is-clean gate: lint the repo's own ``src/`` from wherever
    the process runs (tier-1 pytest and the CI job both call this)."""
    return lint_paths([str(repo_root() / "src")])


def _parse_select(spec: str) -> List[str]:
    codes = [c.strip() for c in spec.split(",") if c.strip()]
    bad = [c for c in codes if not CODE_RE.match(c)]
    if bad:
        raise argparse.ArgumentTypeError(
            f"bad rule code(s) {bad}; expected HLxxx")
    return codes


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="hemt-lint: contract-enforcing static analysis for "
                    "the HeMT engine (determinism, hashability, "
                    "tracer-safety).")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files/directories to lint (default: src)")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--select", type=_parse_select, default=None,
                        metavar="HL001,HL004",
                        help="run only these rule codes")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    parser.add_argument("--output", default=None, metavar="FILE",
                        help="also write the report (in --format) here — "
                             "the CI job uploads this as an artifact")
    try:
        args = parser.parse_args(argv)
    except SystemExit as exc:        # argparse exits 2 on usage errors
        return int(exc.code or 0)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.code}  {rule.name:16s} {rule.description}")
        return 0

    report = lint_paths(args.paths, args.select)

    if args.format == "json":
        rendered = json.dumps(report.to_json(), indent=2, sort_keys=True)
    else:
        lines = [f.format() for f in report.findings]
        lines += [f"{p}:{ln}: unused waiver for {code}"
                  for p, ln, code in report.unused_waivers]
        summary = (f"{len(report.findings)} finding(s), "
                   f"{len(report.suppressed)} waived, "
                   f"{len(report.unused_waivers)} unused waiver(s) in "
                   f"{report.files_checked} file(s)")
        rendered = "\n".join(lines + [summary])
    print(rendered)
    if args.output:
        Path(args.output).write_text(rendered + "\n", encoding="utf-8")
    return report.exit_code


if __name__ == "__main__":
    sys.exit(main())
