"""HL001: solve-cache / dedup key dataclasses must be frozen and hashable.

``run_job``'s module-level solve LRU, the resident calendar's per-spec
solve reuse, and ``batched.dedup_rows`` all key by *value* on spec
objects.  A spec that is mutable, or carries an unhashable field
(``list`` / ``dict`` / ``set`` / ``np.ndarray``), either raises
``TypeError`` at first cache lookup or — worse — hashes by identity and
silently poisons the cache with stale solves.

Which dataclasses count as specs (the "reachable as a key" closure):

* an explicit allow-list of the engine's known key types
  (:data:`SPEC_ROOTS`: stage specs, mitigation policies, fault events,
  arrival traces, …),
* any dataclass whose name ends in ``Spec`` / ``Trace`` / ``Policy``
  (the repo's naming convention for hashable value specs), and
* transitively, any same-file dataclass named in a field annotation of
  one already in the closure (recursive hashability).

For every spec in the closure the rule requires ``frozen=True`` on the
decorator and flags fields annotated with unhashable container types.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..base import FileContext, Finding, register

SPEC_ROOTS = frozenset({
    # engine stage specs (run_job solve LRU keys)
    "PullSpec", "StaticSpec",
    # mitigation policies (hashable fields of the stage specs)
    "SpeculativeCopies", "WorkStealing", "ReskewHandoff",
    "DuplicatePlacement",
    # fault model (FaultTrace rides run_stage_events / resident splices)
    "NodeCrash", "SpotPreemption", "RetryPolicy", "FaultTrace",
    # arrival traces + serving request model (seeded value specs)
    "PoissonTrace", "DiurnalTrace", "MMPPTrace", "RequestModel",
    # capacity / resident value specs
    "BurstableNode", "ResizeEvent",
})

SPEC_SUFFIXES: Tuple[str, ...] = ("Spec", "Trace", "Policy")

UNHASHABLE_NAMES = frozenset({
    "list", "List", "dict", "Dict", "set", "Set", "bytearray",
    "ndarray", "MutableSequence", "MutableMapping", "MutableSet",
    "DefaultDict", "defaultdict", "OrderedDict", "Counter", "deque",
})


def _dataclass_frozen(cls: ast.ClassDef) -> Optional[bool]:
    """None if not a dataclass; else the frozen= value (False when absent
    or not a literal True)."""
    for dec in cls.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else (
            target.id if isinstance(target, ast.Name) else None)
        if name != "dataclass":
            continue
        if isinstance(dec, ast.Call):
            for kw in dec.keywords:
                if kw.arg == "frozen":
                    return isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True
            return False
        return False
    return None


def _annotation_names(ann: ast.AST) -> Set[str]:
    """Every type name mentioned anywhere in an annotation (handles
    Optional[...], Tuple[...], string forward references)."""
    names: Set[str] = set()
    stack: List[ast.AST] = [ann]
    while stack:
        node = stack.pop()
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name):
                names.add(sub.id)
            elif isinstance(sub, ast.Attribute):
                names.add(sub.attr)
            elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                try:
                    stack.append(ast.parse(sub.value, mode="eval").body)
                except SyntaxError:
                    pass
    return names


@register
class FrozenSpecRule:
    code = "HL001"
    name = "frozen-spec"
    description = ("solve-cache/dedup key dataclasses must be frozen=True "
                   "with recursively hashable field types")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test or not ctx.in_dir("repro"):
            return
        classes: Dict[str, ast.ClassDef] = {}
        frozen: Dict[str, bool] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ClassDef):
                fz = _dataclass_frozen(node)
                if fz is not None:
                    classes[node.name] = node
                    frozen[node.name] = fz

        specs: Set[str] = {n for n in classes
                           if n in SPEC_ROOTS or n.endswith(SPEC_SUFFIXES)}
        # same-file closure over field annotations (recursive hashability)
        changed = True
        while changed:
            changed = False
            for name in list(specs):
                for stmt in classes[name].body:
                    if not isinstance(stmt, ast.AnnAssign):
                        continue
                    for ref in _annotation_names(stmt.annotation):
                        if ref in classes and ref not in specs:
                            specs.add(ref)
                            changed = True

        for name in sorted(specs):
            cls = classes[name]
            if not frozen[name]:
                yield ctx.finding(
                    cls, self.code,
                    f"spec dataclass '{name}' is a solve-cache/dedup key "
                    f"type and must be @dataclass(frozen=True)")
            for stmt in cls.body:
                if not isinstance(stmt, ast.AnnAssign):
                    continue
                bad = _annotation_names(stmt.annotation) & UNHASHABLE_NAMES
                if bad:
                    field = stmt.target.id if isinstance(
                        stmt.target, ast.Name) else "<field>"
                    yield ctx.finding(
                        stmt, self.code,
                        f"spec field '{name}.{field}' is annotated with "
                        f"unhashable type(s) {sorted(bad)}; use "
                        f"Tuple/FrozenSet/Mapping-free equivalents so the "
                        f"spec stays hashable")
