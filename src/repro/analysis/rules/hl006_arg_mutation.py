"""HL006: closed-form solver functions must not mutate array parameters.

``run_job`` caches solves by spec *value* and replays them as O(n)
shifts; the batched planner dedups rows and fans one solve out to every
duplicate.  Both are sound only because solving is a pure function of
its inputs — a solver that sorts, scales, or writes into a caller's
array in place corrupts every later cache hit *and* the caller's spec.

Scope: functions whose names carry the solver prefixes
(:data:`SOLVER_PREFIXES`) in ``core/engine.py`` and ``core/batched.py``.
Flagged constructs, on any name aliasing a parameter:

* subscript stores (``works[i] = x``) and augmented subscript stores,
* augmented assignment to the bare name (``works += x`` is in-place for
  ndarrays),
* in-place methods (``.sort()``, ``.fill()``, ``.put()``, …).

Aliasing is tracked flow-insensitively: ``np.asarray`` / ``atleast_2d``
/ ``reshape`` / ``ravel`` / ``transpose`` / ``squeeze`` / views via
subscripts KEEP the taint (numpy returns no-copy views of an existing
ndarray), while any other rebinding (``x = x.copy()``,
``x = np.array(x)``, arithmetic) clears it.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Set, Tuple

from ..base import FileContext, Finding, register

SOLVER_PREFIXES: Tuple[str, ...] = (
    "_closed_form", "batched_closed", "pull_scan", "_pull",
    "_rel_summary", "dedup_rows", "_stage_result", "_as_2d",
    "_broadcast_overheads", "_finish_stats",
)

# numpy calls that may return a view of (or the very same) input array
ALIASING_CALLS = frozenset({
    "asarray", "asanyarray", "atleast_1d", "atleast_2d", "atleast_3d",
    "ravel", "reshape", "transpose", "squeeze", "view", "broadcast_to",
})

INPLACE_METHODS = frozenset({
    "sort", "fill", "put", "resize", "setflags", "itemset", "partition",
    "setfield", "byteswap",
    # list/dict mutators, should a solver take sequence params
    "append", "extend", "insert", "remove", "clear", "reverse", "pop",
    "update", "setdefault", "popitem",
})


def _subscript_root(node: ast.AST) -> Optional[str]:
    """a[i][j].b[k] -> 'a' (the name whose storage a store would hit)."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _names(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _Taint:
    def __init__(self, fn: ast.FunctionDef):
        a = fn.args
        self.names: Set[str] = {
            x.arg for x in (a.posonlyargs + a.args + a.kwonlyargs)}
        if a.vararg:
            self.names.add(a.vararg.arg)
        # flow-insensitive alias pass to fixpoint
        changed = True
        cleared: Set[str] = set()
        while changed:
            changed = False
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1):
                    continue
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    if self._aliases(node.value):
                        if tgt.id not in self.names:
                            self.names.add(tgt.id)
                            changed = True
                    elif tgt.id in self.names and tgt.id not in cleared:
                        # rebound to a fresh value (x = x.copy(), x = np.
                        # array(x), arithmetic): taint cleared
                        self.names.discard(tgt.id)
                        cleared.add(tgt.id)
                        changed = True
                elif isinstance(tgt, ast.Tuple) and self._aliases(node.value):
                    for el in tgt.elts:
                        if isinstance(el, ast.Name) \
                                and el.id not in self.names:
                            self.names.add(el.id)
                            changed = True

    def _aliases(self, value: ast.AST) -> bool:
        """Does this expression alias tainted storage?"""
        if isinstance(value, ast.Name):
            return value.id in self.names
        if isinstance(value, (ast.Subscript, ast.Attribute)):
            return _subscript_root(value) in self.names
        if isinstance(value, ast.Call):
            func = value.func
            fname = func.attr if isinstance(func, ast.Attribute) else (
                func.id if isinstance(func, ast.Name) else None)
            if fname in ALIASING_CALLS:
                # np.asarray(works) aliases; works.reshape(...) aliases
                if isinstance(func, ast.Attribute) \
                        and self._aliases(func.value):
                    return True
                return any(self._aliases(arg) for arg in value.args)
        return False


@register
class ArgMutationRule:
    code = "HL006"
    name = "arg-mutation"
    description = ("closed-form solver functions must not mutate array "
                   "parameters (in-place stores poison the value-keyed "
                   "solve caches)")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test or not ctx.in_dir("core"):
            return
        if ctx.name not in {"engine.py", "batched.py"}:
            return
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not fn.name.startswith(SOLVER_PREFIXES):
                continue
            taint = _Taint(fn)
            yield from self._check_fn(ctx, fn, taint)

    def _check_fn(self, ctx: FileContext, fn: ast.FunctionDef,
                  taint: "_Taint") -> Iterable[Finding]:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Subscript):
                        root = _subscript_root(tgt)
                        if root in taint.names:
                            yield ctx.finding(
                                node, self.code,
                                f"subscript store into parameter-aliased "
                                f"'{root}' in solver '{fn.name}'; copy "
                                f"before writing (solves must be pure)")
            elif isinstance(node, ast.AugAssign):
                tgt = node.target
                root = tgt.id if isinstance(tgt, ast.Name) \
                    else _subscript_root(tgt)
                if root in taint.names:
                    yield ctx.finding(
                        node, self.code,
                        f"in-place augmented assignment to "
                        f"parameter-aliased '{root}' in solver "
                        f"'{fn.name}'; copy before writing")
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in INPLACE_METHODS:
                root = _subscript_root(node.func.value)
                if root in taint.names:
                    yield ctx.finding(
                        node, self.code,
                        f"in-place .{node.func.attr}() on "
                        f"parameter-aliased '{root}' in solver "
                        f"'{fn.name}'; use the copying equivalent")
