"""HL003: no wall-clock reads outside ``benchmarks/``.

The simulator has exactly one notion of time — the event-calendar clock
threaded through ``run_stage_events`` / ``run_job`` / the resident
calendar.  A ``time.time()`` / ``perf_counter()`` / ``datetime.now()``
call inside ``src/`` either leaks host timing into simulated results
(nondeterministic oracles) or silently measures the wrong clock.
Real-time measurement belongs in ``benchmarks/`` (which is outside
``src/`` and therefore outside this rule's scope by construction).
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..base import FileContext, Finding, from_imports, import_aliases, register

TIME_FUNCS = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns",
    "perf_counter", "perf_counter_ns",
})
DATETIME_FUNCS = frozenset({"now", "utcnow", "today"})


@register
class WallClockRule:
    code = "HL003"
    name = "wall-clock"
    description = ("forbid time.time/perf_counter/datetime.now outside "
                   "benchmarks/ — simulation results must depend only on "
                   "the simulated clock")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test or ctx.in_dir("benchmarks"):
            return
        tree = ctx.tree
        time_aliases = import_aliases(tree, "time")
        dt_mod_aliases = import_aliases(tree, "datetime")
        dt_cls_names = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "datetime":
                for a in node.names:
                    if a.name in {"datetime", "date"}:
                        dt_cls_names.add(a.asname or a.name)

        for local, node in from_imports(tree, "time").items():
            if local in TIME_FUNCS:
                yield ctx.finding(
                    node, self.code,
                    f"wall-clock import ('{local}' from time); simulation "
                    f"code must use the simulated clock — real timing "
                    f"belongs in benchmarks/")

        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            base = node.value
            # time.time(), time.perf_counter(), ...
            if (node.attr in TIME_FUNCS and isinstance(base, ast.Name)
                    and base.id in time_aliases):
                yield ctx.finding(
                    node, self.code,
                    f"wall-clock read time.{node.attr}; simulation code "
                    f"must use the simulated clock — real timing belongs "
                    f"in benchmarks/")
                continue
            if node.attr not in DATETIME_FUNCS:
                continue
            # datetime.now() via `from datetime import datetime/date`
            if isinstance(base, ast.Name) and base.id in dt_cls_names:
                yield ctx.finding(
                    node, self.code,
                    f"wall-clock read {base.id}.{node.attr}(); simulation "
                    f"code must not depend on the host date/time")
            # datetime.datetime.now() via `import datetime`
            elif (isinstance(base, ast.Attribute)
                  and base.attr in {"datetime", "date"}
                  and isinstance(base.value, ast.Name)
                  and base.value.id in dt_mod_aliases):
                yield ctx.finding(
                    node, self.code,
                    f"wall-clock read datetime.{base.attr}.{node.attr}(); "
                    f"simulation code must not depend on the host date/time")
