"""HL002: simulation code may only use seeded ``np.random.Generator``s.

Every differential oracle in this repo pins the fast engine against a
naive rescan at 1e-9 on *randomized* inputs, and every trace spec
(``PoissonTrace`` …) promises bit-identical replay from its ``seed``
field.  Both guarantees die the moment simulation code touches
process-global RNG state: stdlib ``random.*``, the legacy
``np.random.*`` module functions (one hidden global ``RandomState``),
or an entropy-seeded ``default_rng()``.

Scope: ``core/``, ``runtime/``, ``workloads/`` (the deterministic
simulation layers).  ``jax.random`` is exempt — its keys are explicit
and splitting is pure.
"""
from __future__ import annotations

import ast
from typing import Iterable

from ..base import (FileContext, Finding, from_imports, import_aliases,
                    register)

# the non-legacy surface of numpy.random: everything else on the module is
# a hidden-global-state function (NPY002 territory)
NUMPY_RANDOM_ALLOWED = frozenset({
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64",
})


@register
class SeededRngRule:
    code = "HL002"
    name = "seeded-rng"
    description = ("core/runtime/workloads must use seeded "
                   "np.random.default_rng(seed); stdlib random and legacy "
                   "np.random.* module functions are forbidden")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test or not ctx.in_dir("core", "runtime", "workloads"):
            return
        tree = ctx.tree
        np_aliases = import_aliases(tree, "numpy")
        random_aliases = import_aliases(tree, "random")
        # `from numpy import random [as npr]` behaves like the module
        npr_aliases = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == "numpy":
                for a in node.names:
                    if a.name == "random":
                        npr_aliases.add(a.asname or a.name)

        # from random import shuffle / from numpy.random import seed
        for local, node in from_imports(tree, "random").items():
            yield ctx.finding(
                node, self.code,
                f"stdlib random import ('{local}') draws from unseedable "
                f"process-global state; use np.random.default_rng(seed)")
        for local, node in from_imports(tree, "numpy.random").items():
            if local not in NUMPY_RANDOM_ALLOWED:
                yield ctx.finding(
                    node, self.code,
                    f"legacy np.random function import ('{local}') mutates "
                    f"the hidden global RandomState; use "
                    f"np.random.default_rng(seed)")

        default_rng_names = {local for local in
                             from_imports(tree, "numpy.random")
                             if local == "default_rng"}

        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            base = node.value
            # random.shuffle(...) via `import random`
            if isinstance(base, ast.Name) and base.id in random_aliases:
                yield ctx.finding(
                    node, self.code,
                    f"stdlib random.{node.attr} draws from unseedable "
                    f"process-global state; use np.random.default_rng(seed)")
                continue
            # np.random.X  /  (from numpy import random).X
            is_np_random = (
                (isinstance(base, ast.Attribute) and base.attr == "random"
                 and isinstance(base.value, ast.Name)
                 and base.value.id in np_aliases)
                or (isinstance(base, ast.Name) and base.id in npr_aliases))
            if is_np_random and node.attr not in NUMPY_RANDOM_ALLOWED:
                yield ctx.finding(
                    node, self.code,
                    f"legacy np.random.{node.attr} mutates the hidden "
                    f"global RandomState; use np.random.default_rng(seed)")

        # unseeded default_rng(): entropy-seeded Generator breaks replay
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            is_default_rng = (
                (isinstance(func, ast.Attribute)
                 and func.attr == "default_rng")
                or (isinstance(func, ast.Name)
                    and func.id in default_rng_names))
            if is_default_rng and not node.args and not node.keywords:
                yield ctx.finding(
                    node, self.code,
                    "default_rng() with no seed draws OS entropy and breaks "
                    "deterministic replay; pass an explicit seed")
