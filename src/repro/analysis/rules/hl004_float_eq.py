"""HL004: no ``==`` / ``!=`` between float-typed expressions in solver code.

Every differential oracle pins the fast paths at 1e-9, and the
speculation trigger carries an explicit ``_EPS`` guard precisely because
``(start + thr) - start`` can round below ``thr`` at nonzero starts
(PR 5's shift-invariance bug).  A bare float equality in ``core/``
solver code is either that bug waiting to recur, or an *exact-routing
check* (e.g. "all io_mb identical -> symmetric closed form") that is
deliberately exact because inequality merely falls back to the event
path.  The former must be rewritten with a tolerance; the latter gets a
waiver whose justification documents why exactness is safe.

Float-typedness is decided by a local heuristic (no type inference):

* float literals (``x != 0.0``),
* ``float(...)`` casts,
* names annotated ``: float`` (parameters or assignments) or assigned
  from a float-typed expression, within the enclosing function,
* attribute reads of float-annotated dataclass fields declared in the
  same file, plus the engine's well-known cross-file float spec fields
  (:data:`KNOWN_FLOAT_ATTRS`).

Comparisons against integer literals or untyped names are not flagged —
precision over recall; the randomized oracles catch what this misses.
"""
from __future__ import annotations

import ast
from typing import Iterable, Set

from ..base import FileContext, Finding, register

# float-annotated spec fields compared across module boundaries
# (PullSpec.task_work / io_mb, SimTask.io_mb / cpu_work, fault times)
KNOWN_FLOAT_ATTRS = frozenset({
    "io_mb", "task_work", "cpu_work", "at", "recover_at", "warning",
    "grain", "carry",
})


def _is_float_annotation(ann: ast.AST) -> bool:
    return isinstance(ann, ast.Name) and ann.id == "float"


def _collect_file_float_attrs(tree: ast.Module) -> Set[str]:
    """Names of float-annotated dataclass fields declared in this file."""
    attrs: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for stmt in node.body:
            if (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)
                    and _is_float_annotation(stmt.annotation)):
                attrs.add(stmt.target.id)
    return attrs


class _FloatEnv:
    """Per-function set of names known to be float-typed."""

    def __init__(self, func: ast.AST, file_attrs: Set[str]):
        self.file_attrs = file_attrs
        self.names: Set[str] = set()
        if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = func.args
            for a in (args.posonlyargs + args.args + args.kwonlyargs):
                if a.annotation is not None \
                        and _is_float_annotation(a.annotation):
                    self.names.add(a.arg)
            # one forward pass: names assigned from float-typed exprs
            for node in ast.walk(func):
                if (isinstance(node, ast.AnnAssign)
                        and isinstance(node.target, ast.Name)
                        and _is_float_annotation(node.annotation)):
                    self.names.add(node.target.id)
                elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name) \
                        and self.is_float(node.value):
                    self.names.add(node.targets[0].id)

    def is_float(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return isinstance(node.value, float)
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "float":
            return True
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            return node.attr in self.file_attrs \
                or node.attr in KNOWN_FLOAT_ATTRS
        if isinstance(node, ast.BinOp):
            return self.is_float(node.left) or self.is_float(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_float(node.operand)
        return False


@register
class FloatEqRule:
    code = "HL004"
    name = "float-eq"
    description = ("== / != between float-typed expressions in core/ "
                   "solver modules; use a 1e-9 guard or waive documented "
                   "exact-routing checks")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test or not ctx.in_dir("core"):
            return
        file_attrs = _collect_file_float_attrs(ctx.tree)
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        scopes = funcs if funcs else []
        seen: Set[int] = set()
        for scope in scopes:
            env = _FloatEnv(scope, file_attrs)
            for node in ast.walk(scope):
                if not isinstance(node, ast.Compare) or id(node) in seen:
                    continue
                seen.add(id(node))
                operands = [node.left] + list(node.comparators)
                for op, left, right in zip(node.ops, operands,
                                           operands[1:]):
                    if not isinstance(op, (ast.Eq, ast.NotEq)):
                        continue
                    if env.is_float(left) or env.is_float(right):
                        sym = "==" if isinstance(op, ast.Eq) else "!="
                        yield ctx.finding(
                            node, self.code,
                            f"float {sym} in solver code; compare with a "
                            f"1e-9 tolerance (the oracles' pin) or waive "
                            f"with the exactness argument")
                        break
