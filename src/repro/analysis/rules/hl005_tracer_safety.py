"""HL005: jax-traced code must stay tracer-safe.

``kernels/`` and ``core/batched.py`` hold the jax twins of the numpy
closed forms — the staging ground for the ROADMAP's Pallas port.  Code
that traces today but concretizes a tracer (``if x > 0`` on a traced
value, ``.item()``, ``float(x)``) or produces a data-dependent shape
(``jnp.nonzero``, one-argument ``jnp.where``) fails only when the
enclosing ``jit`` / ``vmap`` / ``scan`` finally lands — the worst
possible time.  This rule flags those constructs *inside traced
functions* so the twins keep their jit-ability invariant.

What counts as traced (static heuristic, documented over-approximation):

* functions decorated with ``@jit`` / ``@jax.jit`` / ``@vmap`` /
  ``@pl.when(...)`` / ``@partial(jax.jit, ...)``,
* functions passed (directly, via a name, or via a
  ``functools.partial`` binding) to ``jit`` / ``vmap`` / ``pmap`` /
  ``lax.scan`` / ``lax.cond`` / ``lax.while_loop`` / ``lax.fori_loop``
  / ``lax.map`` / ``pl.pallas_call`` / ``checkpoint`` / ``remat``,
* and every function nested inside one of those (closures trace too).

Traced *values* are the function's positional parameters plus any local
assigned from one.  Keyword-only parameters and names listed in the
jit's ``static_argnames`` are static (python values at trace time), as
are ``is None`` tests and ``isinstance`` checks.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from ..base import FileContext, Finding, dotted_name, names_in, register

TRACE_ENTRY_FUNCS = frozenset({
    "jit", "vmap", "pmap", "scan", "cond", "while_loop", "fori_loop",
    "map", "pallas_call", "checkpoint", "remat", "associated_scan",
    "associative_scan", "custom_vjp", "custom_jvp",
})
TRACING_DECORATORS = frozenset({"jit", "vmap", "pmap", "when",
                                "checkpoint", "remat"})
DATA_DEP_SHAPE_FUNCS = frozenset({
    "nonzero", "flatnonzero", "argwhere", "unique", "extract", "compress",
})
CONCRETIZING_CASTS = frozenset({"float", "int", "bool", "complex"})


def _last_component(node: ast.AST) -> Optional[str]:
    d = dotted_name(node)
    return d.split(".")[-1] if d else None


def _decorator_static_argnames(dec: ast.AST) -> Set[str]:
    """static_argnames=(...) from a (partial-wrapped) jit decorator."""
    out: Set[str] = set()
    if isinstance(dec, ast.Call):
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) \
                            and isinstance(sub.value, str):
                        out.add(sub.value)
    return out


def _is_tracing_decorator(dec: ast.AST) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = _last_component(target)
    if name in TRACING_DECORATORS:
        return True
    # @partial(jax.jit, ...) / @functools.partial(jit, ...)
    if name == "partial" and isinstance(dec, ast.Call) and dec.args:
        return _last_component(dec.args[0]) in TRACING_DECORATORS
    return False


def _collect_traced_roots(tree: ast.Module) -> Dict[str, Set[str]]:
    """name -> static_argnames for every function the file traces."""
    funcs: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    traced: Dict[str, Set[str]] = {}

    for name, fn in funcs.items():
        for dec in fn.decorator_list:
            if _is_tracing_decorator(dec):
                traced.setdefault(name, set()).update(
                    _decorator_static_argnames(dec))

    # alias = f  /  alias = partial(f, ...) — resolve one level
    alias_of: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            val = node.value
            if isinstance(val, ast.Name) and val.id in funcs:
                alias_of[node.targets[0].id] = val.id
            elif isinstance(val, ast.Call) \
                    and _last_component(val.func) == "partial" \
                    and val.args and isinstance(val.args[0], ast.Name) \
                    and val.args[0].id in funcs:
                alias_of[node.targets[0].id] = val.args[0].id

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _last_component(node.func) not in TRACE_ENTRY_FUNCS:
            continue
        statics = _decorator_static_argnames(node)
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            target = None
            if isinstance(arg, ast.Name):
                target = alias_of.get(arg.id, arg.id)
            elif isinstance(arg, ast.Call) \
                    and _last_component(arg.func) == "partial" \
                    and arg.args and isinstance(arg.args[0], ast.Name):
                target = arg.args[0].id
            if target in funcs:
                traced.setdefault(target, set()).update(statics)
    return traced


def _traced_names(fn: ast.FunctionDef, statics: Set[str],
                  inherited: Set[str]) -> Set[str]:
    """Positional params + locals derived from them (fixpoint pass)."""
    args = fn.args
    names = {a.arg for a in (args.posonlyargs + args.args)} - statics
    names |= inherited
    if args.vararg:
        names.add(args.vararg.arg)
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and (names_in(node.value)
                                                 & names):
                for tgt in node.targets:
                    for sub in ast.walk(tgt):
                        if isinstance(sub, ast.Name) \
                                and sub.id not in names:
                            names.add(sub.id)
                            changed = True
    return names


def _is_static_test(test: ast.AST, traced: Set[str]) -> bool:
    """is None / isinstance / no traced name referenced -> static."""
    if not (names_in(test) & traced):
        return True
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        return True
    if isinstance(test, ast.Call) \
            and _last_component(test.func) == "isinstance":
        return True
    return False


@register
class TracerSafetyRule:
    code = "HL005"
    name = "tracer-safety"
    description = ("flag python control flow on traced values, .item(), "
                   "concretizing casts, and data-dependent shapes inside "
                   "jit/vmap/scan bodies in kernels/ and core/batched.py")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        if ctx.is_test:
            return
        if not (ctx.in_dir("kernels")
                or (ctx.in_dir("core") and ctx.name == "batched.py")):
            return
        roots = _collect_traced_roots(ctx.tree)
        funcs = {n.name: n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        # (fn, statics, inherited traced names); nested defs trace too
        work: List = [(funcs[name], statics, set())
                      for name, statics in roots.items() if name in funcs]
        emitted: Set = set()        # a fn can be both a root and nested
        while work:
            fn, statics, inherited = work.pop()
            traced = _traced_names(fn, statics, inherited)
            for f in self._check_body(ctx, fn, traced):
                key = (f.line, f.col, f.message)
                if key not in emitted:
                    emitted.add(key)
                    yield f
            for stmt in ast.walk(fn):
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and stmt is not fn:
                    work.append((stmt, set(), traced))

    def _check_body(self, ctx: FileContext, fn: ast.FunctionDef,
                    traced: Set[str]) -> Iterable[Finding]:
        nested = {n for sub in ast.walk(fn)
                  if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and sub is not fn
                  for n in ast.walk(sub)}
        for node in ast.walk(fn):
            if node in nested:        # reported by the nested visit
                continue
            if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                if not _is_static_test(test, traced):
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "conditional expression",
                            ast.Assert: "assert"}[type(node)]
                    yield ctx.finding(
                        node, self.code,
                        f"python {kind} on traced value(s) "
                        f"{sorted(names_in(test) & traced)} in traced "
                        f"function '{fn.name}'; use lax.cond/jnp.where")
            elif isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item":
                    yield ctx.finding(
                        node, self.code,
                        f".item() concretizes a tracer in traced function "
                        f"'{fn.name}'")
                    continue
                last = _last_component(node.func)
                if last in CONCRETIZING_CASTS \
                        and isinstance(node.func, ast.Name) and node.args \
                        and (names_in(node.args[0]) & traced):
                    yield ctx.finding(
                        node, self.code,
                        f"{last}() cast concretizes traced value(s) in "
                        f"traced function '{fn.name}'; use .astype/jnp "
                        f"ops instead")
                elif last in DATA_DEP_SHAPE_FUNCS:
                    yield ctx.finding(
                        node, self.code,
                        f"{last}() produces a data-dependent shape; not "
                        f"jit-able inside traced function '{fn.name}'")
                elif last == "where" and len(node.args) == 1:
                    yield ctx.finding(
                        node, self.code,
                        f"one-argument where() produces a data-dependent "
                        f"shape in traced function '{fn.name}'; pass "
                        f"(cond, x, y)")
