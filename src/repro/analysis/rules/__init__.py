"""Built-in hemt-lint rules.

Importing this package registers every rule with the
:mod:`repro.analysis.base` registry — one module per rule, named
``hlNNN_<slug>``.  A later PR adds a rule by dropping a module here and
importing it below; nothing else (CLI, JSON output, waivers, repo
self-check, CI job) needs to change.
"""
from . import hl001_frozen_spec   # noqa: F401
from . import hl002_seeded_rng    # noqa: F401
from . import hl003_wall_clock    # noqa: F401
from . import hl004_float_eq      # noqa: F401
from . import hl005_tracer_safety  # noqa: F401
from . import hl006_arg_mutation  # noqa: F401
