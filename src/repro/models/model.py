"""Top-level model: embeddings + stack(s) + head, train loss, decode step.

``init_params`` is jit/eval_shape-traceable so the dry-run can build
ShapeDtypeStruct pytrees for 100B+ configs without allocating.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, padded_vocab_size
from repro.models import frontends, transformer
from repro.models.layers import (
    embed, embedding_init, rmsnorm, rmsnorm_init, sinusoidal_positions, unembed,
)

Pytree = Any

NEG_INF = -1e30


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def mask_pad_logits(logits: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Embedding tables are padded to a 256 multiple (sharding divisibility);
    pad-vocab logits are forced to -inf so softmax mass is exact."""
    pv = padded_vocab_size(cfg)
    if pv == cfg.vocab_size:
        return logits
    valid = jnp.arange(pv) < cfg.vocab_size
    return jnp.where(valid, logits, NEG_INF)


def init_params(key, cfg: ModelConfig) -> Pytree:
    ks = jax.random.split(key, 6)
    dt = _dtype(cfg)
    pv = padded_vocab_size(cfg)
    p: Dict[str, Any] = {
        "embed": embedding_init(ks[0], pv, cfg.d_model, dt),
        "stack": transformer.stack_init(ks[1], cfg, cross=cfg.encoder_layers > 0,
                                        dtype=dt),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embedding_init(ks[2], pv, cfg.d_model, dt)
    if cfg.encoder_layers > 0:
        enc_cfg = _encoder_cfg(cfg)
        p["encoder"] = transformer.stack_init(ks[3], enc_cfg, dtype=dt)
        p["enc_norm"] = rmsnorm_init(cfg.d_model)
    if cfg.frontend != "none":
        p["adapter"] = frontends.adapter_init(ks[4], cfg, dt)
    return p


def params_axes(cfg: ModelConfig) -> Pytree:
    ax: Dict[str, Any] = {
        "embed": {"table": ("vocab", "embed")},
        "stack": transformer.stack_axes(cfg, cross=cfg.encoder_layers > 0),
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        ax["unembed"] = {"table": ("vocab", "embed")}
    if cfg.encoder_layers > 0:
        ax["encoder"] = transformer.stack_axes(_encoder_cfg(cfg))
        ax["enc_norm"] = {"scale": (None,)}
    if cfg.frontend != "none":
        ax["adapter"] = {"w": (None, "embed")}
    return ax


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, n_layers=cfg.encoder_layers, moe=None,
                               attn_period=0, ssm=None, encoder_layers=0)


def encode(params: Pytree, enc_feats: jnp.ndarray, cfg: ModelConfig, *,
           impl: str = "xla", remat: str = "none") -> jnp.ndarray:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    enc_cfg = _encoder_cfg(cfg)
    x = frontends.adapter_apply(params["adapter"], enc_feats) \
        if cfg.frontend != "none" else enc_feats
    s = x.shape[1]
    x = x + sinusoidal_positions(s, cfg.d_model)[None].astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(s)[None], x.shape[:2])
    x, _ = transformer.stack_apply(params["encoder"], x, enc_cfg, pos,
                                   causal=False, impl=impl, remat=remat)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def hidden_states(params: Pytree, tokens: Optional[jnp.ndarray],
                  cfg: ModelConfig, *,
                  input_embeds: Optional[jnp.ndarray] = None,
                  enc_feats: Optional[jnp.ndarray] = None,
                  impl: str = "xla", remat: str = "none", constrain=None,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Final-norm hidden states (B,S,D) + moe aux loss (pre-unembed)."""
    if input_embeds is not None:
        x = frontends.adapter_apply(params["adapter"], input_embeds)
    else:
        x = embed(params["embed"], tokens)
    if cfg.attention is not None and cfg.attention.rope_style == "none" \
            and cfg.encoder_layers > 0:
        # whisper: sinusoidal positions on decoder too
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)

    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    enc_out = None
    if cfg.encoder_layers > 0:
        assert enc_feats is not None, "enc-dec model requires enc_feats"
        enc_out = encode(params, enc_feats, cfg, impl=impl, remat=remat)

    if constrain is not None:
        x = constrain(x)
    x, aux = transformer.stack_apply(params["stack"], x, cfg, pos,
                                     enc_out=enc_out, impl=impl, remat=remat,
                                     constrain=constrain)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if constrain is not None:
        x = constrain(x, kind="hidden")
    return x, aux


def forward(params: Pytree, tokens: Optional[jnp.ndarray], cfg: ModelConfig, *,
            input_embeds: Optional[jnp.ndarray] = None,
            enc_feats: Optional[jnp.ndarray] = None,
            impl: str = "xla", remat: str = "none", constrain=None,
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits (B,S,V), moe_aux_loss)."""
    x, aux = hidden_states(params, tokens, cfg, input_embeds=input_embeds,
                           enc_feats=enc_feats, impl=impl, remat=remat,
                           constrain=constrain)
    head = params["unembed"] if "unembed" in params else params["embed"]
    logits = unembed(head, x)
    if constrain is not None:
        logits = constrain(logits, kind="logits")
    return logits, aux


# vocabularies at or above this size use the chunked softmax-xent (the fp32
# logits tensor of a 262k-vocab model is the single largest train buffer)
CHUNKED_XENT_VOCAB = 32_768
XENT_CHUNK = 4_096


def chunked_softmax_xent(x: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray, vocab_size: int,
                         chunk: int = XENT_CHUNK) -> jnp.ndarray:
    """Cross-entropy without materializing (B,S,V) logits.

    Scans vocab chunks with an online (max, sumexp, true-logit) carry; the
    per-chunk logits tile (B,S,C) is recomputed in the backward
    (jax.checkpoint), exactly like flash attention treats its probability
    tile. x: (B,S,D); table: (V_padded, D) (pad rows masked via vocab_size).
    Returns per-token nll (B,S) fp32.
    """
    v = table.shape[0]
    nc = -(-v // chunk)
    vp = nc * chunk
    if vp != v:
        table = jnp.pad(table, ((0, vp - v), (0, 0)))
    tchunks = table.reshape(nc, chunk, table.shape[1])

    def step(carry, inp):
        m_p, l_p, t_p = carry
        ci, tc = inp                                   # tc (C, D)
        logits = jnp.einsum("bsd,cd->bsc", x.astype(jnp.float32),
                            tc.astype(jnp.float32))
        gids = ci * chunk + jnp.arange(chunk)          # global vocab ids
        logits = jnp.where(gids[None, None, :] < vocab_size, logits, NEG_INF)
        m_c = jnp.max(logits, axis=-1)
        m_n = jnp.maximum(m_p, m_c)
        l_n = l_p * jnp.exp(m_p - m_n) + jnp.sum(
            jnp.exp(logits - m_n[..., None]), axis=-1)
        t_n = t_p + jnp.sum(
            jnp.where(labels[..., None] == gids[None, None, :], logits, 0.0),
            axis=-1)
        return (m_n, l_n, t_n), None

    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    b, s = labels.shape
    init = (jnp.full((b, s), NEG_INF, jnp.float32),
            jnp.zeros((b, s), jnp.float32), jnp.zeros((b, s), jnp.float32))
    (m, l, t), _ = jax.lax.scan(step, init, (jnp.arange(nc), tchunks))
    lse = jnp.log(l) + m
    return lse - t


def loss_fn(params: Pytree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, *,
            impl: str = "xla", remat: str = "none", constrain=None,
            ) -> jnp.ndarray:
    """Next-token cross-entropy (+ MoE aux). batch keys: tokens|input_embeds,
    labels, and enc_feats for enc-dec archs."""
    labels = batch["labels"]
    if padded_vocab_size(cfg) >= CHUNKED_XENT_VOCAB \
            and not os.environ.get("REPRO_NAIVE_LOSS") \
            and not os.environ.get("REPRO_DENSE_XENT"):
        x, aux = hidden_states(params, batch.get("tokens"), cfg,
                               input_embeds=batch.get("input_embeds"),
                               enc_feats=batch.get("enc_feats"),
                               impl=impl, remat=remat, constrain=constrain)
        head = params["unembed"] if "unembed" in params else params["embed"]
        nll = chunked_softmax_xent(x, head["table"], labels, cfg.vocab_size)
        mask = batch.get("loss_mask", jnp.ones_like(nll))
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux
    logits, aux = forward(params, batch.get("tokens"), cfg,
                          input_embeds=batch.get("input_embeds"),
                          enc_feats=batch.get("enc_feats"),
                          impl=impl, remat=remat, constrain=constrain)
    logits = mask_pad_logits(logits, cfg)
    if os.environ.get("REPRO_NAIVE_LOSS"):
        # the pre-iteration-1 formulation kept for §Perf A/B measurement:
        # take_along_axis over the vocab axis forces GSPMD to materialize
        # gathered fp32 logits
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask", jnp.ones_like(nll))
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux
    # Cross-entropy in logsumexp + select-reduce form: every op is
    # elementwise or a reduction along vocab, so GSPMD keeps the logits
    # vocab-sharded end-to-end (partial reductions + a scalar-ish
    # all-reduce) instead of all-gathering a (B,S,V) fp32 tensor for the
    # take_along_axis gather. See EXPERIMENTS.md §Perf iteration 1.
    logits_f = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits_f, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits_f - m), axis=-1)) + m[..., 0]
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    true_logit = jnp.sum(
        jnp.where(labels[..., None] == vocab_iota, logits_f, 0.0), axis=-1)
    nll = lse - true_logit
    mask = batch.get("loss_mask", jnp.ones_like(nll))
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0) + aux


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def prefill(params: Pytree, tokens: Optional[jnp.ndarray], cfg: ModelConfig,
            max_len: int, *, enc_feats: Optional[jnp.ndarray] = None,
            input_embeds: Optional[jnp.ndarray] = None,
            impl: str = "xla", remat: str = "none",
            ) -> Tuple[jnp.ndarray, Pytree]:
    """Process a prompt batch and build the decode state.

    tokens: (B, S) (or input_embeds (B, S, F) for vision prompts).
    Returns (last-token logits (B, V), decode state with cache filled and
    length = S) — the serving prefill step.
    """
    if input_embeds is not None:
        x = frontends.adapter_apply(params["adapter"], input_embeds)
    else:
        x = embed(params["embed"], tokens)
    if cfg.attention is not None and cfg.attention.rope_style == "none" \
            and cfg.encoder_layers > 0:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model)[None].astype(x.dtype)
    b, s = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    enc_out = None
    if cfg.encoder_layers > 0:
        assert enc_feats is not None, "enc-dec model requires enc_feats"
        enc_out = encode(params, enc_feats, cfg, impl=impl, remat=remat)

    x, cache, _ = transformer.stack_prefill(params["stack"], x, cfg, pos,
                                            max_len, enc_out=enc_out,
                                            impl=impl, remat=remat)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    head = params["unembed"] if "unembed" in params else params["embed"]
    logits = mask_pad_logits(unembed(head, x)[:, 0, :], cfg)
    state = {"cache": cache, "length": jnp.full((), s, jnp.int32)}
    return logits, state


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int) -> Pytree:
    dt = _dtype(cfg)
    return {
        "cache": transformer.stack_init_cache(cfg, batch, max_len, dtype=dt),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_step(params: Pytree, state: Pytree, token: jnp.ndarray,
                cfg: ModelConfig, *, enc_out: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, Pytree]:
    """token: (B,) int32. Returns (logits (B,V), new state)."""
    x = embed(params["embed"], token[:, None])
    if cfg.attention is not None and cfg.attention.rope_style == "none" \
            and cfg.encoder_layers > 0:
        # whisper: sinusoidal position for the current step, computed directly
        x = x + _sin_row(state["length"], cfg.d_model).astype(x.dtype)[None, None]

    x, new_cache = transformer.stack_decode_step(
        params["stack"], state["cache"], x, state["length"], cfg, enc_out=enc_out)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["unembed"] if "unembed" in params else params["embed"]
    logits = mask_pad_logits(unembed(head, x)[:, 0, :], cfg)
    return logits, {"cache": new_cache, "length": state["length"] + 1}


def _sin_row(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    import math as _m
    half = d // 2
    inv = jnp.exp(-_m.log(10_000.0) / max(half - 1, 1)
                  * jnp.arange(half, dtype=jnp.float32))
    scaled = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)])
