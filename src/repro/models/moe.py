"""Mixture-of-Experts FFN with capacity-based sort dispatch and HeMT
skewed-capacity routing.

The paper's Algorithm 1 (skewed hash partitioner) buckets shuffle records by
capacity-weighted ranges. In the MoE "shuffle" (token -> expert-shard
dispatch) we apply the same idea: per-expert slot capacities are made
proportional to the expert *shard* capacity vector supplied by the HeMT
planner, so a slow or contended expert shard receives proportionally fewer
tokens before overflow-drop, shrinking the synchronization delay at the MoE
barrier (the all-to-all + combine).

Dispatch is sort-based and *grouped by batch row*: each sequence dispatches
its own tokens, so under batch-sharded data parallelism the sort stays local
to the shard (no global resort — the collective cost is only the buffer
all-to-all that expert parallelism itself requires).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import Params, _dense_init


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig, glu: bool,
             dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    e = cfg.n_experts
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": _dense_init(ks[0], d_model, e, dtype=jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d_model, d_ff), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, d_ff, d_model), jnp.float32)
                   * (1.0 / math.sqrt(d_ff))).astype(dtype),
    }
    if glu:
        p["w_gate"] = (jax.random.normal(ks[3], (e, d_model, d_ff), jnp.float32)
                       * scale).astype(dtype)
    return p


def expert_capacities(cfg: MoEConfig, tokens_per_group: int):
    """Per-expert slot capacities (E,) — static numpy int array.

    Homogeneous: C_e = ceil(T*k/E * capacity_factor) for all e.
    HeMT (shard_capacities set): C_e proportional to relative shard capacity
    (paper Sec. 5.1: d_i = D * v_i / V), rounded by largest remainder so that
    sum stays equal to the homogeneous total (fixed buffer footprint).
    """
    import numpy as np
    e, k = cfg.n_experts, cfg.top_k
    total = int(math.ceil(tokens_per_group * k * cfg.capacity_factor))
    if cfg.shard_capacities is None:
        per = int(math.ceil(total / e))
        return np.full((e,), per, np.int32)
    v = np.asarray(cfg.shard_capacities, np.float64)
    share = v / v.sum() * total
    base = np.floor(share).astype(np.int32)
    rem = int(total - base.sum())
    order = np.argsort(-(share - np.floor(share)))
    base[order[:rem]] += 1
    return base


def moe_apply(params: Params, x: jnp.ndarray, cfg: MoEConfig, act: str = "silu",
              constrain=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D). Returns (out (B,S,D), aux_loss scalar).

    constrain: optional sharding hook; the dispatch buffers get kind
    "moe_buffer" = (batch over data, experts over "model", slots, d) — the
    expert-parallel all-to-all layout. Without it GSPMD is free to leave
    the (B, E*cap, D) scatter buffer replicated over the model axis."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    caps_np = expert_capacities(cfg, s)
    cap_buf = int(caps_np.max())  # rectangular buffer: max per-expert capacity
    caps = jnp.asarray(caps_np)

    logits = (x.astype(jnp.float32) @ params["router"])          # (B, S, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, k)                       # (B, S, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # ---- load-balancing aux loss (switch-style) --------------------------
    me = jnp.mean(gates, axis=(0, 1))                            # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.aux_loss_weight

    # ---- sort-based grouped dispatch -------------------------------------
    # flatten expert choices per batch row: (B, S*k)
    exp_flat = top_i.reshape(b, s * k)
    w_flat = top_w.reshape(b, s * k)
    tok_flat = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(s * k)
    tok_flat = jnp.broadcast_to(tok_flat, (b, s * k))

    order = jnp.argsort(exp_flat, axis=-1, stable=True)          # (B, S*k)
    exp_s = jnp.take_along_axis(exp_flat, order, -1)
    tok_s = jnp.take_along_axis(tok_flat, order, -1)
    w_s = jnp.take_along_axis(w_flat, order, -1)

    # position within its expert run: exp_s is sorted, so the run start of
    # expert e is searchsorted(exp_s, e) — O(S*k*logE) and (B, E) memory
    # instead of the (B, S*k, E) cumsum tensor (16.8 GB/layer for dbrx)
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left"))(exp_s)
    pos_in_exp = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        starts, exp_s, axis=1)                                   # (B, S*k)

    keep = pos_in_exp < caps[exp_s]
    slot = jnp.where(keep, exp_s * cap_buf + jnp.minimum(pos_in_exp, cap_buf - 1),
                     e * cap_buf)                                # drop slot

    # scatter tokens into (B, E*cap+1, D) then drop the overflow row
    src = jnp.take_along_axis(x, tok_s[..., None], axis=1)       # (B, S*k, D)
    buf = jnp.zeros((b, e * cap_buf + 1, d), x.dtype)
    buf = jax.vmap(lambda bf, sl, sr: bf.at[sl].set(sr))(buf, slot, src)
    buf = buf[:, : e * cap_buf].reshape(b, e, cap_buf, d)
    if constrain is not None:
        buf = constrain(buf, kind="moe_buffer")   # the EP all-to-all

    # ---- expert FFN -------------------------------------------------------
    activation = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
        up = activation(gate) * up
    else:
        up = activation(up)
    out_buf = jnp.einsum("becf,efd->becd", up, params["w_down"])
    if constrain is not None:
        out_buf = constrain(out_buf, kind="moe_buffer")
    out_buf = out_buf.reshape(b, e * cap_buf, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((b, 1, d), x.dtype)], axis=1)

    # ---- combine -----------------------------------------------------------
    gathered = jax.vmap(lambda bf, sl: bf[sl])(out_buf, slot)    # (B, S*k, D)
    gathered = gathered * (w_s * keep)[..., None].astype(x.dtype)
    out = jnp.zeros((b, s, d), x.dtype)
    out = jax.vmap(lambda o, t, g: o.at[t].add(g))(out, tok_s, gathered)
    return out, aux


def moe_apply_dense_fallback(params: Params, x: jnp.ndarray, cfg: MoEConfig,
                             act: str = "silu") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: route every token through its top-k experts exactly (no
    capacity drop). O(T * E) compute — used by tests as reference."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ params["router"]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    weights = jax.vmap(jax.vmap(lambda i, v: jnp.zeros((e,), jnp.float32)
                                .at[i].set(v)))(top_i, top_w)

    activation = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = jnp.einsum("bsd,edf->besf", x, params["w_up"])
    if "w_gate" in params:
        gate = jnp.einsum("bsd,edf->besf", x, params["w_gate"])
        up = activation(gate) * up
    else:
        up = activation(up)
    per_exp = jnp.einsum("besf,efd->besd", up, params["w_down"])
    out = jnp.einsum("besd,bse->bsd", per_exp.astype(jnp.float32), weights)

    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(top_i[..., 0], e), axis=(0, 1))
    aux = e * jnp.sum(me * ce) * cfg.aux_loss_weight
    return out.astype(x.dtype), aux
