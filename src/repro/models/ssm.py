"""Mamba2 block (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: within-chunk term is a (masked) attention-like dense
matmul; across chunks a small recurrence over per-chunk states. This is the
pure-jnp reference/train path; ``repro.kernels.ssd_scan`` provides the
Pallas TPU kernel for the same math.

Layout follows the Mamba2 paper: d_inner = expand*d_model split into heads of
size P=head_dim; per-head scalar decay a_t = exp(dt*A); B/C shared across
heads within a group (n_groups, like GQA).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import Params, _dense_init, rmsnorm, rmsnorm_init


def ssm_dims(d_model: int, cfg: SSMConfig) -> Dict[str, int]:
    d_inner = cfg.expand * d_model
    n_heads = d_inner // cfg.head_dim
    return dict(d_inner=d_inner, n_heads=n_heads, d_state=cfg.state_dim,
                n_groups=cfg.n_groups, conv_dim=d_inner + 2 * cfg.n_groups * cfg.state_dim)


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> Params:
    dims = ssm_dims(d_model, cfg)
    d_in, nh, ds, ng = dims["d_inner"], dims["n_heads"], dims["d_state"], dims["n_groups"]
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_in + 2 * ng * ds + nh  # [z, x, B, C, dt]
    return {
        "w_in": _dense_init(ks[0], d_model, in_dim, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, dims["conv_dim"]),
                                     jnp.float32) / math.sqrt(cfg.conv_width)
                   ).astype(dtype),
        "conv_b": jnp.zeros((dims["conv_dim"],), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": rmsnorm_init(d_in),
        "w_out": _dense_init(ks[2], d_in, d_model, dtype=dtype),
    }


def _split_proj(proj: jnp.ndarray, d_model: int, cfg: SSMConfig):
    dims = ssm_dims(d_model, cfg)
    d_in, nh, ds, ng = dims["d_inner"], dims["n_heads"], dims["d_state"], dims["n_groups"]
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * ng * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over sequence. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b.astype(out.dtype))


def ssd_scan_chunks(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                    B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                    init_state: jnp.ndarray = None, constrain=None,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD with the chunk axis *scanned* (one chunk's intra tensors live at
    a time) instead of batched — the memory-lean XLA lowering for long
    sequences; same math as `ssd_chunked`. The Pallas kernel streams chunks
    the same way (its VMEM state scratch is this scan's carry)."""
    bsz, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = (dt * a).reshape(bsz, nc, chunk, h)
    # intra-chunk matmul operands follow the model compute dtype (bf16 on
    # the bf16 path) with fp32 accumulation — halves the scan-saved VJP
    # residual stacks, the decays/cumsums stay fp32 (EXPERIMENTS cell B4)
    cdt = x.dtype if x.dtype == jnp.bfloat16 else jnp.float32
    xw = (x.astype(jnp.float32) * dt[..., None]).astype(cdt).reshape(
        bsz, nc, chunk, h, p)
    Bc = B.astype(cdt).reshape(bsz, nc, chunk, g, n)
    Cc = C.astype(cdt).reshape(bsz, nc, chunk, g, n)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(state, inp):
        dc, xc, bc, cc = inp            # (b,chunk,h), (b,chunk,h,p), (b,chunk,g,n)
        bch = jnp.repeat(bc, rep, axis=2)
        cch = jnp.repeat(cc, rep, axis=2)
        cum = jnp.cumsum(dc, axis=1)                       # (b,q,h)
        li = cum[:, :, None, :] - cum[:, None, :, :]       # (b,q,k,h)
        L = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        scores = jnp.einsum("bqhn,bkhn->bqkh", cch, bch,
                            preferred_element_type=jnp.float32) * L
        y = jnp.einsum("bqkh,bkhp->bqhp", scores.astype(cdt), xc,
                       preferred_element_type=jnp.float32)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bqhn,bhpn->bqhp", cch.astype(jnp.float32), state)
        decay_end = jnp.exp(cum[:, -1:, :] - cum)          # (b,q,h)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + jnp.einsum(
            "bqhn,bqhp,bqh->bhpn", bch.astype(jnp.float32),
            xc.astype(jnp.float32), decay_end)
        if constrain is not None:
            # keep the carried (and scan-saved) state head-sharded — the
            # saved-state stack is (n_chunks, B, H, P, N), the dominant
            # train-time buffer for big hybrid models (jamba)
            state = constrain(state, kind="ssm_state")
        return state, y

    # recompute the per-chunk score tile in the VJP instead of stacking all
    # (q x q x H) tiles across chunks (same trick as chunked_attention)
    step = jax.checkpoint(step, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (jnp.moveaxis(dta, 1, 0), jnp.moveaxis(xw, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    final, ys = jax.lax.scan(step, init_state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, final


# sequences at or above this length scan chunks instead of batching them
SSD_SCAN_THRESHOLD = 4096


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                B: jnp.ndarray, C: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray = None, constrain=None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.

    x:  (batch, S, H, P)   per-head inputs
    dt: (batch, S, H)      softplus'd step sizes
    B:  (batch, S, G, N), C: (batch, S, G, N); heads are grouped G|H
    Returns (y (batch,S,H,P), final_state (batch,H,P,N)).
    """
    s0 = x.shape[1]
    pad = (-s0) % chunk
    if pad:
        # zero-dt padding is inert: decay exp(0*a)=1, input dt*x=0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, fin = ssd_chunked(x, dt, a_log, B, C, chunk, init_state, constrain)
        return y[:, :s0], fin
    if x.shape[1] >= SSD_SCAN_THRESHOLD:
        return ssd_scan_chunks(x, dt, a_log, B, C, chunk, init_state,
                               constrain)
    bsz, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    rep = h // g

    a = -jnp.exp(a_log.astype(jnp.float32))              # (H,) negative
    dta = dt * a                                          # (B, S, H) log-decay
    xw = x * dt[..., None]                                # dt-weighted input

    # reshape into chunks
    xc = xw.reshape(bsz, nc, chunk, h, p)
    dc = dta.reshape(bsz, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(bsz, nc, chunk, g, n), rep, axis=3)  # (b,nc,q,H,N)
    Cc = jnp.repeat(C.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    cum = jnp.cumsum(dc, axis=2)                          # (b, nc, q, H)

    # ---- intra-chunk (dual / attention-like) ------------------------------
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,nc,q,q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], li, -jnp.inf))
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32)) * L
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc.astype(jnp.float32))

    # ---- chunk states ------------------------------------------------------
    # state_c = sum_j exp(cum_last - cum_j) * B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,q,H)
    states = jnp.einsum("bcqhn,bcqhp,bcqh->bchpn",
                        Bc.astype(jnp.float32), xc.astype(jnp.float32),
                        decay_to_end)                     # (b,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,H)

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        st_prev = carry
        st_c, dec_c = inp
        st_new = st_prev * dec_c[:, :, None, None] + st_c
        return st_new, st_prev

    states_t = jnp.moveaxis(states, 1, 0)                 # (nc, b, H, P, N)
    decay_t = jnp.moveaxis(chunk_decay, 1, 0)             # (nc, b, H)
    final, prev_states = jax.lax.scan(step, init_state, (states_t, decay_t))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (b, nc, H, P, N)

    # ---- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(cum)                       # (b,nc,q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Cc.astype(jnp.float32), prev_states, decay_from_start)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final


def ssm_apply(params: Params, x: jnp.ndarray, d_model: int, cfg: SSMConfig,
              impl: str = "xla", constrain=None) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D)."""
    dims = ssm_dims(d_model, cfg)
    d_in, nh, ds, ng = dims["d_inner"], dims["n_heads"], dims["d_state"], dims["n_groups"]
    bsz, s, _ = x.shape

    proj = x @ params["w_in"]
    z, xbc, dt = _split_proj(proj, d_model, cfg)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [d_in, d_in + ng * ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xs = xs.reshape(bsz, s, nh, cfg.head_dim)
    B = B.reshape(bsz, s, ng, ds)
    C = C.reshape(bsz, s, ng, ds)

    chunk = min(cfg.chunk, s)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xs, dt, params["a_log"], B, C, chunk=chunk)
    else:
        y, _ = ssd_chunked(xs, dt, params["a_log"], B, C, chunk,
                           constrain=constrain)
    y = y + xs.astype(jnp.float32) * params["d_skip"][:, None]
    y = y.reshape(bsz, s, d_in).astype(x.dtype)

    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    return y @ params["w_out"]


def ssm_prefill(params: Params, x: jnp.ndarray, d_model: int, cfg: SSMConfig,
                impl: str = "xla") -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence Mamba2 that also emits the decode cache
    (conv tail = last conv_width-1 *raw* xbc rows, and the final SSD state)."""
    dims = ssm_dims(d_model, cfg)
    d_in, nh, ds, ng = dims["d_inner"], dims["n_heads"], dims["d_state"], dims["n_groups"]
    bsz, s, _ = x.shape

    proj = x @ params["w_in"]
    z, xbc_raw, dt = _split_proj(proj, d_model, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [d_in, d_in + ng * ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    xs = xs.reshape(bsz, s, nh, cfg.head_dim)
    B = B.reshape(bsz, s, ng, ds)
    C = C.reshape(bsz, s, ng, ds)

    chunk = min(cfg.chunk, s)
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, final = kops.ssd_scan(xs, dt, params["a_log"], B, C, chunk=chunk)
    else:
        y, final = ssd_chunked(xs, dt, params["a_log"], B, C, chunk)
    y = y + xs.astype(jnp.float32) * params["d_skip"][:, None]
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out = y @ params["w_out"]

    # conv tail: last W-1 raw xbc rows (zero-padded on the left if s < W-1)
    w1 = cfg.conv_width - 1
    pad = jnp.pad(xbc_raw, ((0, 0), (w1, 0), (0, 0)))
    tail = jax.lax.dynamic_slice_in_dim(pad, s, w1, axis=1)
    return out, {"conv": tail.astype(x.dtype), "state": final}


# --------------------------------------------------------------------------
# decode (single-token recurrence)
# --------------------------------------------------------------------------

def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    dims = ssm_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, dims["conv_dim"]), dtype),
        "state": jnp.zeros((batch, dims["n_heads"], cfg.head_dim, dims["d_state"]),
                           jnp.float32),
    }


def ssm_decode_step(params: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                    d_model: int, cfg: SSMConfig,
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (B, 1, D). Single-step SSM recurrence: s' = a*s + dt*B x^T."""
    dims = ssm_dims(d_model, cfg)
    d_in, nh, ds, ng = dims["d_inner"], dims["n_heads"], dims["d_state"], dims["n_groups"]
    bsz = x.shape[0]

    proj = x[:, 0, :] @ params["w_in"]
    z, xbc, dt = _split_proj(proj, d_model, cfg)

    # conv cache: window of last (W-1) inputs
    conv_in = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B,W,C)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", conv_in.astype(jnp.float32),
                          w.astype(jnp.float32)) + params["conv_b"]
    xbc_act = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = conv_in[:, 1:, :]

    xs, B, C = jnp.split(xbc_act, [d_in, d_in + ng * ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B, H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                            # (B, H)

    xs = xs.reshape(bsz, nh, cfg.head_dim).astype(jnp.float32)
    rep = nh // ng
    Bh = jnp.repeat(B.reshape(bsz, ng, ds), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(bsz, ng, ds), rep, axis=1).astype(jnp.float32)

    dx = xs * dt[..., None]                                            # (B,H,P)
    state = cache["state"] * decay[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", dx, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xs * params["d_skip"][:, None]
    y = y.reshape(bsz, d_in).astype(x.dtype)

    y = rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    out = (y @ params["w_out"])[:, None, :]
    return out, {"conv": new_conv, "state": state}
