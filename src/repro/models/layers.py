"""Primitive layers: norms, rotary embeddings, MLPs, embeddings.

Pure-JAX, parameters are plain pytrees (nested dicts of jnp arrays).
Initializers take an explicit PRNGKey; forward fns are pure.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(dt)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float, style: str) -> jnp.ndarray:
    """Inverse frequencies. style='half' (chatglm 2d-rope) rotates only the
    first half of head dims, so it needs head_dim//4 frequencies."""
    rot = head_dim if style == "full" else head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               style: str = "full") -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    if style == "none":
        return x
    head_dim = x.shape[-1]
    inv = rope_frequencies(head_dim, theta, style)          # (rot/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]                      # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]

    if style == "half":
        rot_part, pass_part = jnp.split(x, 2, axis=-1)
    else:
        rot_part, pass_part = x, None

    xf = rot_part.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(rot_part.shape)
    rotated = rotated.astype(x.dtype)
    if pass_part is not None:
        return jnp.concatenate([rotated, pass_part], axis=-1)
    return rotated


def sinusoidal_positions(n_pos: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embedding table (n_pos, d)."""
    half = d // 2
    log_timescale = math.log(10_000.0) / max(half - 1, 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    scaled = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# --------------------------------------------------------------------------
# dense / GLU MLP
# --------------------------------------------------------------------------

def _dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None,
                dtype=jnp.bfloat16) -> jnp.ndarray:
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, glu: bool, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": _dense_init(ks[0], d_model, d_ff, dtype=dtype),
         "w_down": _dense_init(ks[1], d_ff, d_model, dtype=dtype)}
    if glu:
        p["w_gate"] = _dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp_apply(params: Params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    activation = jax.nn.silu if act == "silu" else jax.nn.gelu
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = activation(x @ params["w_gate"]) * up
    else:
        up = activation(up)
    return up @ params["w_down"]


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> Params:
    # stddev d^-0.5 keeps tied-unembedding logits O(1) at init
    tbl = (jax.random.normal(key, (vocab, d), jnp.float32)
           * (1.0 / math.sqrt(d))).astype(dtype)
    return {"table": tbl}


def embed(params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["table"].T
