"""Attention: GQA, causal / sliding-window masks, cross-attention, KV cache.

The XLA path (`dot_product_attention`) is the default for lowering/dry-run;
`repro.kernels.ops.flash_attention` provides the Pallas TPU kernel for the
same math (selected via ``impl='pallas'``).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import Params, _dense_init, apply_rope

NEG_INF = -1e30


def attention_init(key, d_model: int, cfg: AttentionConfig,
                   dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], d_model, cfg.n_heads * cfg.head_dim, dtype=dtype),
        "wk": _dense_init(ks[1], d_model, cfg.n_kv_heads * cfg.head_dim, dtype=dtype),
        "wv": _dense_init(ks[2], d_model, cfg.n_kv_heads * cfg.head_dim, dtype=dtype),
        "wo": _dense_init(ks[3], cfg.n_heads * cfg.head_dim, d_model, dtype=dtype),
    }


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: int) -> jnp.ndarray:
    """(..., Sq, Sk) additive bias. window>0 limits lookback (sliding window)."""
    rel = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(rel.shape, bool)
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    return jnp.where(ok, 0.0, NEG_INF)


def dot_product_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                          bias: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh). GQA via head grouping."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias[:, None, None, :, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, dh)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool, window: int, scale: float,
                      block_q: int = 512, block_k: int = 1024) -> jnp.ndarray:
    """Flash-equivalent streaming attention in pure XLA (lax.scan online
    softmax) — the compile target for long sequences where the dense
    (Sq x Sk) logits tensor must never materialize. Same math as
    ``dot_product_attention`` with arange positions; the Pallas kernel
    (`repro.kernels.flash_attention`) is the TPU-native twin.

    q: (B, Sq, Hq, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D).
    """
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_p = -(-sq // bq) * bq
    sk_p = -(-sk // bk) * bk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    nq, nk = sq_p // bq, sk_p // bk
    # (nq, B, Hkv, g, bq, D) / (nk, B, Hkv, bk, D)
    qb = q.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(b, nk, bk, hkv, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, bk, hkv, d).transpose(1, 0, 3, 2, 4)

    def q_block(args):
        qi, qt = args                                     # qt (B,Hkv,g,bq,D)
        q0 = qi * bq

        def kv_step(carry, inp):
            m_p, l_p, acc = carry
            ki, kt, vt = inp                              # kt (B,Hkv,bk,D)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qt.astype(jnp.float32),
                           kt.astype(jnp.float32)) * scale
            qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            rel = qpos - kpos
            ok = kpos < sk
            if causal:
                ok &= rel >= 0
            if window > 0:
                ok &= rel < window
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_c = jnp.max(s, axis=-1, keepdims=True)
            m_n = jnp.maximum(m_p, m_c)
            p = jnp.exp(s - m_n)
            alpha = jnp.exp(m_p - m_n)
            l_n = l_p * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p,
                                           vt.astype(jnp.float32))
            return (m_n, l_n, acc), None

        # flash-style backward: the (bq, bk) probability tile is REcomputed
        # in the VJP instead of saved per step — without these checkpoints
        # the scan/map VJPs stack all S^2 tiles (the whole point of flash
        # attention is to never materialize that)
        kv_step = jax.checkpoint(
            kv_step, policy=jax.checkpoint_policies.nothing_saveable)
        init = (jnp.full((b, hkv, g, bq, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, bq, 1), jnp.float32),
                jnp.zeros((b, hkv, g, bq, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init,
                                      (jnp.arange(nk), kb, vb))
        return acc / jnp.where(l == 0.0, 1.0, l)

    q_block = jax.checkpoint(
        q_block, policy=jax.checkpoint_policies.nothing_saveable)
    out = jax.lax.map(q_block, (jnp.arange(nq), qb))      # (nq,B,Hkv,g,bq,D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq_p, hq, d)
    return out[:, :sq].astype(q.dtype)


# sequences at or above this length stream through chunked_attention
CHUNKED_THRESHOLD = 2048


def attention_apply(params: Params, x: jnp.ndarray, cfg: AttentionConfig,
                    positions: jnp.ndarray, *, window_override: Optional[int] = None,
                    kv_source: Optional[jnp.ndarray] = None,
                    impl: str = "xla") -> jnp.ndarray:
    """Full-sequence attention (train / prefill).

    kv_source: if given, keys/values come from it (cross-attention, no mask,
    no rope on kv beyond source positions).
    """
    b, s, _ = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, s, hq, dh)

    cross = kv_source is not None
    src = kv_source if cross else x
    sk = src.shape[1]
    k = (src @ params["wk"]).reshape(b, sk, hkv, dh)
    v = (src @ params["wv"]).reshape(b, sk, hkv, dh)

    if not cross:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)
        window = cfg.sliding_window if window_override is None else window_override
        bias = _mask_bias(positions, positions, cfg.causal, window)
    else:
        bias = None

    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(dh)
    if impl == "pallas" and not cross:
        from repro.kernels import ops as kops
        window = cfg.sliding_window if window_override is None else window_override
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window,
                                   scale=scale)
    elif not cross and (impl == "chunked" or max(s, sk) >= CHUNKED_THRESHOLD):
        window = cfg.sliding_window if window_override is None else window_override
        out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                                scale=scale)
    else:
        out = dot_product_attention(q, k, v, bias, scale)
    return out.reshape(b, s, hq * dh) @ params["wo"]


def attention_prefill(params: Params, x: jnp.ndarray, cfg: AttentionConfig,
                      positions: jnp.ndarray, cache_len: int, *,
                      window_override: Optional[int] = None, impl: str = "xla",
                      ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Full-sequence self-attention that also emits the decode KV cache.

    Returns (out (B,S,D), cache {"k","v"} of (B, cache_len, Hkv, Dh)) laid
    out ring-buffer style: slot i holds the largest position p < S with
    p % cache_len == i (matches attention_decode_step's addressing).
    """
    b, s, _ = x.shape
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, s, hq, dh)
    k = (x @ params["wk"]).reshape(b, s, hkv, dh)
    v = (x @ params["wv"]).reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_style)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_style)

    window = cfg.sliding_window if window_override is None else window_override
    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(dh)
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=cfg.causal, window=window,
                                   scale=scale)
    elif impl == "chunked" or s >= CHUNKED_THRESHOLD:
        out = chunked_attention(q, k, v, causal=cfg.causal, window=window,
                                scale=scale)
    else:
        bias = _mask_bias(positions, positions, cfg.causal, window)
        out = dot_product_attention(q, k, v, bias, scale)
    out = out.reshape(b, s, hq * dh) @ params["wo"]

    # ring-layout fill: slot i <- position p = s-1 - ((s-1-i) mod cap), p>=0
    cap = cache_len
    idx = jnp.arange(cap)
    src = (s - 1) - jnp.mod((s - 1) - idx, cap)
    valid = src >= 0
    srcc = jnp.clip(src, 0, s - 1)
    gk = jnp.where(valid[None, :, None, None], jnp.take(k, srcc, axis=1), 0)
    gv = jnp.where(valid[None, :, None, None], jnp.take(v, srcc, axis=1), 0)
    return out, {"k": gk.astype(x.dtype), "v": gv.astype(x.dtype)}


# --------------------------------------------------------------------------
# KV-cache decode
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, cfg: AttentionConfig,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_decode_step(params: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                          cache_len: jnp.ndarray, cfg: AttentionConfig, *,
                          window_override: Optional[int] = None,
                          kv_source: Optional[jnp.ndarray] = None,
                          ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode. x: (B, 1, D); cache_len: scalar int32 (current length).

    The KV cache is a ring buffer of size cache['k'].shape[1]; for sliding
    window layers the cache is allocated at window size so wrap-around
    implements eviction for free.
    """
    b, one, _ = x.shape
    assert one == 1
    dh, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    cap = cache["k"].shape[1]

    q = (x @ params["wq"]).reshape(b, 1, hq, dh)
    cross = kv_source is not None
    if cross:
        # cross-attention: static kv from encoder output, no cache update
        sk = kv_source.shape[1]
        k = (kv_source @ params["wk"]).reshape(b, sk, hkv, dh)
        v = (kv_source @ params["wv"]).reshape(b, sk, hkv, dh)
        scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(dh)
        out = dot_product_attention(q, k, v, None, scale)
        return out.reshape(b, 1, hq * dh) @ params["wo"], cache

    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta, cfg.rope_style)
    k_new = (x @ params["wk"]).reshape(b, 1, hkv, dh)
    k_new = apply_rope(k_new, pos, cfg.rope_theta, cfg.rope_style)
    v_new = (x @ params["wv"]).reshape(b, 1, hkv, dh)

    slot = jnp.mod(cache_len, cap)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    # Ring buffer: absolute position stored at slot i is the largest p <= L
    # with p % cap == i, i.e. abs(i) = L - ((L - i) mod cap); L = cache_len
    # (the just-inserted token's position).
    idx = jnp.arange(cap)
    abs_pos = cache_len - jnp.mod(cache_len - idx, cap)
    valid = abs_pos >= 0
    window = cfg.sliding_window if window_override is None else window_override
    if window > 0:
        valid &= (cache_len - abs_pos) < window
    bias = jnp.where(valid, 0.0, NEG_INF)[None, None, :]  # (1, 1, cap)

    scale = cfg.scale if cfg.scale is not None else 1.0 / math.sqrt(dh)
    out = dot_product_attention(q, k_cache, v_cache,
                                jnp.broadcast_to(bias, (b, 1, cap)), scale)
    out = out.reshape(b, 1, hq * dh) @ params["wo"]
    return out, {"k": k_cache, "v": v_cache}
