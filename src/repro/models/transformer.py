"""Composable decoder/encoder stacks with scan-over-layer-groups.

Layers are grouped by the config's structural period (gemma3: 6 = 5 local +
1 global; jamba: 8 = 1 attn + 7 mamba with MoE every 2nd layer); parameters
are stacked with a leading ``(n_groups, ...)`` axis and the stack is applied
with ``jax.lax.scan`` so HLO size and compile time stay bounded for 40-72
layer models. Remat (activation checkpointing) wraps the scan body.

Every init function has a mirror ``*_axes`` function returning the same
pytree structure with *logical axis name tuples* instead of arrays; the
runtime maps logical names -> mesh axes (see runtime/sharding.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Params, mlp_apply, mlp_init, rmsnorm, rmsnorm_init,
)

Pytree = Any


# ==========================================================================
# single-layer init / axes / apply
# ==========================================================================

def _layer_init(key, cfg: ModelConfig, idx: int, *, cross: bool = False,
                causal: bool = True, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    kind = cfg.layer_kind(idx)
    p: Params = {"norm1": rmsnorm_init(cfg.d_model)}
    if kind == "attn":
        p["mixer"] = attn.attention_init(ks[0], cfg.d_model, cfg.attention, dtype)
    else:
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg.d_model, cfg.ssm, dtype)
    if cross:
        p["norm_cross"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attn.attention_init(ks[1], cfg.d_model, cfg.attention, dtype)
    if cfg.d_ff > 0 and not (kind == "ssm" and cfg.family == "ssm"):
        p["norm2"] = rmsnorm_init(cfg.d_model)
        if cfg.layer_is_moe(idx):
            p["ffn"] = moe_mod.moe_init(ks[2], cfg.d_model, cfg.d_ff, cfg.moe,
                                        cfg.glu, dtype)
        else:
            p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def _layer_axes(cfg: ModelConfig, idx: int, *, cross: bool = False) -> Pytree:
    """Logical axis names per leaf, mirroring _layer_init structure."""
    kind = cfg.layer_kind(idx)
    ax: Dict[str, Any] = {"norm1": {"scale": (None,)}}
    if kind == "attn":
        ax["mixer"] = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
                       "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    else:
        ax["mixer"] = {"w_in": ("embed", "ssm_inner"),
                       "conv_w": (None, "ssm_conv"), "conv_b": ("ssm_conv",),
                       "a_log": (None,), "dt_bias": (None,), "d_skip": (None,),
                       "gate_norm": {"scale": (None,)},
                       "w_out": ("ssm_inner", "embed")}
    if cross:
        ax["norm_cross"] = {"scale": (None,)}
        ax["cross"] = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
                       "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.d_ff > 0 and not (kind == "ssm" and cfg.family == "ssm"):
        ax["norm2"] = {"scale": (None,)}
        if cfg.layer_is_moe(idx):
            ax["ffn"] = {"router": ("embed", None),
                         "w_up": ("expert", "embed", "mlp"),
                         "w_down": ("expert", "mlp", "embed")}
            if cfg.glu:
                ax["ffn"]["w_gate"] = ("expert", "embed", "mlp")
        else:
            ax["ffn"] = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
            if cfg.glu:
                ax["ffn"]["w_gate"] = ("embed", "mlp")
    return ax


def _layer_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, idx: int,
                 positions: jnp.ndarray, *, enc_out: Optional[jnp.ndarray] = None,
                 causal: bool = True, impl: str = "xla", constrain=None,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm residual layer. Returns (x, moe_aux_loss)."""
    kind = cfg.layer_kind(idx)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        acfg = cfg.attention
        if not causal:
            acfg = attn.AttentionConfig(**{**acfg.__dict__, "causal": False})
        window = None
        if acfg.local_global != (0, 0):
            window = 0 if cfg.layer_is_global_attn(idx) else acfg.sliding_window
        h = attn.attention_apply(p["mixer"], h, acfg, positions,
                                 window_override=window, impl=impl)
    else:
        h = ssm_mod.ssm_apply(p["mixer"], h, cfg.d_model, cfg.ssm, impl=impl,
                              constrain=constrain)
    x = x + h
    if "cross" in p:
        h = rmsnorm(p["norm_cross"], x, cfg.norm_eps)
        h = attn.attention_apply(p["cross"], h, cfg.attention, positions,
                                 kv_source=enc_out, impl="xla")
        x = x + h
    if "ffn" in p:
        h = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.layer_is_moe(idx):
            h, aux = moe_mod.moe_apply(p["ffn"], h, cfg.moe, cfg.act,
                                       constrain=constrain)
        else:
            h = mlp_apply(p["ffn"], h, cfg.act)
        x = x + h
    return x, aux


# ==========================================================================
# stacked (scan) decoder stack
# ==========================================================================

def stack_init(key, cfg: ModelConfig, *, cross: bool = False,
               dtype=jnp.bfloat16) -> Params:
    """Stacked params: each leaf gains a leading (n_groups,) axis."""
    period = cfg.layer_period
    n_groups = cfg.n_layers // period
    assert cfg.n_layers % period == 0, (cfg.n_layers, period)

    def one_group(gkey):
        ks = jax.random.split(gkey, period)
        return {f"sub{j}": _layer_init(ks[j], cfg, j, cross=cross, dtype=dtype)
                for j in range(period)}

    return jax.vmap(one_group)(jax.random.split(key, n_groups))


def stack_axes(cfg: ModelConfig, *, cross: bool = False) -> Pytree:
    period = cfg.layer_period
    group = {f"sub{j}": _layer_axes(cfg, j, cross=cross) for j in range(period)}
    # prepend the scanned "layers" axis (never sharded) to every leaf
    return jax.tree.map(lambda t: ("layers",) + tuple(t), group,
                        is_leaf=lambda t: isinstance(t, tuple))


def stack_apply(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray, *, enc_out: Optional[jnp.ndarray] = None,
                causal: bool = True, impl: str = "xla", remat: str = "none",
                constrain=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """constrain: optional h -> h sharding hook applied to the residual
    stream at group boundaries (sequence-parallel saved activations)."""
    period = cfg.layer_period

    def group_body(carry, gparams):
        h, aux = carry
        for j in range(period):
            h, aux_j = _layer_apply(gparams[f"sub{j}"], h, cfg, j, positions,
                                    enc_out=enc_out, causal=causal, impl=impl,
                                    constrain=constrain)
            aux = aux + aux_j
        if constrain is not None:
            h = constrain(h)
        return (h, aux), None

    if remat == "full":
        group_body = jax.checkpoint(group_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (x, aux), _ = jax.lax.scan(group_body,
                               (x, jnp.zeros((), jnp.float32)), params)
    return x, aux


# ==========================================================================
# decode caches (stacked to match scan)
# ==========================================================================

def stack_init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     dtype=jnp.bfloat16, has_cross: bool = False) -> Pytree:
    """Per-layer decode caches, stacked (n_groups, ...) like the params.

    Sliding-window layers allocate only ``window`` slots (ring buffer).
    """
    period = cfg.layer_period
    n_groups = cfg.n_layers // period

    def one_layer(j):
        kind = cfg.layer_kind(j)
        if kind == "ssm":
            return ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
        acfg = cfg.attention
        length = max_len
        if acfg.local_global != (0, 0) and not cfg.layer_is_global_attn(j):
            length = min(max_len, acfg.sliding_window)
        elif acfg.sliding_window > 0 and acfg.local_global == (0, 0):
            length = min(max_len, acfg.sliding_window)
        return attn.init_kv_cache(batch, length, acfg, dtype)

    group = {f"sub{j}": one_layer(j) for j in range(period)}
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf[None], (n_groups,) + leaf.shape), group)


def cache_axes(cfg: ModelConfig) -> Pytree:
    """Logical axes for cache leaves: batch is data-sharded; kv heads on model."""
    period = cfg.layer_period

    def one_layer(j):
        if cfg.layer_kind(j) == "ssm":
            return {"conv": ("layers", "batch", None, "ssm_conv"),
                    "state": ("layers", "batch", "ssm_heads_cache", None, None)}
        return {"k": ("layers", "batch", "cache_seq", "kv_heads_cache", None),
                "v": ("layers", "batch", "cache_seq", "kv_heads_cache", None)}

    return {f"sub{j}": one_layer(j) for j in range(period)}


def stack_prefill(params: Params, x: jnp.ndarray, cfg: ModelConfig,
                  positions: jnp.ndarray, max_len: int, *,
                  enc_out: Optional[jnp.ndarray] = None, impl: str = "xla",
                  remat: str = "none",
                  ) -> Tuple[jnp.ndarray, Pytree, jnp.ndarray]:
    """Full-sequence pass that also builds the decode cache.

    Returns (hidden (B,S,D), cache pytree matching stack_init_cache(max_len),
    moe aux loss). Cache slots follow the decode ring-buffer layout so
    stack_decode_step continues seamlessly with cache_len = S.
    """
    period = cfg.layer_period

    def cache_len_for(j: int) -> int:
        acfg = cfg.attention
        if acfg.local_global != (0, 0) and not cfg.layer_is_global_attn(j):
            return min(max_len, acfg.sliding_window)
        if acfg.sliding_window > 0 and acfg.local_global == (0, 0):
            return min(max_len, acfg.sliding_window)
        return max_len

    def group_body(carry, gparams):
        h, aux = carry
        gcache = {}
        for j in range(period):
            p = gparams[f"sub{j}"]
            kind = cfg.layer_kind(j)
            hin = rmsnorm(p["norm1"], h, cfg.norm_eps)
            if kind == "attn":
                acfg = cfg.attention
                window = None
                if acfg.local_global != (0, 0):
                    window = 0 if cfg.layer_is_global_attn(j) else acfg.sliding_window
                out, c = attn.attention_prefill(p["mixer"], hin, acfg, positions,
                                                cache_len_for(j),
                                                window_override=window, impl=impl)
            else:
                out, c = ssm_mod.ssm_prefill(p["mixer"], hin, cfg.d_model,
                                             cfg.ssm, impl=impl)
            h = h + out
            if "cross" in p:
                hin = rmsnorm(p["norm_cross"], h, cfg.norm_eps)
                out = attn.attention_apply(p["cross"], hin, cfg.attention,
                                           positions, kv_source=enc_out,
                                           impl="xla")
                h = h + out
            if "ffn" in p:
                hin = rmsnorm(p["norm2"], h, cfg.norm_eps)
                if cfg.layer_is_moe(j):
                    out, aux_j = moe_mod.moe_apply(p["ffn"], hin, cfg.moe, cfg.act)
                    aux = aux + aux_j
                else:
                    out = mlp_apply(p["ffn"], hin, cfg.act)
                h = h + out
            gcache[f"sub{j}"] = c
        return (h, aux), gcache

    if remat == "full":
        group_body = jax.checkpoint(group_body,
                                    policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (x, aux), cache = jax.lax.scan(group_body,
                                   (x, jnp.zeros((), jnp.float32)), params)
    return x, cache, aux


def stack_decode_step(params: Params, cache: Pytree, x: jnp.ndarray,
                      cache_len: jnp.ndarray, cfg: ModelConfig, *,
                      enc_out: Optional[jnp.ndarray] = None,
                      ) -> Tuple[jnp.ndarray, Pytree]:
    """One-token decode through the whole stack. x: (B, 1, D)."""
    period = cfg.layer_period

    def group_body(h, scanned):
        gparams, gcache = scanned
        new_gcache = {}
        for j in range(period):
            p, c = gparams[f"sub{j}"], gcache[f"sub{j}"]
            kind = cfg.layer_kind(j)
            hin = rmsnorm(p["norm1"], h, cfg.norm_eps)
            if kind == "attn":
                acfg = cfg.attention
                window = None
                if acfg.local_global != (0, 0):
                    window = 0 if cfg.layer_is_global_attn(j) else acfg.sliding_window
                out, c = attn.attention_decode_step(p["mixer"], hin, c, cache_len,
                                                    acfg, window_override=window)
            else:
                out, c = ssm_mod.ssm_decode_step(p["mixer"], hin, c,
                                                 cfg.d_model, cfg.ssm)
            h = h + out
            if "cross" in p:
                hin = rmsnorm(p["norm_cross"], h, cfg.norm_eps)
                out, _ = attn.attention_decode_step(p["cross"], hin, c, cache_len,
                                                    cfg.attention, kv_source=enc_out)
                h = h + out
            if "ffn" in p:
                hin = rmsnorm(p["norm2"], h, cfg.norm_eps)
                if cfg.layer_is_moe(j):
                    out, _ = moe_mod.moe_apply(p["ffn"], hin, cfg.moe, cfg.act)
                else:
                    out = mlp_apply(p["ffn"], hin, cfg.act)
                h = h + out
            new_gcache[f"sub{j}"] = c
        return h, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params, cache))
    return x, new_cache
