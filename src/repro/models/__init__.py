"""Model zoo: composable pure-JAX definitions for all assigned architectures."""
from repro.models.model import (  # noqa: F401
    decode_step, forward, init_decode_state, init_params, loss_fn, params_axes,
)
