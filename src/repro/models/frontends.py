"""Modality frontends.

Per the brief, [audio]/[vlm] entries specify the transformer BACKBONE only;
the frontend is a STUB — ``input_specs()`` provides precomputed frame/patch
embeddings. These helpers define the stub embedding shapes and a linear
adapter that maps frontend features into the backbone d_model.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _dense_init

# Feature dims the (stubbed) frontends would emit.
AUDIO_FEATURE_DIM = 128      # e.g. 128-bin log-mel frame stack after conv
VISION_FEATURE_DIM = 1024    # pixtral-ViT patch embedding dim


def frontend_feature_dim(cfg: ModelConfig) -> int:
    return {"audio": AUDIO_FEATURE_DIM, "vision": VISION_FEATURE_DIM}[cfg.frontend]


def adapter_init(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    return {"w": _dense_init(key, frontend_feature_dim(cfg), cfg.d_model, dtype=dtype)}


def adapter_apply(params: Params, feats: jnp.ndarray) -> jnp.ndarray:
    # frontend stubs may hand fp32 features; keep the backbone in param dtype
    return feats.astype(params["w"].dtype) @ params["w"]


def stub_feature_shape(cfg: ModelConfig, batch: int, seq: int) -> Tuple[int, ...]:
    """Shape of the precomputed embeddings input_specs() hands the backbone."""
    return (batch, seq, frontend_feature_dim(cfg))
