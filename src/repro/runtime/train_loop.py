"""Train-step factory + HeMT grain accumulation.

Two granularities:

* ``make_train_step`` — one jit-able global step (whole global batch in one
  program). This is what the multi-pod dry-run lowers: batch sharded over
  ("pod","data"), params per the bundle's sharding rules, AdamW fused in.

* ``make_grain_step`` / ``make_apply_step`` — HeMT-DP decomposition: a
  grain step accumulates loss/grads over one fixed-shape microbatch; the
  apply step consumes the (weighted) accumulated gradient at the barrier.
  The accumulation trip count is a *host-side* loop so each slice can run
  its own k_i (the paper's macrotask size) between barriers.

* ``make_grain_accumulate`` / ``grain_accumulate_cached`` — batched fast
  path: the stacked grains of a whole step ([G, grain_batch, seq]) are
  folded into one GrainAcc with a single jitted ``lax.scan`` dispatch
  instead of G Python-dispatched grain steps.  The step's grain count is
  fixed (global_batch // grain_batch), so the scan traces once per config;
  ``grain_accumulate_cached`` keys a module-level jit cache on the (frozen,
  hashable) config bundle so drivers built repeatedly — benchmarks sweeping
  modes, elastic restarts — reuse the compiled program.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchBundle, ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.compression import (
    CompressionState, compress_decompress, compression_init,
)
from repro.optim.schedule import warmup_cosine

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: AdamWState
    step: jnp.ndarray          # () int32
    ef: Pytree                 # compression error-feedback (possibly empty {})


def train_state_init(key, cfg: ModelConfig, bundle: ArchBundle) -> TrainState:
    params = init_params(key, cfg)
    moment_dtype = "bfloat16" if bundle.mesh.bf16_optimizer else "float32"
    opt = adamw_init(params, moment_dtype)
    ef: Pytree = {}
    if bundle.train.compression != "none":
        ef = compression_init(params).error
    return TrainState(params, opt, jnp.zeros((), jnp.int32), ef)


def _loss_with_aux(params, batch, cfg, impl, remat, constrain=None):
    return loss_fn(params, batch, cfg, impl=impl, remat=remat,
                   constrain=constrain)


def make_train_step(cfg: ModelConfig, bundle: ArchBundle, *, impl: str = "xla",
                    constrain=None,
                    ) -> Callable[[TrainState, Dict[str, jnp.ndarray]],
                                  Tuple[TrainState, Dict[str, jnp.ndarray]]]:
    """constrain: optional residual-stream sharding hook (sequence-parallel
    saved activations — see runtime.sharding.make_activation_constraint)."""
    tc = bundle.train
    remat = bundle.mesh.remat

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray],
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        loss, grads = jax.value_and_grad(_loss_with_aux)(
            state.params, batch, cfg, impl, remat, constrain)
        ef = state.ef
        if tc.compression != "none":
            sent, new_cs = compress_decompress(
                grads, CompressionState(ef), scheme=tc.compression)
            grads, ef = sent, new_cs.error
        lr = warmup_cosine(state.step, peak_lr=tc.lr,
                           warmup_steps=tc.warmup_steps,
                           total_steps=tc.total_steps)
        params, opt, gnorm = adamw_update(
            grads, state.opt, state.params, lr=lr, beta1=tc.beta1,
            beta2=tc.beta2, weight_decay=tc.weight_decay,
            grad_clip=tc.grad_clip)
        new_state = TrainState(params, opt, state.step + 1, ef)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


# --------------------------------------------------------------------------
# HeMT-DP grain decomposition
# --------------------------------------------------------------------------

class GrainAcc(NamedTuple):
    grads: Pytree
    loss_sum: jnp.ndarray
    n: jnp.ndarray             # grains accumulated


def grain_acc_init(params: Pytree) -> GrainAcc:
    return GrainAcc(
        grads=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        loss_sum=jnp.zeros(()), n=jnp.zeros((), jnp.int32))


def make_grain_step(cfg: ModelConfig, bundle: ArchBundle, *, impl: str = "xla",
                    jit: bool = True) -> Callable:
    remat = bundle.mesh.remat

    def grain_step(params: Pytree, acc: GrainAcc,
                   grain: Dict[str, jnp.ndarray]) -> GrainAcc:
        loss, grads = jax.value_and_grad(_loss_with_aux)(
            params, grain, cfg, impl, remat)
        return GrainAcc(
            grads=jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc.grads, grads),
            loss_sum=acc.loss_sum + loss, n=acc.n + 1)

    return jax.jit(grain_step) if jit else grain_step


def make_grain_accumulate(cfg: ModelConfig, bundle: ArchBundle, *,
                          impl: str = "xla", jit: bool = True) -> Callable:
    """(params, acc, grains[G, ...]) -> acc after folding all G grains.

    Semantically identical to calling ``grain_step`` G times in stacking
    order, but issues one jitted dispatch (lax.scan over the leading grain
    axis) — the O(grains) Python-dispatch overhead of the per-grain loop
    disappears from the step hot path."""
    remat = bundle.mesh.remat

    def grain_accumulate(params: Pytree, acc: GrainAcc,
                         grains: Dict[str, jnp.ndarray]) -> GrainAcc:
        def body(carry: GrainAcc, grain: Dict[str, jnp.ndarray]):
            loss, grads = jax.value_and_grad(_loss_with_aux)(
                params, grain, cfg, impl, remat)
            nxt = GrainAcc(
                grads=jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                   carry.grads, grads),
                loss_sum=carry.loss_sum + loss, n=carry.n + 1)
            return nxt, None

        out, _ = jax.lax.scan(body, acc, grains)
        return out

    return jax.jit(grain_accumulate) if jit else grain_accumulate


_GRAIN_ACC_CACHE: Dict[Any, Callable] = {}


def grain_accumulate_cached(cfg: ModelConfig, bundle: ArchBundle, *,
                            impl: str = "xla") -> Callable:
    """Module-level cache of jitted grain-accumulate functions, keyed by the
    frozen (cfg, bundle, impl) triple: every driver with the same config
    shares one traced program."""
    key = (cfg, bundle, impl)
    fn = _GRAIN_ACC_CACHE.get(key)
    if fn is None:
        fn = _GRAIN_ACC_CACHE[key] = make_grain_accumulate(cfg, bundle,
                                                           impl=impl)
    return fn


def make_apply_step(cfg: ModelConfig, bundle: ArchBundle, *,
                    jit: bool = True) -> Callable:
    """Barrier step: mean the accumulated grads over the *global* grain
    count (HeMT slices contribute different k_i; the denominator is the
    total, so skewing never biases the gradient) and apply AdamW."""
    tc = bundle.train

    def apply_step(state: TrainState, acc: GrainAcc,
                   total_grains: jnp.ndarray,
                   ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        denom = jnp.maximum(total_grains.astype(jnp.float32), 1.0)
        grads = jax.tree.map(lambda g: g / denom, acc.grads)
        ef = state.ef
        if tc.compression != "none":
            sent, new_cs = compress_decompress(
                grads, CompressionState(ef), scheme=tc.compression)
            grads, ef = sent, new_cs.error
        lr = warmup_cosine(state.step, peak_lr=tc.lr,
                           warmup_steps=tc.warmup_steps,
                           total_steps=tc.total_steps)
        params, opt, gnorm = adamw_update(
            grads, state.opt, state.params, lr=lr, beta1=tc.beta1,
            beta2=tc.beta2, weight_decay=tc.weight_decay,
            grad_clip=tc.grad_clip)
        metrics = {"loss": acc.loss_sum / denom, "grad_norm": gnorm, "lr": lr}
        return TrainState(params, opt, state.step + 1, ef), metrics

    return jax.jit(apply_step) if jit else apply_step
