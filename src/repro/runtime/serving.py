"""Fleet-scale request serving through the resident calendar.

Open-loop arrival traces (:mod:`repro.core.arrivals`) are chopped into
dispatch windows; each window's requests become one resident *batch job*
(:class:`~repro.core.resident.ResidentJob`) whose lifecycle is expressed
as engine specs — **prefill** as a :class:`~repro.core.engine.PullSpec`
reading request inputs from a datanode over the flow-shared uplink,
**decode** as a :class:`~repro.core.engine.StaticSpec` macrotask split
across the job's heterogeneous replicas.  The whole trace then runs in
ONE :class:`~repro.core.resident.ResidentCalendar`: concurrent batches
space-share replicas under fair shares, spot preemptions and crashes
arrive mid-trace via :class:`~repro.core.faults.FaultTrace` (killed
decode attempts checkpoint and requeue per the retry budget), and
burstable-credit exhaustion rides two-segment
:class:`~repro.core.simulator.SimNode` profiles.

The batching policy is the subsystem's experiment knob (``mode``):

* ``hemt`` — every batch job carries an
  :class:`~repro.core.engine.AdaptivePlan` sharing ONE
  :class:`~repro.runtime.serve_loop.HeMTBatcher` estimator
  (``HeMTBatcher.plan()``), so each decode split is sized per
  AR(1)-estimated replica throughput and every finished batch feeds the
  estimator back at its barrier — the paper's §5.1 loop at fleet scale;
* ``even`` — the HomT baseline: equal decode shares regardless of
  capacity, so every batch waits on its slowest replica;
* ``oracle`` — clairvoyant: splits pinned (via ``proportions``) to the
  replicas' true mean speeds over the horizon.

Request -> replica **compatibility masks** (the sparse rate-matrix
pruning idea — Zhao & Mukherjee 2023, PAPERS.md) map request classes to
the replica names allowed to serve them; each window's requests group by
allowed set and ride the resident calendar's per-job ``allowed`` nodes.

Per-request latency is ``batch completion - request arrival`` (requests
of a stranded batch count as dropped, latency inf);
:class:`ServingReport` reduces the trace to p50/p99 latency, SLO
attainment and goodput.  The batching window is the granularity dial:
wider windows amortize dispatch overhead but add queueing delay — the
Tiny-Tasks trade-off (Bora et al. 2022, PAPERS.md) on one measured
curve.

:func:`run_round` is the closed-loop sibling for single dispatch rounds
(the ``launch/serve.py`` demo loop made honest): shares from
``HeMTBatcher.dispatch``, one ``run_job`` solve, observed per-replica
throughput fed back, and optional **speculation on straggling replicas**
via :class:`~repro.core.speculation.SpeculativeCopies` on the decode
stage.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.arrivals import dispatch_epochs
from repro.core.engine import JobSchedule, PullSpec, StaticSpec, run_job
from repro.core.faults import FaultTrace, RetryPolicy
from repro.core.resident import ResidentCalendar, ResidentJob, ResidentResult
from repro.core.simulator import SimNode
from repro.runtime.serve_loop import HeMTBatcher

_EPS = 1e-9

MODES = ("hemt", "even", "oracle")


@dataclass(frozen=True)
class RequestModel:
    """Per-request resource shape, sampled deterministically from
    ``seed``: decode work (optionally lognormal with coefficient of
    variation ``work_cv``), prefill input bytes + CPU work, and a
    request class in ``[0, classes)`` — the domain of compatibility
    masks.  ``prefill_work`` defaults to 0 so prefill is pure I/O and
    the AR(1) estimator only ever observes decode throughput."""
    decode_work: float = 1.0
    work_cv: float = 0.0
    prefill_mb: float = 0.0
    prefill_work: float = 0.0
    classes: int = 1
    seed: int = 0

    def __post_init__(self):
        if self.decode_work <= 0.0:
            raise ValueError("decode_work must be positive")
        if self.work_cv < 0.0:
            raise ValueError("work_cv must be >= 0")
        if self.prefill_mb < 0.0 or self.prefill_work < 0.0:
            raise ValueError("prefill shape must be >= 0")
        if self.classes < 1:
            raise ValueError("classes must be >= 1")

    def sample(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(decode works, request classes) for ``n`` requests."""
        rng = np.random.default_rng(self.seed)
        if self.work_cv > 0.0:
            sigma = math.sqrt(math.log1p(self.work_cv ** 2))
            mu = math.log(self.decode_work) - 0.5 * sigma * sigma
            works = rng.lognormal(mu, sigma, n)
        else:
            works = np.full(n, float(self.decode_work))
        if self.classes > 1:
            klass = rng.integers(0, self.classes, n)
        else:
            klass = np.zeros(n, np.int64)
        return works, klass


@dataclass
class ServingReport:
    """Trace-level outcome: per-request latencies (inf = dropped with a
    stranded batch), the SLO, and the resident result behind them."""
    latencies: np.ndarray
    arrivals: np.ndarray
    slo: Optional[float]
    horizon: float
    result: ResidentResult

    @property
    def n_requests(self) -> int:
        return int(self.latencies.size)

    @property
    def n_completed(self) -> int:
        return int(np.isfinite(self.latencies).sum())

    def percentile(self, q: float) -> float:
        if self.latencies.size == 0:
            return 0.0
        return float(np.percentile(self.latencies, q))

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    @property
    def attainment(self) -> float:
        """Fraction of requests completing within the SLO (fraction
        merely *completing* when no SLO is set); 1.0 on an empty
        trace."""
        if self.latencies.size == 0:
            return 1.0
        if self.slo is None:
            return self.n_completed / self.n_requests
        ok = self.latencies <= self.slo + _EPS
        return float(ok.sum()) / self.n_requests

    @property
    def goodput(self) -> float:
        """SLO-attained requests per second, over
        ``max(horizon, last completion)``."""
        if self.latencies.size == 0:
            return 0.0
        if self.slo is None:
            good = self.n_completed
        else:
            good = int((self.latencies <= self.slo + _EPS).sum())
        elapsed = max(self.horizon, self.result.makespan)
        return good / elapsed if elapsed > 0.0 else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "n_requests": self.n_requests,
            "n_completed": self.n_completed,
            "p50_s": self.p50,
            "p99_s": self.p99,
            "attainment": self.attainment,
            "goodput_rps": self.goodput,
        }


@dataclass
class ServingScenario:
    """The open-loop fleet scenario: configure once, :meth:`run` a
    trace.  See the module docstring for the semantics; ``build_jobs``
    is exposed separately (and is deterministic — every call returns
    structurally identical jobs with fresh adaptive state) so the
    differential suite can pin the resident path against the naive
    per-arrival rescan oracle."""
    replicas: Sequence[SimNode]
    window: float
    model: RequestModel = field(default_factory=RequestModel)
    mode: str = "hemt"
    slo: Optional[float] = None
    uplink_bw: Optional[float] = None
    datanode: int = 0
    faults: Optional[FaultTrace] = None
    mask: Optional[Mapping[int, Sequence[str]]] = None
    alpha: float = 0.3
    warmup: int = 1
    probe_work: float = 1.0
    max_prefill_tasks: int = 0
    retry: RetryPolicy = field(default_factory=RetryPolicy)

    def __post_init__(self):
        if not self.replicas:
            raise ValueError("at least one replica is required")
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}: {self.mode!r}")
        if self.window <= 0.0:
            raise ValueError("window must be positive")
        if self.warmup < 0:
            raise ValueError("warmup must be >= 0")
        if self.probe_work <= 0.0:
            raise ValueError("probe_work must be positive")
        names = {nd.name for nd in self.replicas}
        if self.mask is not None:
            for c, allowed in self.mask.items():
                extra = set(allowed) - names
                if extra:
                    raise ValueError(
                        f"mask for class {c} names unknown replicas "
                        f"{sorted(extra)}")
                if not set(allowed):
                    raise ValueError(f"mask for class {c} is empty")

    # ------------------------------------------------------------------
    def _true_speeds(self, horizon: float) -> Dict[str, float]:
        return {nd.name: nd.work_between(0.0, horizon) / horizon
                for nd in self.replicas}

    def _probed_batcher(self) -> HeMTBatcher:
        """A fresh HeMT batcher, warmed by ``warmup`` probe tasks per
        replica: each probe is a genuine t=0 measurement (one
        ``probe_work`` task through the replica's own profile +
        overhead), the serving analogue of the fudge-factor probe —
        estimates start measured, not advertised."""
        batcher = HeMTBatcher([nd.name for nd in self.replicas],
                              alpha=self.alpha, mode="hemt")
        for _ in range(self.warmup):
            for nd in self.replicas:
                t = nd.finish_time(self.probe_work, nd.task_overhead)
                batcher.observe(nd.name, self.probe_work, t)
        return batcher

    def _mask_groups(self, klass: np.ndarray,
                     ) -> List[Tuple[np.ndarray, Optional[frozenset]]]:
        """Group request positions by their allowed-replica set (one
        all-replicas group when no mask is given), deterministic
        order."""
        if self.mask is None:
            return [(np.arange(klass.size), None)]
        all_names = tuple(nd.name for nd in self.replicas)
        key_of = {}
        for c in np.unique(klass):
            allowed = self.mask.get(int(c))
            key_of[int(c)] = (tuple(sorted(allowed))
                              if allowed is not None else all_names)
        groups = []
        for key in sorted(set(key_of.values())):
            classes = [c for c, k in key_of.items() if k == key]
            sub = np.flatnonzero(np.isin(klass, classes))
            if sub.size == 0:
                continue
            allowed = None if key == all_names else frozenset(key)
            groups.append((sub, allowed))
        return groups

    def build_jobs(self, times: np.ndarray, works: np.ndarray,
                   klass: np.ndarray, horizon: float,
                   ) -> Tuple[List[ResidentJob],
                              List[Tuple[str, np.ndarray, float]]]:
        """Batch jobs + per-job request groups ``(job name, request
        indices, dispatch time)`` for one sampled trace."""
        times = np.asarray(times, np.float64)
        batcher = self._probed_batcher() if self.mode == "hemt" else None
        oracle = self._true_speeds(horizon) if self.mode == "oracle" \
            else None
        epochs = dispatch_epochs(times, self.window)
        jobs: List[ResidentJob] = []
        groups: List[Tuple[str, np.ndarray, float]] = []
        for e in np.unique(epochs):
            sel = np.flatnonzero(epochs == e)
            parts = self._mask_groups(klass[sel])
            for gi, (sub, allowed) in enumerate(parts):
                idx = sel[sub]
                total = float(works[idx].sum())
                b = idx.size
                stages: List[object] = []
                m = self.model
                if m.prefill_mb > 0.0 or m.prefill_work > 0.0:
                    k = b if self.max_prefill_tasks <= 0 \
                        else min(b, self.max_prefill_tasks)
                    io = m.prefill_mb * b / k
                    # uplink_bw=None means an unmodeled (infinite)
                    # uplink: prefill degenerates to its CPU part
                    with_io = self.uplink_bw is not None and io > _EPS
                    stages.append(PullSpec(
                        works=(m.prefill_work * b / k,) * k,
                        io_mb=io if with_io else 0.0,
                        datanode=self.datanode if with_io else -1))
                stages.append(StaticSpec(works=(total,)))
                name = f"b{int(e):07d}" + (f".{gi}" if len(parts) > 1
                                           else "")
                dispatch = (int(e) + 1) * self.window
                jobs.append(ResidentJob(
                    name, tuple(stages), arrival=dispatch,
                    deadline=(float(times[idx].min()) + self.slo
                              if self.slo is not None else None),
                    retry=self.retry,
                    adaptive=batcher.plan() if batcher is not None
                    else None,
                    proportions=dict(oracle) if oracle is not None
                    else None,
                    allowed=allowed))
                groups.append((name, idx, dispatch))
        return jobs, groups

    def run(self, trace) -> ServingReport:
        """Run one arrival trace (an :data:`~repro.core.arrivals.
        ArrivalTrace` spec, or a raw array of arrival times) through the
        resident calendar."""
        if hasattr(trace, "times"):
            times = trace.times()
            horizon = trace.horizon
        else:
            times = np.asarray(trace, np.float64)
            horizon = float(times.max()) + self.window if times.size \
                else self.window
        works, klass = self.model.sample(times.size)
        jobs, groups = self.build_jobs(times, works, klass, horizon)
        cal = ResidentCalendar(self.replicas, self.uplink_bw,
                               faults=self.faults)
        result = cal.run(jobs)
        latencies = np.full(times.size, np.inf)
        for name, idx, _ in groups:
            out = result.outcomes[name]
            if out.status == "done":
                latencies[idx] = out.completion - times[idx]
        return ServingReport(latencies, times, self.slo, horizon, result)


def compare_modes(scenario: ServingScenario, trace,
                  modes: Sequence[str] = MODES) -> Dict[str, "ServingReport"]:
    """Run one trace under several batching modes, everything else held
    fixed — the mode-comparison sweep the benchmarks and capacity studies
    run.  Each mode gets a ``dataclasses.replace`` copy of ``scenario``
    (the input is never mutated), and the reports ride the array path
    end-to-end: latency columns come back as numpy arrays and the
    closed forms underneath stay columnar — no ``TaskRecord`` is ever
    materialized for the comparison."""
    unknown = [m for m in modes if m not in MODES]
    if unknown:
        raise ValueError(f"unknown modes {unknown}; choose from {MODES}")
    return {m: replace(scenario, mode=m).run(trace) for m in modes}


# --------------------------------------------------------------------------
# closed-loop round driver (speculation on straggling replicas)
# --------------------------------------------------------------------------

def run_round(batcher: HeMTBatcher, nodes: Sequence[SimNode],
              n_requests: int, *, decode_work: float = 1.0,
              prefill_mb: float = 0.0, prefill_work: float = 0.0,
              uplink_bw: Optional[float] = None, datanode: int = 0,
              speculation=None, start_time: float = 0.0,
              ) -> Tuple[Dict[str, int], JobSchedule]:
    """One dispatch round as a whole-job solve, with the observe loop
    closed: ``batcher.dispatch`` sizes per-replica shares, the round
    runs as ``run_job([prefill?, decode])`` on the replicas' real
    profiles, and each replica's observed (executed work, busy time)
    feeds back into the batcher — so successive rounds track drift
    (burstable-credit exhaustion shows up as a falling estimate).

    ``speculation`` (a :class:`~repro.core.speculation.
    SpeculativeCopies`) rides the decode stage: straggling replicas get
    duplicate decode attempts on idle finished replicas,
    first-finisher-wins — use ``batcher.straggling()`` to decide when
    hedging is worth arming.  ``start_time`` advances the fleet clock
    across rounds so multi-segment profiles deplete for real."""
    if n_requests < 0:
        raise ValueError("n_requests must be >= 0")
    by_name = {nd.name: nd for nd in nodes}
    if set(by_name) != set(batcher.replicas):
        raise ValueError("node names must match the batcher's replicas")
    shares = batcher.dispatch(n_requests)
    stages: List[object] = []
    if prefill_mb > 0.0 or prefill_work > 0.0:
        with_io = uplink_bw is not None and prefill_mb > _EPS
        stages.append(PullSpec(
            works=(prefill_work,) * max(n_requests, 1),
            io_mb=prefill_mb if with_io else 0.0,
            datanode=datanode if with_io else -1))
    stages.append(StaticSpec(
        works=tuple(shares[nd.name] * decode_work for nd in nodes),
        mitigation=speculation))
    sched = run_job(list(nodes), stages, uplink_bw, start_time=start_time)
    summ = sched.stages[-1]
    for nd in nodes:
        batcher.observe(nd.name, summ.work.get(nd.name, 0.0),
                        summ.node_finish[nd.name] - summ.start)
    return shares, sched
