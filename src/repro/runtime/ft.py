"""Fault tolerance: heartbeats, straggler detection, failure response.

The paper's §5 signal — execution-time variation at program barriers — is
exactly what the trainer's StepReports carry. `FleetMonitor` consumes them:

  * missed heartbeats  -> slice declared dead -> elastic replan
    (survivor estimates kept, paper's cold-start rule for replacements)
  * grain-rate z-score below threshold -> straggler -> *no restart*:
    HeMT absorbs the capacity loss by re-skewing the next plan (the paper's
    point); in HomT mode the work-stealing queue absorbs it per Claim 1.
  * optional speculation for pull-mode stages (paper §8's [45, 6, 5]),
    driven by the same ``SpeculativeCopies`` trigger rule the simulated
    engine applies (``repro.core.speculation``) — see
    ``FleetMonitor.speculation_candidates``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.speculation import SpeculativeCopies
from repro.core.straggler import StragglerReport, detect_stragglers


@dataclass
class Heartbeat:
    slice_name: str
    at: float                    # fleet-clock seconds
    grains_done: int
    elapsed: float               # busy seconds this step


@dataclass
class FleetEvent:
    kind: str                    # "dead" | "straggler" | "recovered"
    #                              | "exhausted" (whole-fleet terminal)
    slice_name: str
    at: float
    detail: str = ""


class FleetMonitor:
    """Tracks liveness + throughput of every slice from step heartbeats.

    ``speculation`` (a :class:`~repro.core.speculation.SpeculativeCopies`
    policy) configures the advisory re-launch rule used by
    :meth:`speculation_candidates`; the same policy object can be handed to
    the simulated engine (``run_stage_events(mitigation=...)``) so what the
    monitor would re-launch is exactly what the simulation re-launches.
    """

    def __init__(self, slices: Sequence[str], *, timeout: float = 3.0,
                 z_threshold: float = -1.5,
                 speculation: Optional[SpeculativeCopies] = None):
        self.timeout = timeout
        self.z_threshold = z_threshold
        self.speculation = speculation or SpeculativeCopies(
            quantile=0.5, factor=2.0, min_completed=1)
        self.last_seen: Dict[str, float] = {s: 0.0 for s in slices}
        self.rates: Dict[str, float] = {}
        self.events: List[FleetEvent] = []
        self._dead: set = set()
        self._straggling: set = set()   # open straggler episodes, by name
        self.exhausted = False          # set by mark_exhausted()

    # ------------------------------------------------------------------
    def heartbeat(self, hb: Heartbeat) -> None:
        self.last_seen[hb.slice_name] = hb.at
        if hb.elapsed > 0:
            self.rates[hb.slice_name] = hb.grains_done / hb.elapsed
        if hb.slice_name in self._dead:
            self._dead.discard(hb.slice_name)
            self.events.append(FleetEvent("recovered", hb.slice_name, hb.at))

    def check(self, now: float) -> Tuple[List[str], List[StragglerReport]]:
        """Returns (newly dead slices, current stragglers).

        Straggler events carry the stable slice *name* (the report index is
        alive-local and shifts as nodes die) and are deduplicated per
        episode: one "straggler" event when a slice starts lagging, one
        "recovered" event when it stops (or nothing further if it dies —
        the heartbeat path owns dead/recovered transitions)."""
        newly_dead = []
        for name, seen in self.last_seen.items():
            if name not in self._dead and now - seen > self.timeout:
                self._dead.add(name)
                newly_dead.append(name)
                self.events.append(FleetEvent(
                    "dead", name, now,
                    f"no heartbeat for {now - seen:.1f}s (timeout {self.timeout}s)"))
        alive = [n for n in self.last_seen if n not in self._dead]
        rates = [self.rates.get(n, 0.0) for n in alive]
        stragglers = detect_stragglers(rates, self.z_threshold)
        reports = []
        current = set()
        for s in stragglers:
            name = alive[s.index]
            current.add(name)
            reports.append(StragglerReport(s.index, s.rate, s.zscore, name))
            if name not in self._straggling:
                self._straggling.add(name)
                self.events.append(FleetEvent(
                    "straggler", name, now,
                    f"rate {s.rate:.2f} grains/s, z={s.zscore:.2f}"))
        for name in sorted(self._straggling - current):
            self._straggling.discard(name)
            if name not in self._dead:
                self.events.append(FleetEvent(
                    "recovered", name, now, "straggler episode ended"))
        return newly_dead, reports

    def speculation_candidates(self, now: float,
                               done_durations: Sequence[float],
                               running_starts: Dict[str, float],
                               running_io_mb: Optional[Dict[str, float]]
                               = None) -> List[str]:
        """Tasks worth re-launching on an idle slice: running at/over the
        policy threshold given completed durations (engine-shared
        at-threshold trigger; the paper's §8 opportunistic speculation).
        ``running_io_mb`` (input bytes per running task) feeds the
        policy's re-fetch cost term — a copy that must re-read its input
        is only advised once the straggler is late enough to cover it."""
        pol = self.speculation
        io = running_io_mb or {}
        return [key for key, st in running_starts.items()
                if pol.should_speculate(done_durations, now - st,
                                        io.get(key, 0.0))]

    def mark_exhausted(self, now: float,
                       estimates: Optional[Dict[str, float]] = None) -> None:
        """Record the whole-fleet terminal event: every slice is gone and
        recovery gave up (:class:`~repro.runtime.elastic.
        FleetExhaustedError`).  ``estimates`` — the error's last-known
        speeds — are logged in the event detail so the halt is
        checkpointable from the event stream alone."""
        self.exhausted = True
        detail = ""
        if estimates:
            detail = "last estimates: " + ", ".join(
                f"{n}={v:.3g}" for n, v in sorted(estimates.items()))
        self.events.append(FleetEvent("exhausted", "*", now, detail))

    def alive(self) -> List[str]:
        return [n for n in self.last_seen if n not in self._dead]

    def remove(self, name: str) -> None:
        self.last_seen.pop(name, None)
        self.rates.pop(name, None)
        self._dead.discard(name)
        self._straggling.discard(name)

    def add(self, name: str, now: float) -> None:
        self.last_seen[name] = now
