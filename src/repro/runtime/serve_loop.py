"""Serving: prefill/decode step factories + HeMT continuous batching.

``make_serve_step`` / ``make_prefill_step`` are what the decode/prefill
dry-run shapes lower. ``HeMTBatcher`` is the paper's §5.1 estimator applied
to replicas: request batches are sized proportional to AR(1)-estimated
per-replica decode throughput, so heterogeneous replicas (contended hosts,
burstable capacity) reach their batch deadlines together — the serving
analogue of macrotask skewing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence


import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.engine import AdaptivePlan
from repro.core.estimators import ARSpeedEstimator
from repro.core.partitioner import proportional_split, even_split
from repro.models.model import decode_step, prefill

Pytree = Any


def make_serve_step(cfg: ModelConfig, *, sample: str = "greedy",
                    ) -> Callable:
    """serve_step(params, state, tokens (B,), [enc_out]) ->
    (next_tokens (B,), logits (B,V), new state)."""

    def serve_step(params: Pytree, state: Pytree, tokens: jnp.ndarray,
                   enc_out: Optional[jnp.ndarray] = None):
        logits, new_state = decode_step(params, state, tokens, cfg,
                                        enc_out=enc_out)
        if sample == "greedy":
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt, logits, new_state

    return serve_step


def make_prefill_step(cfg: ModelConfig, max_len: int, *, impl: str = "xla",
                      ) -> Callable:
    """prefill_step(params, tokens (B,S), [enc_feats]) ->
    (first sampled token (B,), decode state)."""

    def prefill_step(params: Pytree, tokens: jnp.ndarray,
                     enc_feats: Optional[jnp.ndarray] = None):
        logits, state = prefill(params, tokens, cfg, max_len,
                                enc_feats=enc_feats, impl=impl)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), state

    return prefill_step


# --------------------------------------------------------------------------
# HeMT continuous batching across replicas
# --------------------------------------------------------------------------

@dataclass
class ReplicaState:
    name: str
    active: int = 0                  # requests currently decoding
    tokens_done: int = 0


@dataclass
class DispatchRecord:
    round: int
    shares: Dict[str, int]
    predicted_finish: Dict[str, float]


class HeMTBatcher:
    """Sizes per-replica request batches ∝ estimated decode throughput.

    `observe(replica, tokens, seconds)` feeds the same AR(1) estimator the
    trainer uses (§5.1 — per job class, here per model). `dispatch(n)`
    splits n requests; homogeneous mode (`mode='even'`) is the HomT-like
    baseline."""

    def __init__(self, replicas: Sequence[str], *, alpha: float = 0.3,
                 mode: str = "hemt", min_share: int = 0):
        self.replicas = list(replicas)
        self.estimator = ARSpeedEstimator(alpha=alpha)
        self.mode = mode
        self.min_share = min_share
        self.log: List[DispatchRecord] = []
        self._round = 0

    def observe(self, replica: str, tokens: int, seconds: float) -> None:
        if tokens > 0 and seconds > 0:
            self.estimator.observe(replica, tokens, seconds)

    def dispatch(self, n_requests: int) -> Dict[str, int]:
        n = len(self.replicas)
        if self.mode == "even" or not self.estimator.known():
            shares = even_split(n_requests, n)
        else:
            speeds = self.estimator.speeds(self.replicas)
            shares = proportional_split(n_requests, speeds,
                                        min_share=self.min_share)
        speeds = self.estimator.speeds(self.replicas)
        pred = {r: (s / v if v > 0 else float("inf"))
                for r, s, v in zip(self.replicas, shares, speeds)}
        out = dict(zip(self.replicas, shares))
        self.log.append(DispatchRecord(self._round, out, pred))
        self._round += 1
        return out

    def resize(self, replicas: Sequence[str]) -> None:
        gone = set(self.replicas) - set(replicas)
        for g in gone:
            self.estimator.forget(g)
        self.replicas = list(replicas)

    def plan(self, **kwargs) -> AdaptivePlan:
        """An :class:`~repro.core.engine.AdaptivePlan` sharing this
        batcher's AR(1) state.  The fleet serving scenario
        (:mod:`repro.runtime.serving`) attaches one per batch job, so
        every decode split is sized from the same estimates round-based
        ``dispatch`` uses and every finished batch feeds the estimator
        back through the resident calendar's barrier observations."""
        return AdaptivePlan(self.estimator, **kwargs)

    def straggling(self, factor: float = 2.0) -> List[str]:
        """Replicas whose estimated speed has fallen ``factor``x below
        the median estimate — the serving-side speculation trigger.
        Round drivers (:func:`repro.runtime.serving.run_round`) hedge
        these with duplicate decode attempts via
        :class:`~repro.core.speculation.SpeculativeCopies`."""
        if factor < 1.0:
            raise ValueError("straggler factor must be >= 1.0")
        if not self.estimator.known():
            return []
        speeds = self.estimator.speeds(self.replicas)
        ordered = sorted(speeds)
        mid = len(ordered) // 2
        median = ordered[mid] if len(ordered) % 2 else \
            0.5 * (ordered[mid - 1] + ordered[mid])
        return [r for r, v in zip(self.replicas, speeds)
                if v * factor < median]

    def predicted_sync_delay(self, shares: Dict[str, int]) -> float:
        speeds = dict(zip(self.replicas, self.estimator.speeds(self.replicas)))
        times = [shares[r] / speeds[r] for r in self.replicas
                 if shares.get(r, 0) > 0]
        return (max(times) - min(times)) if times else 0.0
