"""HeMT-DP training driver — the paper's scheduler running a *real* JAX
training loop over a fleet of (simulated-speed) slices.

On hardware, each slice is an SPMD island running `grain_step` k_i times
between gradient barriers, and elapsed wall-times feed the AR(1) estimator.
On this CPU container the *math* is real (every grain's gradient is
computed and accumulated — the resulting model update is bit-identical to
synchronous training on the same global batch), while *time* comes from a
calibrated virtual clock per slice (piecewise speed profiles, per-grain
overhead — `repro.core.simulator.SimNode`), so the paper's completion-time
comparisons (HeMT vs HomT vs static) reproduce deterministically.

Modes (paper sections):
  hemt        — OA-HeMT: per-slice grain counts ∝ AR(1) speed estimates (§5)
  oa-hemt     — like hemt, but `run_window` schedules W steps' barriers in
                ONE adaptive `engine.run_job` call (per-barrier re-planning
                from the shared estimator, whole-grain quantum) — O(n)
                schedule work per step instead of a full engine entry
  homt        — pull-based microtasking over the grain queue (§3, Claim 1)
  static-even — Spark-default: equal macrotasks, no stealing (§4 baseline)

Hot path: the per-step schedule comes from the fast-path simulation engine
(closed form for constant-speed slices, event calendar otherwise), and the
step's gradients are folded with a single jitted lax.scan grain-accumulate
dispatch over the stacked grains (see runtime.train_loop) — the scheduler
and the math both cost O(1) Python dispatches per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ArchBundle, ModelConfig
from repro.core.engine import AdaptivePlan, StaticSpec, run_job
from repro.core.planner import GrainPlanner
from repro.core.simulator import SimNode, SimTask, run_pull_stage, run_static_stage
from repro.data.grains import GrainSource, plan_grain_ranges
from repro.data.pipeline import SyntheticCorpus
from repro.runtime.train_loop import (
    TrainState, grain_acc_init, grain_accumulate_cached, make_apply_step,
)


@dataclass(frozen=True)
class SliceSpec:
    """One data-parallel slice: name + virtual speed profile.

    profile: ((t_start_seconds, relative_speed), ...) — the paper's node
    model (static shares, interference injections, burstable two-segment);
    list inputs are coerced to tuples so specs stay hashable.
    grain_overhead: per-grain dispatch cost in seconds (the microtasking
    overhead term the paper analyzes)."""
    name: str
    profile: Tuple[Tuple[float, float], ...] = ((0.0, 1.0),)
    grain_overhead: float = 0.05

    def __post_init__(self):
        object.__setattr__(
            self, "profile",
            tuple((float(t), float(s)) for t, s in self.profile))


@dataclass
class StepReport:
    step: int
    mode: str
    grain_counts: Dict[str, int]
    slice_elapsed: Dict[str, float]
    makespan: float
    idle_time: float              # barrier sync delay (paper's metric)
    loss: float
    steals: int = 0


class HeMTTrainer:
    """Drives real grain steps under the paper's three scheduling policies."""

    def __init__(self, cfg: ModelConfig, bundle: ArchBundle,
                 slices: Sequence[SliceSpec], *, grain_batch: int,
                 global_batch: int, seq_len: int, mode: str = "hemt",
                 alpha: float = 0.3, grain_cost: float = 1.0, seed: int = 0):
        assert global_batch % grain_batch == 0
        assert mode in ("hemt", "oa-hemt", "homt", "static-even")
        self.cfg, self.bundle = cfg, bundle
        self.slices = list(slices)
        self.mode = mode
        self.n_grains = global_batch // grain_batch
        self.grain_batch = grain_batch
        self.global_batch = global_batch
        self.grain_cost = grain_cost    # seconds per grain at speed 1.0
        self.corpus = SyntheticCorpus(cfg.vocab_size, seq_len, seed=seed)
        self.source = GrainSource(self.corpus, grain_batch)
        planner_mode = "hemt" if mode in ("hemt", "oa-hemt") else "homt"
        self.planner = GrainPlanner([s.name for s in self.slices],
                                    alpha=alpha, mode=planner_mode)
        self.grain_accumulate = grain_accumulate_cached(cfg, bundle)
        self.apply_step = make_apply_step(cfg, bundle)
        self.reports: List[StepReport] = []
        self.grain_dispatches = 0   # jitted accumulate calls (1 per step)
        self._clock = 0.0           # virtual fleet clock (seconds)
        # set by run_window when the whole fleet is lost and recovery gives
        # up: the FleetExhaustedError's last-known speed estimates
        self.exhausted: Optional[Dict[str, float]] = None

    # ------------------------------------------------------------------
    def _sim_nodes(self) -> List[SimNode]:
        """Slice speed profiles shifted to the current virtual clock."""
        nodes = []
        for s in self.slices:
            # segment active at the current clock, plus future breakpoints
            last_active = [(0.0, [sp for t0, sp in s.profile
                                  if t0 <= self._clock][-1])]
            future = [(t0 - self._clock, sp) for t0, sp in s.profile
                      if t0 > self._clock]
            nodes.append(SimNode(s.name, last_active + future,
                                 s.grain_overhead))
        return nodes

    def _schedule(self, step: int):
        """Returns (grain_counts per slice, elapsed per slice, makespan,
        idle, steals) from the virtual-clock schedule for this step."""
        nodes = self._sim_nodes()
        if self.mode == "homt":
            tasks = [SimTask(self.grain_cost, task_id=i)
                     for i in range(self.n_grains)]
            res = run_pull_stage(nodes, tasks)
            counts = {s.name: 0 for s in self.slices}
            for r in res.records:
                counts[r.node] += 1
            steals = max(0, len(res.records) - len(self.slices))
        else:
            if self.mode == "static-even":
                from repro.core.partitioner import even_split
                grains = even_split(self.n_grains, len(self.slices))
                counts = {s.name: g for s, g in zip(self.slices, grains)}
            else:
                plan = self.planner.plan(self.n_grains)
                counts = dict(zip(plan.slice_names, plan.grains))
            assignments = [[SimTask(self.grain_cost, task_id=j)
                            for j in range(counts[s.name])]
                           for s in self.slices]
            res = run_static_stage(nodes, assignments)
            steals = 0
        elapsed = {name: t for name, t in res.node_finish.items()}
        return counts, elapsed, res.completion, res.idle_time, steals

    # ------------------------------------------------------------------
    def _execute_math(self, state: TrainState, counts: Dict[str, int],
                      ) -> Tuple[TrainState, Dict]:
        """Fold one step's grains and apply the update.

        Real math: every grain's gradient accumulates (order-independent).
        All n_grains grains of the step land in the corpus's preallocated
        [G, grain_batch, seq] block (no per-grain host stacking) and are
        folded with ONE jitted lax.scan dispatch — O(1) dispatches per
        step instead of O(grains).  Reusing the block buffer is safe:
        jnp.asarray snapshots it for the device, and the step blocks on
        its own loss before the next step refills it.
        """
        assignment = plan_grain_ranges(
            int(state.step), self.global_batch, self.grain_batch,
            list(counts), list(counts.values()))
        block = self.source.load_stacked(
            [g for grains in assignment.per_slice.values() for g in grains])
        stacked = {k: jnp.asarray(v) for k, v in block.items()}
        acc = grain_acc_init(state.params)
        acc = self.grain_accumulate(state.params, acc, stacked)
        self.grain_dispatches += 1
        return self.apply_step(state, acc, jnp.asarray(self.n_grains))

    def run_step(self, state: TrainState) -> Tuple[TrainState, StepReport]:
        step = int(state.step)
        counts, elapsed, makespan, idle, steals = self._schedule(step)
        state, metrics = self._execute_math(state, counts)

        # feed the estimator with the *virtual* observations (work, time)
        self.planner.observe_step(
            {name: {"grains": counts[name], "elapsed": max(elapsed[name], 1e-9)}
             for name in counts if counts[name] > 0})

        self._clock += makespan
        rep = StepReport(step, self.mode, counts, elapsed, makespan, idle,
                         float(metrics["loss"]), steals)
        self.reports.append(rep)
        return state, rep

    def run_window(self, state: TrainState, n_steps: int, *,
                   faults=None, monitor=None) -> TrainState:
        """OA-HeMT at window scale (mode ``oa-hemt``): schedule the next
        ``n_steps`` gradient barriers in ONE adaptive ``run_job`` call —
        each barrier re-plans the next step's grain split from the shared
        AR(1) estimator, with a whole-grain quantum — then execute the
        real math per step with the logged counts.  Other modes fall back
        to per-step :meth:`run_step` scheduling.

        The estimator is fed by the adaptive plan itself (executed grains
        / busy time per slice at every barrier — the plan's whole-grain
        quantum normalizes work to grains/sec, the same unit
        ``planner.observe_step`` records), not via ``observe_step`` — one
        observation per (slice, barrier) in one unit either way, so
        per-step and windowed scheduling can be mixed freely.  One
        deliberate timing difference: a window stage is one *macrotask*
        per slice (a single ``grain_overhead`` per barrier — the HeMT
        dispatch amortization), whereas ``run_step``'s static stage pays
        the overhead per grain; observed throughputs genuinely differ by
        that amortization.

        ``faults`` (a :class:`~repro.core.faults.FaultTrace` on the fleet
        clock) injects crashes / spot preemptions into the window's
        virtual schedule — the driver shifts it to the window's local
        clock and hands the whole window to ONE
        :class:`~repro.core.resident.ResidentCalendar` pass: recoveries
        *splice into* the adaptive schedule (survivors keep their AR(1)
        state, checkpointed prefixes count, residuals requeue under the
        trace's retry policy) instead of re-entering ``run_job`` from
        scratch per event.  The trace is a *timing* model: every grain's
        gradient still accumulates (the math stays
        synchronous-equivalent), so use traces whose retry budget covers
        the window.  ``monitor`` (a :class:`~repro.runtime.ft.
        FleetMonitor`) observes the detection loop: every barrier feeds
        it per-slice heartbeats (slices the barrier planned work for)
        and runs ``monitor.check``; after the window every dead
        declaration is applied at once — :func:`repro.runtime.elastic.
        replan` keeps the survivors' AR(1) estimates and drops the dead
        slices from the fleet.  If *no* slice survives, the
        :class:`~repro.runtime.elastic.FleetExhaustedError` is absorbed
        gracefully: the monitor logs the terminal event, the last-known
        speed estimates land in ``self.exhausted``, and the trained
        state so far is returned instead of raising.  Both keywords are
        honored in ``oa-hemt`` mode only (the per-step fallback would
        silently ignore them, so passing them there raises).
        """
        if self.mode != "oa-hemt":
            if faults is not None or monitor is not None:
                raise ValueError(
                    "faults/monitor wiring needs windowed scheduling "
                    "(mode='oa-hemt'); other modes schedule per step")
            for _ in range(n_steps):
                state, _ = self.run_step(state)
            return state
        if n_steps <= 0:
            return state
        from repro.core.faults import RetryPolicy
        from repro.core.resident import ResidentCalendar, ResidentJob
        from repro.runtime import elastic
        from repro.runtime.ft import Heartbeat
        nodes = self._sim_nodes()
        plan0 = self.planner.plan(self.n_grains)
        spec = StaticSpec(works=tuple(g * self.grain_cost
                                      for g in plan0.grains))
        adaptive = AdaptivePlan(estimator=self.planner.estimator,
                                quantum=self.grain_cost,
                                min_units=self.planner.min_grains)
        trace = faults.shift(-self._clock) if faults is not None else None
        job = ResidentJob(
            "window", stages=(spec,) * n_steps,
            retry=trace.retry if trace is not None else RetryPolicy(),
            adaptive=adaptive,
            # the windowed driver's historical contract: abandoned work is
            # *eaten* (the step's gradients all accumulate anyway), never
            # folded into the next barrier's quantum budget
            fold_lost=False)
        result = ResidentCalendar(nodes, faults=trace).run([job])
        outcome = result.outcomes["window"]
        clock0 = self._clock
        dead_all: List[str] = []
        for s, summ in enumerate(outcome.stages):
            counts = {nm: int(round(w / self.grain_cost))
                      for nm, w in outcome.planned[s].items()}
            elapsed = {nm: summ.node_finish[nm] - summ.start
                       for nm in counts}
            step = int(state.step)
            state, metrics = self._execute_math(state, counts)
            rep = StepReport(step, self.mode, counts, elapsed, summ.span,
                             summ.idle_time, float(metrics["loss"]), 0)
            self.reports.append(rep)
            self._clock = clock0 + summ.completion
            if monitor is not None:
                for nm in counts:
                    if counts[nm] > 0 and elapsed[nm] > 0.0:
                        monitor.heartbeat(Heartbeat(
                            nm, self._clock, counts[nm], elapsed[nm]))
                newly_dead, _ = monitor.check(self._clock)
                dead_all.extend(newly_dead)
        gone = set(dead_all)
        if outcome.status == "stranded":
            # the calendar drained with the window unfinished: whatever the
            # monitor saw, only the calendar's usable nodes survive
            gone |= {sl.name for sl in self.slices
                     if sl.name not in set(result.alive)}
        if gone:
            # apply the whole window's roster change at once: survivors
            # keep their AR(1) estimates (paper §5.1)
            self.slices = [sl for sl in self.slices if sl.name not in gone]
            try:
                elastic.replan(self.planner,
                               [sl.name for sl in self.slices])
            except elastic.FleetExhaustedError as e:
                # graceful degradation instead of a crash: log the
                # terminal event, keep the last-known estimates, and hand
                # back the state trained so far
                if monitor is not None:
                    monitor.mark_exhausted(self._clock, e.estimates)
                self.exhausted = e.estimates
        return state

    def run(self, state: TrainState, n_steps: int,
            log: Optional[Callable[[StepReport], None]] = None,
            ) -> TrainState:
        for _ in range(n_steps):
            state, rep = self.run_step(state)
            if log:
                log(rep)
        return state

    # ------------------------------------------------------------------
    def total_time(self) -> float:
        return sum(r.makespan for r in self.reports)

    def mean_idle(self) -> float:
        return float(np.mean([r.idle_time for r in self.reports]))

    def resize(self, slices: Sequence[SliceSpec]) -> None:
        """Elastic event: slice set changed (loss/scale-up). Survivor speed
        estimates are kept, newcomers cold-start at the mean (paper §5.1)."""
        self.slices = list(slices)
        self.planner.resize([s.name for s in self.slices])
