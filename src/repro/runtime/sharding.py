"""Logical-axis -> mesh sharding with automatic divisibility fallback.

Model code annotates every parameter/cache leaf with *logical* axis names
(`models.model.params_axes`, `transformer.cache_axes`). This module maps
them onto the production mesh per the ArchBundle's MeshConfig:

  heads / kv_heads / mlp / vocab / expert / ssm_inner / ssm_conv -> "model"  (TP/EP)
  embed         -> ("pod","data") under FSDP (ZeRO-3), else replicated
  batch         -> ("pod","data")   (pure DP across pods — DCN only carries
                                     the gradient all-reduce, per DESIGN §7)
  cache_seq     -> "model" only when kv heads don't divide the model axis
  seq (activations) -> "data" for long-context decode (sequence parallelism)
  layers        -> never sharded (scan axis)

Every mapping is validated against the actual leaf dim: if the mesh-axis
product doesn't divide it (e.g. deepseek's 56 heads on a 16-way axis — the
flattened heads*head_dim dim *is* divisible; granite's 49155 vocab is padded
upstream), the rule falls back to replication for that leaf instead of
failing to lower. Fallbacks are recorded so the dry-run can report them.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig

Pytree = Any

MODEL_AXES = ("heads", "kv_heads", "mlp", "vocab", "expert", "ssm_inner",
              "ssm_conv", "kv_heads_cache")


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_rules(cfg: ModelConfig, mesh: Mesh, mesh_cfg: MeshConfig,
               ) -> Dict[str, Optional[Tuple[str, ...]]]:
    """Logical-name -> mesh-axes tuple (None = replicated)."""
    data = _data_axes(mesh)
    # FSDP axes: by default exclude "pod" so parameter all-gathers stay on
    # ICI and the DCN only carries the per-step gradient all-reduce
    # (EXPERIMENTS §Perf cell C measures the difference)
    fsdp_axes = data if mesh_cfg.fsdp_pod else tuple(
        a for a in data if a != "pod")
    rules: Dict[str, Optional[Tuple[str, ...]]] = {
        "layers": None,
        "batch": data,
        "embed": fsdp_axes if mesh_cfg.fsdp else None,
        "seq": ("data",) if mesh_cfg.sequence_parallel else None,
    }
    for name in MODEL_AXES:
        rules[name] = ("model",)
    # (Refuted hypothesis, kept sharded: replicating kv projections when
    # n_kv_heads < model-axis size does NOT remove the pair-wise retiling
    # all-gathers — they come from attention-internal activation layouts,
    # not the weights. See EXPERIMENTS §Perf cell C iteration C2.)
    a = cfg.attention
    model_size = mesh.shape["model"] if "model" in mesh.axis_names else 1
    # KV-cache fallback: the cache layout is (..., seq, n_kv_heads, head_dim)
    # with the *head count* as its own dim — when it doesn't divide the
    # model axis (GQA kv=8 or 2 on a 16-way axis), shard the cache's
    # sequence dim instead (paged-KV style; XLA inserts the ring-update
    # collectives around the dynamic-update-slice).
    if a is not None and a.n_kv_heads % max(model_size, 1) != 0:
        rules["kv_heads_cache"] = None
        rules["cache_seq"] = ("model",)
    else:
        rules["cache_seq"] = None
    # SSM decode state: (layers, batch, heads, P, N) — shard heads on model
    rules["ssm_heads_cache"] = ("model",)
    return rules


class ShardingReport:
    """Collects per-leaf fallbacks for the dry-run log."""

    def __init__(self):
        self.fallbacks: List[str] = []

    def note(self, path: str, dim: int, size: int, axes: Tuple[str, ...]):
        self.fallbacks.append(
            f"{path} dim{dim}={size} not divisible by {axes} -> replicated")


def _spec_for(shape: Tuple[int, ...], names: Tuple, mesh: Mesh,
              rules: Dict[str, Optional[Tuple[str, ...]]],
              report: Optional[ShardingReport], path: str = "") -> P:
    used: set = set()
    parts: List[Optional[Tuple[str, ...]]] = []
    for d, name in enumerate(names):
        if name is None:
            parts.append(None)
            continue
        axes = rules.get(name)
        if axes is None:
            parts.append(None)
            continue
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        if not axes:
            parts.append(None)
            continue
        prod = int(np.prod([mesh.shape[a] for a in axes]))
        if d >= len(shape) or shape[d] % prod != 0:
            # divisibility fallback: try a prefix of the axes tuple
            while axes and (d >= len(shape) or shape[d] % int(
                    np.prod([mesh.shape[a] for a in axes])) != 0):
                axes = axes[:-1]
            if not axes:
                if report is not None and d < len(shape):
                    parts.append(None)
                    report.note(path, d, shape[d], tuple(rules.get(name) or ()))
                    continue
                parts.append(None)
                continue
        used.update(axes)
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def shardings_for(abstract: Pytree, axes_tree: Pytree, mesh: Mesh,
                  rules: Dict[str, Optional[Tuple[str, ...]]],
                  report: Optional[ShardingReport] = None) -> Pytree:
    """NamedSharding pytree for `abstract` (ShapeDtypeStruct tree) given the
    logical-axes tree (same structure, leaves = tuples of names)."""
    is_names = lambda t: isinstance(t, tuple) and all(
        n is None or isinstance(n, str) for n in t)

    flat_ax, _ = jax.tree_util.tree_flatten_with_path(axes_tree, is_leaf=is_names)
    flat_ab = jax.tree_util.tree_flatten(abstract)[0]
    assert len(flat_ax) == len(flat_ab), (len(flat_ax), len(flat_ab))
    out = []
    for (path, names), leaf in zip(flat_ax, flat_ab):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = _spec_for(tuple(leaf.shape), names, mesh, rules, report, pstr)
        out.append(NamedSharding(mesh, spec))
    treedef = jax.tree_util.tree_structure(abstract)
    return jax.tree_util.tree_unflatten(treedef, out)


def make_activation_constraint(mesh: Mesh, mesh_cfg: MeshConfig,
                               batch: int, seq: int):
    """Activation sharding hook, by kind:

      residual — (B,S,D): batch over ("pod","data"), seq over "model" when
                 sequence_parallel (Megatron-SP: cuts the saved scan-carry
                 stack by the model-axis size),
      hidden   — (B,S,D) before the unembed matmul: batch-sharded, rest
                 replicated (stops GSPMD from gathering the global batch
                 to shard the d_model contraction),
      logits   — (B,S,V): batch over data, vocab over "model" (keeps the
                 fp32 loss math fully sharded).

    Returns fn(x, kind="residual") or None when batch doesn't divide."""
    data = _data_axes(mesh)
    dprod = int(np.prod([mesh.shape[a] for a in data]))
    if batch % dprod != 0:
        return None
    dspec = data if len(data) > 1 else data[0]
    seq_ok = (mesh_cfg.sequence_parallel and "model" in mesh.axis_names
              and seq % mesh.shape["model"] == 0)
    has_model = "model" in mesh.axis_names
    specs = {
        "residual": P(dspec, "model" if seq_ok else None, None),
        "hidden": P(dspec, None, None),
        "logits": P(dspec, None, "model" if has_model else None),
        # (B, E, cap, D): experts over "model" = the EP all-to-all layout
        "moe_buffer": P(dspec, "model" if has_model else None, None, None),
        # (B, H, P, N) SSD carry: heads over "model" (the scan-saved state
        # stack is the dominant buffer for big hybrid models)
        "ssm_state": P(dspec, "model" if has_model else None, None, None),
    }
    _checked_dim = {"logits": -1, "moe_buffer": 1, "ssm_state": 1}

    def constrain(h, kind: str = "residual"):
        spec = specs[kind]
        d = _checked_dim.get(kind)
        if d is not None and spec[d] is not None \
                and h.shape[d] % mesh.shape["model"] != 0:
            spec = P(*([dspec] + [None] * (h.ndim - 1)))
        return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))

    return constrain


# --------------------------------------------------------------------------
# top-level builders
# --------------------------------------------------------------------------

def param_shardings(cfg: ModelConfig, mesh: Mesh, mesh_cfg: MeshConfig,
                    report: Optional[ShardingReport] = None) -> Pytree:
    from repro.models.model import init_params, params_axes
    abstract = jax.eval_shape(lambda k: init_params(k, cfg),
                              jax.random.PRNGKey(0))
    rules = axis_rules(cfg, mesh, mesh_cfg)
    return shardings_for(abstract, params_axes(cfg), mesh, rules, report)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, mesh_cfg: MeshConfig,
                          state_abstract: Pytree,
                          report: Optional[ShardingReport] = None) -> Pytree:
    """Shardings for a TrainState: params + mirrored opt moments; scalars
    replicated. Works off the abstract state from eval_shape."""
    from repro.models.model import params_axes
    rules = axis_rules(cfg, mesh, mesh_cfg)
    pax = params_axes(cfg)
    replicated = NamedSharding(mesh, P())

    def build(field_name: str, sub_abstract: Pytree) -> Pytree:
        if field_name in ("params", "mu", "nu"):
            return shardings_for(sub_abstract, pax, mesh, rules, report)
        return jax.tree.map(lambda _: replicated, sub_abstract)

    st = state_abstract
    return type(st)(
        params=build("params", st.params),
        opt=type(st.opt)(step=replicated,
                         mu=build("mu", st.opt.mu),
                         nu=build("nu", st.opt.nu)),
        step=replicated,
        ef=jax.tree.map(lambda _: replicated, st.ef),
    )


def batch_shardings(cfg: ModelConfig, mesh: Mesh, mesh_cfg: MeshConfig,
                    batch_abstract: Dict[str, Any],
                    long_context: bool = False) -> Dict[str, Any]:
    """Inputs: batch dim over ("pod","data"); for long-context single-row
    batches, the sequence dim goes over "data" instead (SP)."""
    data = _data_axes(mesh)
    out = {}
    for k, v in batch_abstract.items():
        b = v.shape[0]
        prod = int(np.prod([mesh.shape[a] for a in data]))
        if b % prod == 0:
            spec = [data if len(data) > 1 else data[0]] + [None] * (v.ndim - 1)
        elif len(v.shape) > 1 and long_context and v.shape[1] % mesh.shape["data"] == 0:
            spec = [None, "data"] + [None] * (v.ndim - 2)
        else:
            spec = [None] * v.ndim
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, mesh_cfg: MeshConfig,
                    cache_abstract: Pytree, batch: int,
                    report: Optional[ShardingReport] = None) -> Pytree:
    """Decode-state shardings. Batch over ("pod","data") when divisible;
    otherwise (long_500k's batch=1) the cache sequence dim is sharded over
    "data" — sequence parallelism for the KV pages."""
    from repro.models.transformer import cache_axes
    rules = axis_rules(cfg, mesh, mesh_cfg)
    data = _data_axes(mesh)
    prod = int(np.prod([mesh.shape[a] for a in data]))
    if batch % prod != 0:
        rules["batch"] = None
        # shard KV pages over "data" (plus "model" too when the kv-head dim
        # can't use it) — sequence parallelism for the cache
        if rules.get("kv_heads_cache") is None:
            rules["cache_seq2"] = ("data", "model")
        else:
            rules["cache_seq2"] = ("data",)
    ax = cache_axes(cfg)
    if batch % prod != 0:
        # rewrite attention cache axes: seq dim gets "cache_seq2"
        def rewrite(t):
            if isinstance(t, tuple) and len(t) >= 3 and t[1] == "batch":
                lst = list(t)
                if lst[2] in (None, "cache_seq"):
                    lst[2] = "cache_seq2"
                return tuple(lst)
            return t
        ax = jax.tree.map(rewrite, ax,
                          is_leaf=lambda t: isinstance(t, tuple))
    # decode state = {"cache": ..., "length": scalar}
    state_axes = {"cache": ax, "length": ()}
    return shardings_for(cache_abstract, state_axes, mesh, rules, report)
