"""Distributed runtime: sharding rules, train/serve loops, FT, elasticity."""
from repro.runtime.sharding import (  # noqa: F401
    axis_rules, batch_shardings, cache_shardings, param_shardings,
    shardings_for, train_state_shardings,
)
from repro.runtime.train_loop import (  # noqa: F401
    TrainState, make_grain_step, make_train_step, train_state_init,
)
from repro.runtime.serve_loop import HeMTBatcher, make_serve_step  # noqa: F401
from repro.runtime.serving import (  # noqa: F401
    RequestModel, ServingReport, ServingScenario, run_round,
)
