"""Elastic scaling: respond to slice loss/gain without restarting training.

The HeMT insight makes elasticity cheap: capacity change is just another
speed change, so the planner re-skews instead of redistributing state.
Sequence of events on a resize (DESIGN.md §8):

  1. FleetMonitor declares a slice dead (or the scheduler grants new ones).
  2. `replan` updates the GrainPlanner slice set — survivors keep their
     AR(1) estimates; newcomers cold-start at the survivor mean (§5.1 L_k^o).
  3. Data assignment is index-based (repro.data.grains), so the next step's
     grain ranges simply split differently — no data movement.
  4. Model/optimizer state: under pure cross-slice DP each slice holds a
     full replica, so nothing reshards; under FSDP the restore path re-lowers
     against the new mesh from the latest checkpoint (`reshard_restore`).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.core.planner import GrainPlanner

Pytree = Any


class FleetExhaustedError(RuntimeError):
    """Every slice died and no newcomers arrived: the fleet cannot run
    another step.  Carries the last-known AR(1) speed ``estimates``
    (slice name -> estimated speed, directly-observed slices only) so a
    recovery loop can checkpoint them and halt gracefully — or seed a
    replacement fleet — instead of crashing with a bare error.

    Subclasses :class:`RuntimeError` with the historical message, so
    pre-existing ``except RuntimeError`` / message-matching callers keep
    working."""

    def __init__(self, estimates: Dict[str, float]):
        super().__init__("no slices left after resize")
        self.estimates = dict(estimates)


def replan(planner: GrainPlanner, survivors: Sequence[str],
           newcomers: Sequence[str] = ()) -> List[str]:
    """Apply a fleet change to the planner; returns the new slice list.

    Raises :class:`FleetExhaustedError` (carrying the planner's last-known
    speed estimates) when survivors and newcomers are both empty."""
    new_slices = list(survivors) + list(newcomers)
    if not new_slices:
        raise FleetExhaustedError(planner.estimator.known())
    planner.resize(new_slices)
    return new_slices


def reshard_restore(ckpt_manager, state_like: Pytree,
                    shardings: Optional[Pytree] = None) -> Pytree:
    """Restore the latest checkpoint and (optionally) place it under new
    shardings — the FSDP resize path. On a real fleet `jax.device_put` with
    the new NamedShardings moves each shard over DCN exactly once."""
    restored = ckpt_manager.restore_latest(state_like)
    if restored is None:
        raise FileNotFoundError("no checkpoint to resume from")
    step, state, _meta = restored
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return step, state


def scale_event_log(planner: GrainPlanner) -> List[Dict]:
    """Per-step grain allocations (for EXPERIMENTS / tests)."""
    return [{"mode": p.mode, "grains": dict(zip(p.slice_names, p.grains))}
            for p in planner.step_log]
