"""The paper's multi-stage evaluation workloads (§7), implemented in JAX."""
from repro.workloads.kmeans import KMeansJob, kmeans_reference  # noqa: F401
from repro.workloads.pagerank import PageRankJob, pagerank_reference  # noqa: F401
