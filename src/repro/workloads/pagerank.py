"""PageRank as a HeMT-schedulable multi-stage job (paper §7, Fig 18).

"PageRank ... is a single Spark job containing multiple computation stages
concatenated together through shuffling" — per iteration, each executor
processes the out-edges of its vertex bucket and shuffles rank
contributions to the owners of the destination vertices. Vertex->bucket
ownership is the partitioner: the default even hash vs the paper's
Algorithm 1 skewed hash (`repro.core.skewed_hash`), which sizes buckets by
executor capacity. Iterations are short (~10s at 2-way in the paper), so
per-task overhead matters — exactly the regime where HomT microtasking
loses (Fig 18).

Math is real JAX (sparse-by-segment rank propagation); executor timing
comes from the simulator with per-task overhead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import PullSpec, StaticSpec, run_job
from repro.core.partitioner import even_split
from repro.core.simulator import SimNode
from repro.core.skewed_hash import bucket_of, integer_capacities


def pagerank_reference(src: np.ndarray, dst: np.ndarray, n: int, iters: int,
                       d: float = 0.85) -> np.ndarray:
    """Single-node PageRank oracle (uniform out-degree normalization)."""
    ranks = jnp.full((n,), 1.0 / n)
    out_deg = jnp.maximum(jax.ops.segment_sum(jnp.ones(len(src)), src, n), 1.0)
    s, t = jnp.asarray(src), jnp.asarray(dst)
    for _ in range(iters):
        contrib = ranks[s] / out_deg[s]
        incoming = jax.ops.segment_sum(contrib, t, n)
        ranks = (1 - d) / n + d * incoming
    return np.asarray(ranks)


@dataclass
class StageReport:
    iteration: int
    makespan: float
    idle: float
    bucket_sizes: List[int]


class PageRankJob:
    """Distributed PageRank with even-hash or skewed-hash vertex buckets."""

    def __init__(self, src: np.ndarray, dst: np.ndarray, n: int,
                 nodes: Sequence[SimNode], *, mode: str = "hemt",
                 weights: Optional[Sequence[float]] = None,
                 n_tasks: Optional[int] = None, d: float = 0.85,
                 work_per_edge: float = 2e-5, mitigation=None,
                 adaptive=None):
        assert mode in ("hemt", "homt", "even")
        self.src, self.dst, self.n = src, dst, n
        self.nodes = list(nodes)
        self.mode = mode
        self.d = d
        self.work_per_edge = work_per_edge
        self.n_tasks = n_tasks or 4 * len(nodes)
        # straggler mitigation policy (repro.core.speculation) riding every
        # iteration's stage spec — rescues a skewed-hash bucket stranded on
        # a node whose capacity drifted since the weights were learned
        self.mitigation = mitigation
        # OA-HeMT: an engine.AdaptivePlan re-skews each iteration's
        # edge-processing stage at its barrier from AR(1)-learned speeds
        # (rank math is bucket-invariant, so only the schedule adapts; the
        # shuffle buckets stay fixed, as re-hashing vertices mid-job would
        # move data, not just work)
        self.adaptive = adaptive
        ne = len(nodes)
        if mode == "hemt":
            if weights is None:        # adaptive cold start: even buckets
                weights = [1.0] * ne
            caps = integer_capacities(weights, resolution=1 << 12)
        else:
            caps = integer_capacities([1.0] * ne, resolution=1 << 12)
        # vertex -> owning executor bucket (Algorithm 1 over a Knuth
        # multiplicative hash — raw ids are NOT uniform over the capacity
        # space when n < resolution)
        vhash = (np.arange(n, dtype=np.uint64) * np.uint64(2654435761)
                 % np.uint64(1 << 31)).astype(np.int64)
        self.owner = bucket_of(vhash, caps)
        self.reports: List[StageReport] = []
        self._t = 0.0

    # ------------------------------------------------------------------
    def run(self, iters: int) -> np.ndarray:
        n, ne = self.n, len(self.nodes)
        src, dst = jnp.asarray(self.src), jnp.asarray(self.dst)
        out_deg = jnp.maximum(
            jax.ops.segment_sum(jnp.ones(len(self.src)), src, n), 1.0)
        ranks = jnp.full((n,), 1.0 / n)
        # per-executor edge counts: an executor processes out-edges of the
        # vertices it owns (that is the per-stage work the scheduler sees)
        edge_owner = self.owner[self.src]
        edges_per_exec = np.bincount(edge_owner, minlength=ne)

        # the vertex->bucket shuffle is fixed, so every iteration runs the
        # same stage: hand the whole barrier sequence to run_job (one spec,
        # solved once, O(nodes) per further iteration) instead of
        # re-entering the engine per stage
        if self.mode == "homt":
            per = even_split(int(edges_per_exec.sum()), self.n_tasks)
            spec = PullSpec(works=tuple(c * self.work_per_edge for c in per),
                            mitigation=self.mitigation)
        else:
            spec = StaticSpec(works=tuple(c * self.work_per_edge
                                          for c in edges_per_exec),
                              mitigation=self.mitigation)
        sched = run_job(self.nodes, [spec] * iters, start_time=self._t,
                        adaptive=self.adaptive)
        bucket_sizes = list(np.bincount(self.owner, minlength=ne))

        for it in range(iters):
            contrib = ranks[src] / out_deg[src]
            incoming = jax.ops.segment_sum(contrib, dst, n)
            ranks = (1 - self.d) / n + self.d * incoming
            summ = sched.stages[it]
            self.reports.append(StageReport(it, summ.span, summ.idle_time,
                                            list(bucket_sizes)))
        self._t = sched.completion
        return np.asarray(ranks)

    def total_time(self) -> float:
        return self._t


def random_graph(n: int, avg_deg: int, seed: int = 0,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    m = n * avg_deg
    return (rng.integers(0, n, m).astype(np.int64),
            rng.integers(0, n, m).astype(np.int64))
