"""K-Means as a HeMT-schedulable multi-stage job (paper §7, Fig 17).

The paper: "K-Means consists of repetitive simple two-stage Spark jobs" —
per iteration, a map stage (assign points to nearest centroid, partial
sums per partition) and a reduce stage (combine partials, update
centroids). The map stage carries ~all the compute, so HeMT skews the
*point-partition* sizes by executor capacity; the reduce is tiny.

Math is real JAX; executor timing comes from the calibrated simulator
(`schedule_iteration`) exactly like the training driver.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import PullSpec, StaticSpec, run_job
from repro.core.partitioner import even_split, proportional_split
from repro.core.simulator import SimNode


def kmeans_reference(points: np.ndarray, k: int, iters: int, seed: int = 0,
                     ) -> np.ndarray:
    """Plain single-node K-Means (the oracle for partition-invariance)."""
    rng = np.random.default_rng(seed)
    centroids = points[rng.choice(len(points), k, replace=False)]
    pts = jnp.asarray(points)
    c = jnp.asarray(centroids)
    for _ in range(iters):
        d = jnp.sum((pts[:, None, :] - c[None]) ** 2, -1)
        assign = jnp.argmin(d, -1)
        sums = jax.ops.segment_sum(pts, assign, k)
        cnts = jax.ops.segment_sum(jnp.ones(len(points)), assign, k)
        c = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1)[:, None], c)
    return np.asarray(c)


@dataclass
class IterationReport:
    iteration: int
    makespan: float
    idle: float
    split: List[int]


class KMeansJob:
    """HeMT/HomT-scheduled distributed K-Means over simulated executors."""

    def __init__(self, points: np.ndarray, k: int, nodes: Sequence[SimNode],
                 *, mode: str = "hemt", weights: Optional[Sequence[float]] = None,
                 n_tasks: Optional[int] = None, seed: int = 0,
                 work_per_point: float = 1e-4, mitigation=None,
                 adaptive=None):
        assert mode in ("hemt", "homt", "even")
        self.points = points
        self.k = k
        self.nodes = list(nodes)
        self.mode = mode
        self.weights = list(weights) if weights else None
        self.n_tasks = n_tasks or 4 * len(nodes)
        self.work_per_point = work_per_point
        # straggler mitigation policy (repro.core.speculation) riding every
        # iteration's stage spec — covers stale `weights` on a drifted
        # cluster without changing the partition itself
        self.mitigation = mitigation
        # OA-HeMT: an engine.AdaptivePlan re-splitting each iteration's
        # macrotasks at its barrier from AR(1)-learned executor speeds —
        # `weights` (or the even cold-start split) only seeds iteration 0.
        # The result is partition-invariant, so the math below keeps the
        # fixed point partition while the schedule adapts.
        self.adaptive = adaptive
        rng = np.random.default_rng(seed)
        self.centroids = jnp.asarray(
            points[rng.choice(len(points), k, replace=False)])
        self.reports: List[IterationReport] = []
        self._t = 0.0

    # ------------------------------------------------------------------
    def _partition(self) -> List[int]:
        n = len(self.points)
        if self.mode == "hemt":
            if self.weights is None:    # adaptive cold start: even split
                return even_split(n, len(self.nodes))
            return proportional_split(n, self.weights)
        if self.mode == "even":
            return even_split(n, len(self.nodes))
        return even_split(n, self.n_tasks)

    # ------------------------------------------------------------------
    def run(self, iters: int) -> jnp.ndarray:
        pts = jnp.asarray(self.points)
        n, k = len(self.points), self.k
        # the partition is mode-determined and data-independent, so every
        # iteration is the same stage: one run_job call schedules the whole
        # barrier sequence (repetitive jobs back-to-back)
        split = self._partition()
        if self.mode == "homt":
            spec = PullSpec(works=tuple(c * self.work_per_point
                                        for c in split),
                            mitigation=self.mitigation)
        else:
            spec = StaticSpec(works=tuple(c * self.work_per_point
                                          for c in split),
                              mitigation=self.mitigation)
        sched = run_job(self.nodes, [spec] * iters, start_time=self._t,
                        adaptive=self.adaptive)
        for it in range(iters):
            # real math, partition-structured: per-partition partial sums
            bounds = np.cumsum([0] + list(split))
            sums = jnp.zeros((k, pts.shape[1]))
            cnts = jnp.zeros((k,))
            for lo, hi in zip(bounds[:-1], bounds[1:]):
                if hi == lo:
                    continue
                part = pts[lo:hi]
                d = jnp.sum((part[:, None, :] - self.centroids[None]) ** 2, -1)
                assign = jnp.argmin(d, -1)
                sums = sums + jax.ops.segment_sum(part, assign, k)
                cnts = cnts + jax.ops.segment_sum(jnp.ones(hi - lo), assign, k)
            self.centroids = jnp.where(
                cnts[:, None] > 0, sums / jnp.maximum(cnts, 1)[:, None],
                self.centroids)
            summ = sched.stages[it]
            self.reports.append(IterationReport(it, summ.span, summ.idle_time,
                                                list(split)))
        self._t = sched.completion
        return self.centroids

    def total_time(self) -> float:
        return self._t
