"""Workload partitioners: HomT (equal) and HeMT (capacity-proportional).

The paper's partitioning rule (§5.1): executor i gets d_i = D * v_i / V.
Real systems need integer partitions of records/rows/grains, often with an
alignment quantum (TPU: grains must be whole microbatches; HDFS: whole
blocks). `proportional_split` uses largest-remainder rounding so that
sum(d_i) == D exactly and the split is within one quantum of ideal.
"""
from __future__ import annotations

import math
from typing import List, Sequence

from repro.core.estimators import normalized


def even_split(total: int, n: int, quantum: int = 1) -> List[int]:
    """HomT / Spark-default: equal split of `total` into n integer parts,
    multiples of `quantum` (residual spread over the first parts)."""
    if n <= 0:
        raise ValueError("n must be positive")
    if total % quantum != 0:
        raise ValueError(f"total {total} not a multiple of quantum {quantum}")
    units = total // quantum
    base, rem = divmod(units, n)
    return [(base + (1 if i < rem else 0)) * quantum for i in range(n)]


def proportional_split(total: int, weights: Sequence[float],
                       quantum: int = 1,
                       min_share: int = 0) -> List[int]:
    """HeMT: split `total` (a multiple of `quantum`) proportional to weights.

    Largest-remainder rounding on quantum units; optional per-part floor
    (min_share, in units of `quantum`) so no executor starves (needed to
    keep collecting speed observations on slow nodes — paper §5.1's
    averaging argument assumes every executor keeps receiving work).
    """
    w = normalized(weights)
    n = len(w)
    if total % quantum != 0:
        raise ValueError(f"total {total} not a multiple of quantum {quantum}")
    units = total // quantum
    if min_share * n > units:
        raise ValueError("min_share infeasible")
    # largest-remainder rounding on the FULL unit count (rounding after a
    # floor pre-allocation distorts the split away from d_i = D v_i / V),
    # then repair min_share violations by stealing from the largest parts.
    ideal = [wi * units for wi in w]
    base = [math.floor(x) for x in ideal]
    rem = units - sum(base)
    frac = sorted(range(n), key=lambda i: ideal[i] - base[i], reverse=True)
    for i in frac[:rem]:
        base[i] += 1
    for i in range(n):
        while base[i] < min_share:
            j = max(range(n), key=lambda k: base[k])
            if base[j] <= min_share:
                raise ValueError("min_share infeasible")
            base[j] -= 1
            base[i] += 1
    return [b * quantum for b in base]


def microtask_split(total: int, n_tasks: int, quantum: int = 1) -> List[int]:
    """HomT with explicit task count (tasks >> executors)."""
    return even_split(total, n_tasks, quantum)


def split_error(split: Sequence[int], weights: Sequence[float]) -> float:
    """Max relative deviation of a split from the ideal proportional one."""
    total = sum(split)
    ideal = [w * total for w in normalized(weights)]
    return max(abs(s - i) for s, i in zip(split, ideal))


def makespan(split: Sequence[float], speeds: Sequence[float]) -> float:
    """Completion time of a one-task-per-executor assignment."""
    return max((d / v if d > 0 else 0.0) for d, v in zip(split, speeds))


def optimal_makespan(total: float, speeds: Sequence[float]) -> float:
    """Lower bound: all executors finish together = D / sum(v)."""
    return total / sum(speeds)


def hemt_split_floats(total: float, speeds: Sequence[float]) -> List[float]:
    """Continuous HeMT split d_i = D v_i / V (paper §5.1, pre-rounding)."""
    return [total * w for w in normalized(speeds)]
