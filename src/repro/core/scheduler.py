"""Job-level schedulers: OA-HeMT adaptation loop, HomT baseline, provisioned
and burstable HeMT — paper §5, §6.

`AdaptiveHeMTScheduler` drives a sequence of same-class jobs (paper: fifty
WordCount jobs through a submission queue; here also: a sequence of training
steps): partition by current speed estimates -> run (simulated or real) ->
feed observed (d_i, t_i) back into the AR(1) estimator.

All schedulers simulate through ``run_pull_stage``/``run_static_stage`` and
therefore ride the fast-path engine (``repro.core.engine``): the constant-
speed stages every scheduler below emits take the vectorized closed forms,
so job sweeps (Fig 7/8/13) scale to large task counts.  ``MultiStageJob``
goes one further: it hands the whole stage sequence to ``engine.run_job``,
which carries per-node finish vectors across the program barriers —
an S-stage HomT/HeMT job costs O(S·n) instead of S separate engine entries
materializing task records per stage.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.capacity import BurstableNode, burstable_split
from repro.core.estimators import ARSpeedEstimator, FudgeFactorLearner
from repro.core.partitioner import hemt_split_floats
from repro.core.simulator import (
    SimNode, SimTask, StageResult, run_pull_stage, run_static_stage,
)


@dataclass
class JobResult:
    job_index: int
    completion: float
    idle_time: float
    split: List[float]
    speeds_used: List[float]


class AdaptiveHeMTScheduler:
    """Oblivious-Adaptive HeMT (paper §5).

    First job: even split (the paper's k=1 rule). Afterwards d_i ~ v_i.

    ``mitigation`` (an event-level policy from ``repro.core.speculation``,
    e.g. WorkStealing/SpeculativeCopies) covers the window where estimates
    are stale — the very first job's even split, and every job after an
    un-observed capacity change — by letting idle executors rescue the
    straggler instead of idling until the barrier (paper §5's OA-HeMT
    discussion).  Speed observations then use *executed* work per node (a
    stolen-from node must not be credited for work it handed off).
    """

    def __init__(self, executors: Sequence[str], alpha: float = 0.0,
                 min_share: float = 0.0, mitigation=None):
        # NB: the paper's Fig 7 experiment uses *zero* forgetting factor.
        self.executors = list(executors)
        self.estimator = ARSpeedEstimator(alpha=alpha)
        self.min_share = min_share
        self.mitigation = mitigation
        self.history: List[JobResult] = []

    def plan(self, total_work: float) -> List[float]:
        if not self.estimator.known():
            n = len(self.executors)
            return [total_work / n] * n
        speeds = self.estimator.speeds(self.executors)
        split = hemt_split_floats(total_work, speeds)
        if self.min_share > 0:
            floor = self.min_share * total_work
            split = [max(s, floor) for s in split]
            scale = total_work / sum(split)
            split = [s * scale for s in split]
        return split

    def adaptive_plan(self, quantum: Optional[float] = None,
                      min_units: int = 0):
        """An :class:`~repro.core.engine.AdaptivePlan` sharing THIS
        scheduler's estimator, for handing to ``run_job``/
        ``MultiStageJob.run``: barrier-level observations inside a job and
        job-level observations across the submission queue accumulate into
        the same workload-specific AR(1) state (paper §5.1)."""
        from repro.core.engine import AdaptivePlan
        return AdaptivePlan(estimator=self.estimator, quantum=quantum,
                            min_units=min_units)

    def run_simulated_job(self, nodes: Sequence[SimNode],
                          stage_works: Sequence[float],
                          adaptive: bool = True) -> List[JobResult]:
        """Run ONE multi-stage job (program barriers between stages)
        through ``engine.run_job``, re-planning every stage's split at its
        barrier from the shared estimator when ``adaptive`` (the paper's
        OA-HeMT loop; ``adaptive=False`` is the stale-static baseline that
        keeps the submission-time splits).  Per-stage results are appended
        to ``history`` exactly like per-job results from
        :meth:`run_simulated_sequence`."""
        from repro.core.engine import StaticSpec, run_job
        specs = [StaticSpec(works=tuple(self.plan(w))) for w in stage_works]
        plan = self.adaptive_plan() if adaptive else None
        base = len(self.history)
        sched = run_job(nodes, specs, adaptive=plan)
        for k, summ in enumerate(sched.stages):
            split = [summ.work.get(nd.name, 0.0) for nd in nodes]
            if not adaptive:
                # keep the estimator in the loop even without re-planning
                # (a stale-static scheduler still observes, paper §5)
                for nd, w in zip(nodes, split):
                    dt = summ.node_finish[nd.name] - summ.start
                    if w > 0.0 and dt > 0.0:
                        self.estimator.observe(nd.name, w, dt)
            speeds = self.estimator.speeds([nd.name for nd in nodes])
            self.history.append(JobResult(base + k, summ.span,
                                          summ.idle_time, split, speeds))
        return self.history[base:]

    def record(self, job_index: int, split: Sequence[float],
               elapsed: Sequence[float], result: Optional[StageResult] = None,
               ) -> None:
        for ex, d, t in zip(self.executors, split, elapsed):
            if d > 0 and t > 0:
                self.estimator.observe(ex, d, t)
        speeds = self.estimator.speeds(self.executors)
        comp = max(elapsed)
        idle = comp - min(elapsed)
        if result is not None:
            comp, idle = result.completion, result.idle_time
        self.history.append(JobResult(job_index, comp, idle, list(split), speeds))

    # -- simulation driver ---------------------------------------------------
    def run_simulated_sequence(self, node_factory: Callable[[int], List[SimNode]],
                               n_jobs: int, total_work: float,
                               io_mb_total: float = 0.0,
                               uplink_bw: Optional[float] = None,
                               datanode: int = 0) -> List[JobResult]:
        """Run n_jobs jobs; node_factory(k) returns the cluster as it exists
        at job k (speed profiles relative to job start — lets benchmarks
        inject interference at chosen job indices, paper Fig 7).

        ``io_mb_total`` + ``uplink_bw`` put each job's input behind the
        flow-shared uplink of ``datanode`` (macrotasks read a
        works-proportional share): with an I/O-aware mitigation policy,
        stale-estimate stragglers are rescued by duplicate readers
        re-fetching through the same uplink (the Claim 2 x mitigation
        cross setting)."""
        for k in range(n_jobs):
            nodes = node_factory(k)
            split = self.plan(total_work)
            assignments = [
                [SimTask(w, io_mb_total * w / total_work if io_mb_total > 0
                         else 0.0,
                         datanode if io_mb_total > 0 else -1, task_id=i)]
                for i, w in enumerate(split)]
            res = run_static_stage(nodes, assignments, uplink_bw=uplink_bw,
                                   mitigation=self.mitigation)
            per_node_elapsed = [res.node_finish[nd.name] for nd in nodes]
            if self.mitigation is not None:
                # mitigation moves work between nodes: feed the estimator
                # the work each node actually executed, not the plan
                executed = {nd.name: 0.0 for nd in nodes}
                win_end: Dict[int, float] = {}
                for r in res.records:
                    executed[r.node] += r.cpu_work
                    win_end[r.task_id] = r.end
                split_observed = [executed[nd.name] for nd in nodes]
                for i, nd in enumerate(nodes):
                    if split_observed[i] > 0.0 or split[i] <= 0.0:
                        continue
                    # a straggler whose only attempt was cancelled by a
                    # winning speculative copy left no record — credit the
                    # partial progress its executor would report (real
                    # drivers see a killed attempt's progress counters),
                    # else the estimator never observes the degraded speed
                    # the mitigation exists to cover
                    t_cancel = win_end.get(i)
                    if t_cancel is not None and t_cancel > 0.0:
                        split_observed[i] = min(
                            split[i],
                            nodes[i].work_between(nd.task_overhead, t_cancel))
                        per_node_elapsed[i] = t_cancel
            else:
                split_observed = split
            self.record(k, split_observed, per_node_elapsed, res)
        return self.history


class HomTScheduler:
    """Homogeneous microtasking baseline with a configurable task count."""

    def __init__(self, n_tasks: int):
        self.n_tasks = n_tasks

    def run_simulated(self, nodes: Sequence[SimNode], total_work: float,
                      ) -> StageResult:
        per = total_work / self.n_tasks
        tasks = [SimTask(per, task_id=i) for i in range(self.n_tasks)]
        return run_pull_stage(nodes, tasks)


class ProvisionedHeMTScheduler:
    """§6.1: split by known static resource shares (e.g. Mesos offers of
    1.0 and 0.4 CPUs), optionally corrected by a learned fudge factor."""

    def __init__(self, shares: Sequence[float],
                 fudge: Optional[FudgeFactorLearner] = None,
                 fudge_index: int = -1):
        self.shares = list(shares)
        self.fudge = fudge
        self.fudge_index = fudge_index  # which executor the fudge applies to

    def effective_shares(self) -> List[float]:
        s = list(self.shares)
        if self.fudge is not None and 0 <= self.fudge_index < len(s):
            fastest = max(s)
            s[self.fudge_index] = fastest * self.fudge.effective
        return s

    def plan(self, total_work: float) -> List[float]:
        return hemt_split_floats(total_work, self.effective_shares())

    def run_simulated(self, nodes: Sequence[SimNode], total_work: float,
                      ) -> StageResult:
        split = self.plan(total_work)
        assignments = [[SimTask(w, task_id=i)] for i, w in enumerate(split)]
        return run_static_stage(nodes, assignments)


class BurstableHeMTScheduler:
    """§6.2: split by superposed token-bucket workload curves W_i(t')."""

    def __init__(self, nodes: Sequence[BurstableNode]):
        self.bnodes = list(nodes)

    def plan(self, total_work: float) -> Tuple[List[float], float]:
        return burstable_split(self.bnodes, total_work)

    def run_simulated(self, total_work: float, overhead: float = 0.0,
                      ) -> StageResult:
        split, _ = self.plan(total_work)
        nodes = [SimNode.burstable(f"b{i}", bn, overhead)
                 for i, bn in enumerate(self.bnodes)]
        assignments = [[SimTask(w, task_id=i)] for i, w in enumerate(split)]
        return run_static_stage(nodes, assignments)


# -- multi-stage jobs (paper §7) ---------------------------------------------

@dataclass
class MultiStageJob:
    """stages: list of per-stage total work; between stages data is shuffled
    by either an even or a capacity-skewed partitioner (Algorithm 1).

    ``stage_io_mb`` (optional, one total per stage) makes each stage read
    its input from ``datanode`` through the flow-shared uplink: HomT
    microtasks each fetch an even share, HeMT macrotasks a
    works-proportional share (``StaticSpec.io_mb`` semantics).  Pass
    ``uplink_bw`` to :meth:`run` to make the I/O effective — the Claim 2 x
    mitigation cross setting, where duplicate readers re-fetch through the
    same shared uplink."""
    stage_works: List[float]
    stage_io_mb: Optional[List[float]] = None
    datanode: int = 0

    def _stage_io(self, k: int) -> float:
        if self.stage_io_mb is None:
            return 0.0
        return self.stage_io_mb[k]

    def specs(self, weights: Optional[Sequence[float]],
              n_tasks_per_stage: Optional[int] = None,
              mitigation=None) -> List:
        """The job as engine stage specs: HomT (weights=None) -> one uniform
        PullSpec per stage; HeMT -> one skewed StaticSpec per stage.
        ``mitigation`` (a ``repro.core.speculation`` policy) rides every
        stage spec — event-level policies run inside each stage,
        ReskewHandoff folds straggler residuals across the barriers."""
        from repro.core.engine import PullSpec, StaticSpec
        if weights is None:
            return [PullSpec(n_tasks=n_tasks_per_stage,
                             task_work=w / n_tasks_per_stage,
                             io_mb=self._stage_io(k) / n_tasks_per_stage,
                             datanode=self.datanode if self._stage_io(k) > 0
                             else -1,
                             mitigation=mitigation)
                    for k, w in enumerate(self.stage_works)]
        norm = sum(weights)
        return [StaticSpec(works=tuple(w * wi / norm for wi in weights),
                           mitigation=mitigation,
                           io_mb=self._stage_io(k),
                           datanode=self.datanode if self._stage_io(k) > 0
                           else -1)
                for k, w in enumerate(self.stage_works)]

    def run(self, nodes: Sequence[SimNode], weights: Optional[Sequence[float]],
            n_tasks_per_stage: Optional[int] = None, records: bool = False,
            mitigation=None, adaptive=None,
            uplink_bw: Optional[float] = None) -> Tuple[float, List]:
        """weights=None -> HomT with n_tasks_per_stage; else HeMT skewed.

        Thin wrapper over ``engine.run_job``: per-node finish vectors are
        carried across the program barriers, so the whole S-stage sequence
        costs O(S·n) on constant-speed clusters (record-free
        ``StageSummary`` per stage).  ``records=True`` re-enters the engine
        once per stage instead and returns full ``StageResult`` objects
        with per-task records (the differential-test / debugging path).
        ``adaptive`` (an :class:`~repro.core.engine.AdaptivePlan`) re-plans
        each HeMT stage's split at its barrier from AR(1)-learned speeds —
        the paper's OA-HeMT loop riding the same run_job call.
        ``uplink_bw`` activates the flow-shared I/O model for stages with
        ``stage_io_mb`` input (both spec and records paths).
        """
        if records:
            from repro.core.speculation import ReskewHandoff
            if adaptive is not None:
                raise ValueError(
                    "records=True re-enters the engine per stage; "
                    "per-barrier adaptive re-planning only runs through "
                    "run_job (records=False)")
            if isinstance(mitigation, ReskewHandoff):
                raise ValueError(
                    "records=True re-enters the engine per stage and cannot "
                    "apply barrier-level ReskewHandoff; use records=False "
                    "(run_job folds residuals across barriers) or an "
                    "event-level policy")
            t, results = 0.0, []
            norm = None if weights is None else sum(weights)
            for k, w in enumerate(self.stage_works):
                io = self._stage_io(k)
                dn = self.datanode if io > 0 else -1
                if weights is None:
                    per = w / n_tasks_per_stage
                    tasks = [SimTask(per, io / n_tasks_per_stage, dn,
                                     task_id=i)
                             for i in range(n_tasks_per_stage)]
                    res = run_pull_stage(nodes, tasks, start_time=t,
                                         uplink_bw=uplink_bw,
                                         mitigation=mitigation)
                else:
                    assignments = [[SimTask(w * wi / norm, io * wi / norm,
                                            dn, task_id=i)]
                                   for i, wi in enumerate(weights)]
                    res = run_static_stage(nodes, assignments, start_time=t,
                                           uplink_bw=uplink_bw,
                                           mitigation=mitigation)
                results.append(res)
                t = res.completion  # program barrier between stages
            return t, results
        from repro.core.engine import run_job
        sched = run_job(nodes, self.specs(weights, n_tasks_per_stage,
                                          mitigation=mitigation),
                        uplink_bw=uplink_bw, adaptive=adaptive)
        return sched.completion, sched.stages
