"""Distributed-storage contention model — paper §3, Claim 2.

With n datanodes, replica factor r (n >= r), random replica placement and
uniform closest-replica choice:

  p1 = P(two readers of the SAME block hit the same datanode)   = 1/r
  p2 = P(two readers of DIFFERENT blocks hit the same datanode)
     = sum_{v=max(2r-n,0)}^{r} P(v) * v / r^2 ,
  P(v) = C(r,v) C(n-r, r-v) / C(n,r)          (hypergeometric overlap)

Claim 2: p1 >= p2, equality iff r = n. Finer partitioning makes concurrent
same-block reads more likely, hence more uplink contention (Fig 5).

We use the same model for data-pipeline feeder placement in the framework:
shard replicas ~ datanodes, concurrently-scheduled grains ~ readers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DuplicatePlacement:
    """Which datanode a duplicate reader re-fetches its input from.

    The mitigation subsystem (``repro.core.speculation``) launches
    duplicate readers — a speculative copy re-fetching a straggler's full
    input, or a steal thief re-fetching its stolen range — and each
    duplicate opens a *new* flow through the flow-shared uplink model.
    This policy decides where that flow lands:

    * ``"same"`` (default): the duplicate re-reads the original datanode.
      The new flow fairly shares that uplink with the primary reader — the
      Claim 2 contention cost of duplicating a read, modelled exactly.
    * ``"replica"``: the duplicate reads the block's next replica in a
      deterministic replica ring of ``n_datanodes`` nodes: datanode
      ``(d + 1) % n_datanodes``.  The probabilistic placement model above
      (``overlap_pmf`` etc.) describes *expected* contention under random
      placement; the simulated engine needs a deterministic choice, so we
      pin the ring-adjacent replica — the best case the paper's p1 >= p2
      argument allows, where the duplicate avoids the primary's uplink
      entirely (unless another task's flow already lives there).

    Frozen (hashable) so it can ride the frozen mitigation policies
    through ``PullSpec``/``StaticSpec`` and the ``run_job`` solve caches.
    """
    policy: str = "same"        # "same" | "replica"
    n_datanodes: int = 0        # replica ring size (required for "replica")

    def __post_init__(self):
        if self.policy not in ("same", "replica"):
            raise ValueError(
                f"placement policy must be 'same' or 'replica': {self.policy!r}")
        if self.policy == "replica" and self.n_datanodes < 2:
            raise ValueError("replica placement needs n_datanodes >= 2 "
                             "(a 1-node ring has no distinct replica)")

    def choose(self, datanode: int) -> int:
        """Datanode the duplicate flow reads from (no-op for tasks
        without I/O, ``datanode < 0``)."""
        if datanode < 0 or self.policy == "same":
            return datanode
        return (datanode + 1) % self.n_datanodes


def overlap_pmf(n: int, r: int, v: int) -> float:
    """P(v): probability two random r-subsets of n nodes overlap in v."""
    if v < max(2 * r - n, 0) or v > r:
        return 0.0
    return (math.comb(r, v) * math.comb(n - r, r - v)) / math.comb(n, r)


def p_same_block(r: int) -> float:
    """p1 = 1/r."""
    if r < 1:
        raise ValueError("replica factor must be >= 1")
    return 1.0 / r


def p_diff_block(n: int, r: int) -> float:
    """p2 = sum_v P(v) v / r^2."""
    if n < r:
        raise ValueError("need n >= r")
    lo = max(2 * r - n, 0)
    return sum(overlap_pmf(n, r, v) * v / (r * r) for v in range(lo, r + 1))


def contention_probability(n: int, r: int, same_block: bool) -> float:
    return p_same_block(r) if same_block else p_diff_block(n, r)


def expected_uplink_collisions(n_tasks: int, n_blocks: int, n: int, r: int,
                               seed: int = 0, trials: int = 2000) -> float:
    """Monte-Carlo: tasks read blocks round-robin; each block's replicas on a
    random r-subset; reader picks a replica uniformly. Returns the expected
    number of datanode collisions among concurrent reader pairs (used by the
    Fig 5 benchmark to produce stage times under an uplink bandwidth cap)."""
    rng = np.random.default_rng(seed)
    collisions = 0
    for _ in range(trials):
        placement = [rng.choice(n, size=r, replace=False) for _ in range(n_blocks)]
        readers = [rng.choice(placement[t % n_blocks]) for t in range(n_tasks)]
        cnt = np.bincount(np.asarray(readers), minlength=n)
        collisions += int(np.sum(cnt * (cnt - 1) // 2))
    return collisions / trials


def uplink_slowdown(n_tasks: int, n_blocks: int, n: int, r: int,
                    seed: int = 0, trials: int = 500) -> float:
    """Expected max-readers-per-datanode (bandwidth division factor) when
    n_tasks concurrent tasks read n_blocks blocks."""
    rng = np.random.default_rng(seed)
    worst = 0.0
    for _ in range(trials):
        placement = [rng.choice(n, size=r, replace=False) for _ in range(n_blocks)]
        readers = [rng.choice(placement[t % n_blocks]) for t in range(n_tasks)]
        cnt = np.bincount(np.asarray(readers), minlength=n)
        worst += float(cnt.max())
    return worst / trials
