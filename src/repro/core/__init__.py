"""The paper's primary contribution: Heterogeneous MacroTasking (HeMT).

Submodules:
  estimators  — AR(1) executor speed estimation, fudge-factor probes (§5, §6.2)
  capacity    — token-bucket burstable capacity model, W(t) solver (§6.2)
  partitioner — HomT/HeMT integer partitioners (§4-§5)
  skewed_hash — Algorithm 1 skewed hash partitioner (§7)
  scheduler   — OA-HeMT / provisioned / burstable schedulers (§5-§6)
  straggler   — Claim 1 bound, detection, speculation, elastic re-skew
  speculation — straggler-mitigation policies (speculative copies, work
                stealing, barrier re-skew hand-off) for the engine
  hdfs_model  — Claim 2 storage-contention model (§3)
  simulator   — discrete-event cluster simulator (the paper's testbed)
  engine      — fast-path engine behind the simulator's stage runners
                (event calendar + vectorized closed forms)
  batched     — many-solve planner: the closed forms over [B, n] stacks
                (numpy scan + jax.vmap core, Monte-Carlo plan_capacity)
  planner     — HeMT-DP grain planner used by the training runtime
"""
from repro.core.estimators import (  # noqa: F401
    ARSpeedEstimator, FudgeFactorLearner, synchronization_delay,
)
from repro.core.capacity import (  # noqa: F401
    BurstableNode, TokenBucket, burstable_split, solve_finish_time,
)
from repro.core.partitioner import (  # noqa: F401
    even_split, hemt_split_floats, makespan, optimal_makespan,
    proportional_split,
)
from repro.core.skewed_hash import bucket_of, bucket_of_jnp, integer_capacities  # noqa: F401
from repro.core.engine import (  # noqa: F401
    AdaptivePlan, JobSchedule, PullSpec, StageSummary, StaticSpec, plan_path,
    run_job, run_job_cache_clear,
)
from repro.core.batched import (  # noqa: F401
    BatchResult, CapacityReport, batched_closed_pull,
    batched_closed_pull_hetero, batched_closed_static, dedup_rows,
    plan_capacity,
)
from repro.core.speculation import (  # noqa: F401
    ReskewHandoff, SpeculativeCopies, WorkStealing,
)
from repro.core.planner import GrainPlanner, SlicePlan, WorkStealingQueue  # noqa: F401
from repro.core.straggler import claim1_bound, detect_stragglers, verify_claim1  # noqa: F401
