"""Fast-path simulation engine behind ``run_pull_stage``/``run_static_stage``.

The legacy ``simulator._run_stage`` loop rescans every node at every event
(O(N·T)), pops the shared queue with O(T) ``list.pop(0)``, and re-walks each
node's speed profile from t=0 per task — quadratic exactly in the paper's own
regime (HomT sweeps at realistic microtask counts).  This module replaces it
on the hot path with two layers, keeping ``_run_stage`` as a reference oracle
for differential tests:

1. **Event calendar** (``run_stage_events``): a ``heapq`` of per-node
   completion events keyed ``(time, node_index, version)`` so tie-breaking
   matches the legacy lowest-index scan; ``collections.deque`` task queues
   (O(1) pops); a per-node :class:`ProfileCursor` making ``finish_time`` /
   ``work_between`` amortized O(1) under the engine's monotone query times;
   and incremental I/O flow repricing — when a datanode's reader set changes,
   only *that* datanode's readers have their remaining bytes checkpointed and
   their predicted finish re-pushed (stale heap entries are version-skipped).

2. **Vectorized closed forms** (no event loop at all) for the dominant
   special cases, auto-selected by :func:`simulate_stage`:

   * ``static`` assignment on constant-speed nodes with no effective I/O:
     per-node ``cumsum`` of ``overhead + work/speed`` (HeMT macrotasks);
   * ``pull`` with *uniform* tasks on constant-speed nodes with no effective
     I/O (the HomT microtask sweep): each node's pull times form the
     arithmetic grid ``j * (overhead_i + work/speed_i)``; the schedule is the
     T smallest grid points (ties by node index), found with a vectorized
     threshold search + ``np.lexsort`` — no per-task Python loop.

   "No effective I/O" means ``uplink_bw`` is None/0 (infinite rate — I/O can
   never delay a completion) or no task has ``datanode >= 0`` with positive
   ``io_mb``.  Anything else (multi-segment profiles, flow-shared I/O,
   heterogeneous pull tasks) takes the event calendar, which reproduces the
   oracle's completion times to float round-off (differential tests pin both
   paths to ``_run_stage`` at 1e-9).

Tie semantics: the one deliberate divergence from the oracle is simultaneous
I/O drains.  When two flows hit zero at the exact same instant, the legacy
loop re-candidates the non-owner at its (already past) ``cpu_done_at``,
records a completion *earlier than its I/O finish*, and then advances every
other flow by a negative time delta — inflating their remaining bytes and
cascading through the rest of the stage (visible in the seed's Fig-5 rows at
32/64 identical tasks).  The engine instead completes every task causally at
``max(io_finish, cpu_done)``.  Randomized differential tests draw continuous
task sizes, where exact ties have measure zero and the oracle is sound.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.simulator import (
    SimNode, SimTask, StageResult, TaskRecord, _stage_result,
)

_EPS = 1e-9


# --------------------------------------------------------------------------
# profile cursor
# --------------------------------------------------------------------------

class ProfileCursor:
    """Amortized O(1) speed-profile queries for nondecreasing times.

    The engine's event clock is monotone per node, so each profile segment is
    crossed once per stage instead of once per task.  The arithmetic mirrors
    ``SimNode.finish_time``/``work_between`` operation-for-operation, so the
    results are bit-identical to the legacy full walks.
    """

    __slots__ = ("segs", "k")

    def __init__(self, profile: Sequence[Tuple[float, float]]):
        self.segs: List[Tuple[float, float]] = list(profile) + [(math.inf, 0.0)]
        self.k = 0

    def _seek(self, t0: float) -> int:
        """Advance the cursor past segments ending at or before t0."""
        k, segs = self.k, self.segs
        while segs[k + 1][0] <= t0:
            k += 1
        self.k = k
        return k

    def finish_time(self, work: float, t0: float) -> float:
        """Earliest t with work_between(t0, t) >= work (t0 nondecreasing)."""
        if work <= 0:
            return t0
        segs = self.segs
        k = self._seek(t0)
        rem = work
        while True:
            s0, sp = segs[k]
            hi = segs[k + 1][0]
            lo = t0 if t0 > s0 else s0
            span = hi - lo
            if sp > 0 and rem <= sp * span:
                return lo + rem / sp
            rem -= sp * span
            if math.isinf(hi):
                if rem > 1e-12:
                    raise RuntimeError(f"node can never finish work={work}")
                return hi
            k += 1

    def work_between(self, t0: float, t1: float) -> float:
        """Integrate speed over [t0, t1] (t0 nondecreasing across calls)."""
        if t1 <= t0:
            return 0.0
        segs = self.segs
        k = self._seek(t0)
        total = 0.0
        while k < len(segs) - 1:
            s0, sp = segs[k]
            s1 = segs[k + 1][0]
            lo = max(t0, s0)
            hi = min(t1, s1)
            if hi > lo:
                total += sp * (hi - lo)
            if s1 >= t1:
                break
            k += 1
        return total


# --------------------------------------------------------------------------
# event-calendar core
# --------------------------------------------------------------------------

def run_stage_events(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
                     pull: bool, uplink_bw: Optional[float] = None,
                     start_time: float = 0.0) -> StageResult:
    """Event-calendar equivalent of the legacy ``_run_stage`` rescan loop.

    Semantics match the oracle: tasks pipeline I/O and CPU concurrently and
    complete when both are done; active readers of a datanode share
    ``uplink_bw`` equally; a falsy ``uplink_bw`` means infinite I/O rate.
    """
    n = len(nodes)
    shared = deque(queues[0]) if pull else None
    private = None if pull else [deque(q) for q in queues]
    cursors = [ProfileCursor(nd.profile) for nd in nodes]
    overheads = [nd.task_overhead for nd in nodes]
    bw = uplink_bw if uplink_bw else None   # falsy -> infinite rate -> no I/O

    task: List[Optional[SimTask]] = [None] * n
    t_started = [0.0] * n
    cpu_done = [0.0] * n
    io_left = [0.0] * n
    io_rate = [0.0] * n
    io_at = [0.0] * n                  # last checkpoint time of io_left
    reading = [-1] * n                 # datanode being read, -1 = none
    version = [0] * n                  # invalidates superseded heap entries

    readers: Dict[int, Set[int]] = {}  # datanode -> node indices mid-I/O
    heap: List[Tuple[float, int, int]] = []

    node_finish = {nd.name: start_time for nd in nodes}
    records: List[TaskRecord] = []

    def push(t: float, i: int) -> None:
        version[i] += 1
        heapq.heappush(heap, (t, i, version[i]))

    def reprice(d: int, now: float) -> None:
        """Datanode d's reader set changed: checkpoint each of *its* readers
        and re-predict their I/O finishes (the incremental update replacing
        the legacy every-event global rescan).  Readers found already drained
        (a co-reader finished the same instant) leave the flow and fall
        through to their CPU completion, as in the oracle."""
        rd = readers.get(d)
        if not rd:
            return
        drained = []
        for i in rd:
            left = io_left[i] - io_rate[i] * (now - io_at[i])
            io_left[i] = left if left > 0.0 else 0.0
            io_at[i] = now
            if io_left[i] <= _EPS:
                drained.append(i)
        for i in drained:
            rd.discard(i)
            reading[i] = -1
            # causal completion: never before the drain instant (the legacy
            # loop lets a tied drain complete retroactively at cpu_done_at
            # and then applies a negative advancement to every other flow —
            # see the "tie semantics" note in the module docstring)
            push(max(now, cpu_done[i]), i)
        if not rd:
            return
        rate = bw / len(rd)
        for i in rd:
            io_rate[i] = rate
            push(now + io_left[i] / rate, i)

    def start_task(i: int, tk: SimTask, now: float) -> None:
        launch = now + overheads[i]
        task[i] = tk
        t_started[i] = now
        cpu_done[i] = cursors[i].finish_time(tk.cpu_work, launch)
        if bw is not None and tk.datanode >= 0 and tk.io_mb > _EPS:
            io_left[i] = tk.io_mb
            io_at[i] = now
            io_rate[i] = 0.0
            reading[i] = tk.datanode
            readers.setdefault(tk.datanode, set()).add(i)
            reprice(tk.datanode, now)
        else:
            io_left[i] = 0.0
            push(cpu_done[i], i)

    def finish(i: int, now: float) -> None:
        tk = task[i]
        records.append(TaskRecord(tk.task_id, nodes[i].name,
                                  t_started[i], now, tk.cpu_work))
        node_finish[nodes[i].name] = now
        task[i] = None
        if pull:
            nxt = shared.popleft() if shared else None
        else:
            nxt = private[i].popleft() if private[i] else None
        if nxt is not None:
            start_task(i, nxt, now)

    for i in range(n):
        if pull:
            if shared:
                start_task(i, shared.popleft(), start_time)
        elif private[i]:
            start_task(i, private[i].popleft(), start_time)

    while heap:
        t, i, ver = heapq.heappop(heap)
        if ver != version[i] or task[i] is None:
            continue
        if reading[i] >= 0:
            # predicted I/O completion for node i
            d = reading[i]
            io_left[i] = 0.0
            reading[i] = -1
            readers[d].discard(i)
            reprice(d, t)
            if t + _EPS >= cpu_done[i]:
                finish(i, t)
            else:
                push(cpu_done[i], i)
        elif t + _EPS >= cpu_done[i]:
            finish(i, t)
        else:
            push(cpu_done[i], i)

    return _stage_result(records, node_finish, start_time)


# --------------------------------------------------------------------------
# closed-form fast paths
# --------------------------------------------------------------------------

def _constant_speeds(nodes: Sequence[SimNode]) -> Optional[List[float]]:
    """Per-node speed if every profile is single-segment positive, else None."""
    speeds = []
    for nd in nodes:
        if len(nd.profile) != 1 or nd.profile[0][1] <= 0.0:
            return None
        speeds.append(nd.profile[0][1])
    return speeds


def _io_active(tasks, uplink_bw: Optional[float]) -> bool:
    """True if any task's I/O can delay a completion (finite shared uplink)."""
    if not uplink_bw:
        return False
    return any(t.datanode >= 0 and t.io_mb > _EPS for t in tasks)


def _plan(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
          pull: bool, uplink_bw: Optional[float],
          ) -> Tuple[str, Optional[List[float]], Optional[np.ndarray]]:
    """Single-pass path selection: (path, speeds, pull work array)."""
    speeds = _constant_speeds(nodes)
    if speeds is None:
        return "event", None, None
    if pull:
        tasks = queues[0]
        if not tasks or _io_active(tasks, uplink_bw):
            return "event", speeds, None
        work = np.fromiter((t.cpu_work for t in tasks), np.float64,
                           count=len(tasks))
        if not (work == work[0]).all():
            return "event", speeds, None
        first = float(work[0])
        if any(nd.task_overhead + first / s <= 0.0
               for nd, s in zip(nodes, speeds)):
            return "event", speeds, None    # zero-cost tasks: degenerate grid
        return "closed-pull", speeds, work
    if any(_io_active(q, uplink_bw) for q in queues):
        return "event", speeds, None
    return "closed-static", speeds, None


def plan_path(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
              pull: bool, uplink_bw: Optional[float] = None) -> str:
    """Which execution path ``simulate_stage`` will take:
    'closed-pull' | 'closed-static' | 'event'."""
    return _plan(nodes, queues, pull, uplink_bw)[0]


def _closed_form_static(nodes: Sequence[SimNode], speeds: Sequence[float],
                        assignments: Sequence[Sequence[SimTask]],
                        start_time: float) -> StageResult:
    keyed: List[Tuple[float, int, TaskRecord]] = []
    node_finish = {}
    for i, nd in enumerate(nodes):
        q = assignments[i]
        if not q:
            node_finish[nd.name] = start_time
            continue
        work = np.fromiter((t.cpu_work for t in q), np.float64, count=len(q))
        ends = start_time + np.cumsum(nd.task_overhead + work / speeds[i])
        starts = np.empty_like(ends)
        starts[0] = start_time
        starts[1:] = ends[:-1]
        node_finish[nd.name] = float(ends[-1])
        ends_l, starts_l, name = ends.tolist(), starts.tolist(), nd.name
        keyed.extend(
            (ends_l[j], i, TaskRecord(t.task_id, name, starts_l[j],
                                      ends_l[j], t.cpu_work))
            for j, t in enumerate(q))
    keyed.sort(key=lambda e: (e[0], e[1]))   # oracle order: (time, node idx)
    return _stage_result([r for _, _, r in keyed], node_finish, start_time)


def _closed_form_pull_uniform(nodes: Sequence[SimNode], speeds: Sequence[float],
                              tasks: Sequence[SimTask], work: float,
                              start_time: float) -> StageResult:
    n, n_tasks = len(nodes), len(tasks)
    periods = np.asarray([nd.task_overhead + work / s
                          for nd, s in zip(nodes, speeds)])
    # Node i is free to pull at grid times j * periods[i]; the schedule is the
    # n_tasks smallest grid points, ties resolved by node index (the oracle's
    # lowest-index scan).  Bisect a threshold so we only materialize ~n_tasks
    # candidates before the lexsort.
    lo, hi = 0.0, float(periods.min()) * (n_tasks + 1)
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if int(np.floor(mid / periods).sum()) + n >= n_tasks:
            hi = mid
        else:
            lo = mid
    per_node = np.minimum(np.floor(hi / periods).astype(np.int64) + 2, n_tasks)
    node_idx = np.repeat(np.arange(n), per_node)
    seq = np.concatenate([np.arange(c) for c in per_node])
    times = seq * periods[node_idx]
    order = np.lexsort((node_idx, times))[:n_tasks]

    pull_node = node_idx[order]
    pull_seq = seq[order]
    starts = start_time + times[order]
    ends = start_time + (pull_seq + 1) * periods[pull_node]
    counts = np.bincount(pull_node, minlength=n)

    completion_order = np.lexsort((pull_node, ends)).tolist()
    names = [nd.name for nd in nodes]
    pn, starts_l, ends_l = pull_node.tolist(), starts.tolist(), ends.tolist()
    records = [TaskRecord(tasks[m].task_id, names[pn[m]],
                          starts_l[m], ends_l[m], work)
               for m in completion_order]
    node_finish = {
        nd.name: (start_time + float(counts[i] * periods[i])
                  if counts[i] else start_time)
        for i, nd in enumerate(nodes)}
    return _stage_result(records, node_finish, start_time)


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def simulate_stage(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
                   pull: bool, uplink_bw: Optional[float] = None,
                   start_time: float = 0.0) -> StageResult:
    """Run one stage on the fastest applicable path (see module docstring)."""
    path, speeds, work = _plan(nodes, queues, pull, uplink_bw)
    if path == "closed-pull":
        return _closed_form_pull_uniform(nodes, speeds, queues[0],
                                         float(work[0]), start_time)
    if path == "closed-static":
        return _closed_form_static(nodes, speeds, queues, start_time)
    return run_stage_events(nodes, queues, pull, uplink_bw, start_time)
