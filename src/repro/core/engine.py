"""Fast-path simulation engine behind ``run_pull_stage``/``run_static_stage``.

The legacy ``simulator._run_stage`` loop rescans every node at every event
(O(N·T)), pops the shared queue with O(T) ``list.pop(0)``, and re-walks each
node's speed profile from t=0 per task — quadratic exactly in the paper's own
regime (HomT sweeps at realistic microtask counts).  This module replaces it
on the hot path with two layers, keeping ``_run_stage`` as a reference oracle
for differential tests:

1. **Event calendar** (``run_stage_events``): a ``heapq`` of per-node
   completion events keyed ``(time, node_index, version)`` so tie-breaking
   matches the legacy lowest-index scan; ``collections.deque`` task queues
   (O(1) pops); a per-node :class:`ProfileCursor` making ``finish_time`` /
   ``work_between`` amortized O(1) under the engine's monotone query times;
   and incremental I/O flow repricing — when a datanode's reader set changes,
   only *that* datanode's readers have their remaining bytes checkpointed and
   their predicted finish re-pushed (stale heap entries are version-skipped).

2. **Closed forms** (no event loop at all) for the dominant special
   cases, auto-selected by :func:`simulate_stage` via :func:`plan_path`.
   With T tasks over n nodes the selection table is (first match wins):

   ====================================  =====================  ==============
   input shape                           chosen path            complexity
   ====================================  =====================  ==============
   fault-injected stage (``faults=``)    ``event``              O(T log n)
   any multi-segment speed profile       ``event``              O(T log n)
   static, const speeds, no eff. I/O     ``closed-static``      O(T) numpy
   pull, uniform tasks, no eff. I/O,     ``closed-pull``        O(T) numpy
   positive per-pull period
   pull, heterogeneous tasks (or zero    ``closed-pull-hetero`` O(T log n)
   period), no eff. I/O                                         tight merge
   pull, equal ``io_mb`` > 0, striped    ``closed-pull-io-sym`` O(T) numpy
   round-robin over d | n datanodes,
   network-governed rounds
   anything else (flow-shared I/O)       ``event``              O(T log n)
   ====================================  =====================  ==============

   * ``closed-static``: per-node ``cumsum`` of ``overhead + work/speed``
     (HeMT macrotasks);
   * ``closed-pull``: each node's pull times form the arithmetic grid
     ``j * (overhead_i + work/speed_i)``; the schedule is the T smallest
     grid points (ties by node index), found with a vectorized threshold
     search + ``np.lexsort``;
   * ``closed-pull-hetero``: the merged-grid scan — each node's end times
     are a prefix sum over its assigned works, and the FIFO queue hands task
     k to the node owning the k-th smallest end event, so a single
     ``heapreplace`` pass over the n per-node grid heads reproduces the
     event calendar exactly with none of its per-event bookkeeping;
   * ``closed-pull-io-sym``: every task reads the same ``io_mb``, task k
     from datanode ``dns[k % d]`` (round-robin stripe over d distinct
     datanodes with ``d | n``; d = 1 is the single-datanode case), and CPU
     never governs (``overhead + work/speed <= round I/O time`` for every
     assignment), so the flow-sharing schedule is piecewise linear: in a
     full round each datanode serves exactly ``n / d`` co-readers and all
     n drain simultaneously after ``io_mb / (uplink_bw / (n/d))``; the
     tail round's datanode groups (``c_j`` readers each) drain
     independently after ``io_mb / (uplink_bw / c_j)``.

   "No effective I/O" means ``uplink_bw`` is None/0 (infinite rate — I/O can
   never delay a completion) or no task has ``datanode >= 0`` with positive
   ``io_mb``.  Anything else takes the event calendar, which reproduces the
   oracle's completion times to float round-off (differential tests pin both
   paths to ``_run_stage`` at 1e-9).  A fault-injected stage (a non-empty
   ``faults=`` :class:`~repro.core.faults.FaultTrace`) always routes to the
   event calendar: kills, drains and recoveries are point events the closed
   forms cannot express.

3. **Whole jobs** (:func:`run_job`): an S-stage sequence of
   :class:`PullSpec`/:class:`StaticSpec` stages separated by program
   barriers.  On constant-speed clusters every stage schedule is
   start-invariant, so each *distinct* spec is solved once (record-free
   summaries — no ``TaskRecord`` objects) and repeated stages are O(n)
   shifts of the cached per-node finish vector: an S-stage HomT/HeMT job
   costs O(S·n) after the one-time per-spec solve instead of
   O(S·T log n).  Solves are additionally shared *across* ``run_job``
   calls through a module-level LRU keyed on (cluster signature,
   uplink_bw, spec) — repeated benchmark invocations and the adaptive
   schedulers reuse each other's solves (``run_job_cache_clear`` resets
   it).  Non-constant clusters fall back to per-stage ``simulate_stage``
   at the true absolute start times.

4. **Straggler mitigation** (``repro.core.speculation``): the event
   calendar accepts ``mitigation=`` — a :class:`SpeculativeCopies`
   (quantile-triggered duplicate launch, first finisher wins, loser
   cancelled) or :class:`WorkStealing` (idle node steals the remainder of
   the most-backlogged attempt at a grain boundary) policy — adding task
   cancel / re-launch / idle-recheck events on top of the completion
   calendar.  ``PullSpec``/``StaticSpec`` carry a ``mitigation`` field so
   ``run_job`` threads policies through whole jobs (mitigated stages are
   solved on the event path; they stay start-invariant on constant-speed
   clusters, so the solve caches still apply).  Barrier-level
   :class:`ReskewHandoff` is applied by ``run_job`` itself: stragglers of
   a static stage are cut at ``cutoff_factor * median`` finish and their
   residual work is folded into the next stage's split.  Stages with
   effective I/O are mitigated too: a speculative copy or stolen
   remainder re-fetches its input as a *new flow* through the
   flow-shared uplink (placement chosen by
   :class:`~repro.core.hdfs_model.DuplicatePlacement` — same datanode or
   the ring-adjacent replica), joining the incremental per-datanode
   repricing; cancelling the loser frees its flow and reprices the
   survivors causally at that instant, never retroactively.  Exact
   event semantics live in the ``speculation`` module docstring;
   differential tests pin the engine against naive per-event oracles
   (tests/test_speculation.py, tests/test_speculation_io.py).

5. **Fault injection** (``repro.core.faults``): every layer accepts
   ``faults=`` — a :class:`~repro.core.faults.FaultTrace` of
   :class:`~repro.core.faults.NodeCrash` / :class:`~repro.core.faults.
   SpotPreemption` events with a :class:`~repro.core.faults.RetryPolicy`
   and optional grain-boundary checkpointing.  ``run_stage_events`` kills
   the victim's in-flight attempt (its uplink flow freed through the same
   causal ``drop_flow`` repricing losers use), re-queues the residual per
   the retry policy, and composes with speculation (a surviving copy
   becomes the primary attempt).  ``run_job`` keeps the solve caches
   honest — see the run_job docstring — because faults break
   start-invariance.  Exact semantics live in the ``faults`` module
   docstring, pinned by the naive full-rescan fault oracle in
   tests/test_faults.py.

6. **Online adaptation** (:class:`AdaptivePlan`): the paper's full §5
   OA-HeMT loop at ``run_job`` scale.  ``run_job(..., adaptive=plan)``
   feeds every stage's observed per-node (executed work, busy time) into
   the plan's :class:`~repro.core.estimators.ARSpeedEstimator` at the
   stage's barrier, and re-derives each upcoming ``StaticSpec``'s split
   proportions from the updated speed estimates (``d_i = D v_i / V``)
   before it is solved.  Composition with barrier-level
   :class:`~repro.core.speculation.ReskewHandoff` is exact: a cut stage's
   residual is first folded into the next stage's planned works and the
   re-plan then re-splits the *combined* total — both the split and the
   residual are re-skewed by the freshest estimates.  Solve-cache
   correctness needs no estimator state in the cache keys: a re-planned
   stage is a *new* ``StaticSpec`` value whose works tuple is a pure
   function of the estimator state, and the caches key solves by spec
   value — two adaptive stages collide in the LRU only when their splits
   (and therefore their solves) are identical.  ``PullSpec`` stages pass
   through un-replanned (the shared queue self-balances at run time) but
   still feed the estimator.

Tie semantics: the one deliberate divergence from the oracle is simultaneous
I/O drains.  When two flows hit zero at the exact same instant, the legacy
loop re-candidates the non-owner at its (already past) ``cpu_done_at``,
records a completion *earlier than its I/O finish*, and then advances every
other flow by a negative time delta — inflating their remaining bytes and
cascading through the rest of the stage (visible in the seed's Fig-5 rows at
32/64 identical tasks).  The engine instead completes every task causally at
``max(io_finish, cpu_done)``.  Randomized differential tests draw continuous
task sizes, where exact ties have measure zero and the oracle is sound.

Enforced contracts (machine-checked by ``python -m repro.analysis.lint``,
the ``hemt-lint`` CI job, and the tier-1 self-check in
tests/test_analysis.py — rule table in the README "Static analysis"
section): stage specs and everything reachable from them stay frozen and
hashable because the solve LRU and ``batched.dedup_rows`` key by value
(HL001); solver code never reads the wall clock or unseeded RNG — the
1e-9 differential oracles depend on it (HL002/HL003); float ``==`` in
solver modules is either a documented exact-routing guard or a bug
(HL004); the jax twins stay tracer-safe for the Pallas port (HL005); and
closed-form solvers never mutate parameter arrays, because cached solves
are replayed (HL006).
"""
from __future__ import annotations

import heapq
import math
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.estimators import ARSpeedEstimator
from repro.core.faults import ALIVE, DEAD, DRAINING, FaultTrace, lost_work
from repro.core.partitioner import hemt_split_floats, proportional_split
from repro.core.simulator import (
    SimNode, SimTask, StageColumns, StageResult, TaskRecord, _stage_result,
    _stage_result_columns,
)
from repro.core.speculation import (
    ReskewHandoff, RunningAttempt, Speculate, fold_residual, is_event_policy,
)

_EPS = 1e-9


# --------------------------------------------------------------------------
# profile cursor
# --------------------------------------------------------------------------

class ProfileCursor:
    """Amortized O(1) speed-profile queries for nondecreasing times.

    The engine's event clock is monotone per node, so each profile segment is
    crossed once per stage instead of once per task.  The arithmetic mirrors
    ``SimNode.finish_time``/``work_between`` operation-for-operation, so the
    results are bit-identical to the legacy full walks.
    """

    __slots__ = ("segs", "k")

    def __init__(self, profile: Sequence[Tuple[float, float]]):
        self.segs: List[Tuple[float, float]] = list(profile) + [(math.inf, 0.0)]
        self.k = 0

    def _seek(self, t0: float) -> int:
        """Advance the cursor past segments ending at or before t0."""
        k, segs = self.k, self.segs
        while segs[k + 1][0] <= t0:
            k += 1
        self.k = k
        return k

    def finish_time(self, work: float, t0: float) -> float:
        """Earliest t with work_between(t0, t) >= work (t0 nondecreasing)."""
        if work <= 0:
            return t0
        segs = self.segs
        k = self._seek(t0)
        rem = work
        while True:
            s0, sp = segs[k]
            hi = segs[k + 1][0]
            lo = t0 if t0 > s0 else s0
            span = hi - lo
            if sp > 0 and rem <= sp * span:
                return lo + rem / sp
            rem -= sp * span
            if math.isinf(hi):
                if rem > 1e-12:
                    raise RuntimeError(f"node can never finish work={work}")
                return hi
            k += 1

    def work_between(self, t0: float, t1: float) -> float:
        """Integrate speed over [t0, t1] (t0 nondecreasing across calls)."""
        if t1 <= t0:
            return 0.0
        segs = self.segs
        k = self._seek(t0)
        total = 0.0
        while k < len(segs) - 1:
            s0, sp = segs[k]
            s1 = segs[k + 1][0]
            lo = max(t0, s0)
            hi = min(t1, s1)
            if hi > lo:
                total += sp * (hi - lo)
            if s1 >= t1:
                break
            k += 1
        return total


# --------------------------------------------------------------------------
# event-calendar core
# --------------------------------------------------------------------------

def run_stage_events(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
                     pull: bool, uplink_bw: Optional[float] = None,
                     start_time: float = 0.0,
                     mitigation=None, faults: Optional[FaultTrace] = None,
                     ) -> StageResult:
    """Event-calendar equivalent of the legacy ``_run_stage`` rescan loop.

    Semantics match the oracle: tasks pipeline I/O and CPU concurrently and
    complete when both are done; active readers of a datanode share
    ``uplink_bw`` equally; a falsy ``uplink_bw`` means infinite I/O rate.

    ``mitigation`` is an event-level straggler policy
    (:class:`~repro.core.speculation.SpeculativeCopies` or
    :class:`~repro.core.speculation.WorkStealing`); it adds cancel,
    re-launch, and idle-recheck events on top of the completion calendar.
    Exact semantics (offer instants, fixpoint order, tie resolution, steal
    granularity) are specified in the ``repro.core.speculation`` module
    docstring and pinned by the differential oracles in
    tests/test_speculation.py and tests/test_speculation_io.py.  On stages
    with effective I/O a duplicate launch (speculative copy / stolen
    remainder) re-fetches its input as a *new flow* through the same
    per-datanode repricing primary readers use; cancelling the loser frees
    its flow and reprices the survivors causally at that instant.  A node
    whose only attempts were cancelled produces no record and keeps its
    previous ``node_finish`` (it completed nothing).

    ``faults`` injects a :class:`~repro.core.faults.FaultTrace`: kill /
    drain / recover sub-events ride the same heap as point events ordered
    *before* any same-instant completion of the same node, a kill frees
    the victim's flow through ``drop_flow`` and re-queues the residual per
    the trace's retry policy, and a surviving speculative copy becomes the
    primary attempt.  Exact semantics (checkpoint flooring, re-queue
    destinations, retry accounting, tie rules) are specified in the
    ``repro.core.faults`` module docstring and pinned by the naive
    full-rescan fault oracle in tests/test_faults.py.
    """
    n = len(nodes)
    shared = deque(queues[0]) if pull else None
    private = None if pull else [deque(q) for q in queues]
    cursors = [ProfileCursor(nd.profile) for nd in nodes]
    overheads = [nd.task_overhead for nd in nodes]
    bw = uplink_bw if uplink_bw else None   # falsy -> infinite rate -> no I/O

    if mitigation is not None:
        if not is_event_policy(mitigation):
            raise ValueError(
                f"{type(mitigation).__name__} is not an event-level policy "
                "(barrier-level ReskewHandoff applies through run_job)")
        pl = getattr(mitigation, "placement", None)
        if pl is not None and pl.policy == "replica" and bw is not None:
            top = max((t.datanode for q in queues for t in q), default=-1)
            if top >= pl.n_datanodes:
                raise ValueError(
                    f"replica placement ring (n_datanodes="
                    f"{pl.n_datanodes}) does not cover datanode {top}")

    task: List[Optional[SimTask]] = [None] * n
    t_started = [0.0] * n
    launch_at = [0.0] * n              # when the attempt's CPU work begins
    attempt_work = [0.0] * n           # work of the current attempt
    attempt_io = [0.0] * n             # input bytes of the current attempt
    #                                    (0 when I/O is not effective)
    cpu_done = [0.0] * n
    io_left = [0.0] * n
    io_rate = [0.0] * n
    io_at = [0.0] * n                  # last checkpoint time of io_left
    reading = [-1] * n                 # datanode being read, -1 = none
    version = [0] * n                  # invalidates superseded heap entries
    twin = [-1] * n                    # node running the other copy, -1=none
    copied: Set[int] = set()           # task_ids ever speculatively copied
    done_durations: List[float] = []   # completed attempt durations

    readers: Dict[int, Set[int]] = {}  # datanode -> node indices mid-I/O
    heap: List[Tuple[float, int, int]] = []

    node_finish = {nd.name: start_time for nd in nodes}
    records: List[TaskRecord] = []

    # ---- fault state (repro.core.faults semantics) -----------------------
    if faults is not None and not faults.events:
        faults = None
    dead = [False] * n
    draining = [False] * n
    requeues: Dict[int, int] = {}      # task_id -> kill-requeues so far
    penalty: Dict[int, float] = {}     # task_id -> pending relaunch penalty
    fevents: List[Tuple[float, int, str]] = []
    if faults is not None:
        if faults.max_node() >= n:
            raise ValueError(
                f"fault trace names node {faults.max_node()} but the stage "
                f"has {n} nodes")
        for i in range(n):
            st = faults.state_at(i, start_time)
            dead[i] = st == DEAD
            draining[i] = st == DRAINING
        fevents = faults.sub_events(start_time)

    def push(t: float, i: int) -> None:
        version[i] += 1
        heapq.heappush(heap, (t, i, version[i]))

    def reprice(d: int, now: float) -> None:
        """Datanode d's reader set changed: checkpoint each of *its* readers
        and re-predict their I/O finishes (the incremental update replacing
        the legacy every-event global rescan).  Readers found already drained
        (a co-reader finished the same instant) leave the flow and fall
        through to their CPU completion, as in the oracle."""
        rd = readers.get(d)
        if not rd:
            return
        drained = []
        for i in rd:
            left = io_left[i] - io_rate[i] * (now - io_at[i])
            io_left[i] = left if left > 0.0 else 0.0
            io_at[i] = now
            if io_left[i] <= _EPS:
                drained.append(i)
        for i in drained:
            rd.discard(i)
            reading[i] = -1
            # causal completion: never before the drain instant (the legacy
            # loop lets a tied drain complete retroactively at cpu_done_at
            # and then applies a negative advancement to every other flow —
            # see the "tie semantics" note in the module docstring)
            push(max(now, cpu_done[i]), i)
        if not rd:
            return
        rate = bw / len(rd)
        for i in rd:
            io_rate[i] = rate
            push(now + io_left[i] / rate, i)

    def start_task(i: int, tk: SimTask, now: float) -> None:
        # a re-queued task's pending relaunch penalty (RetryPolicy backoff)
        # is consumed at its next launch, wherever it lands
        launch = now + overheads[i] + penalty.pop(tk.task_id, 0.0)
        task[i] = tk
        t_started[i] = now
        launch_at[i] = launch
        attempt_work[i] = tk.cpu_work
        cpu_done[i] = cursors[i].finish_time(tk.cpu_work, launch)
        if bw is not None and tk.datanode >= 0 and tk.io_mb > _EPS:
            attempt_io[i] = tk.io_mb
            io_left[i] = tk.io_mb
            io_at[i] = now
            io_rate[i] = 0.0
            reading[i] = tk.datanode
            readers.setdefault(tk.datanode, set()).add(i)
            reprice(tk.datanode, now)
        else:
            attempt_io[i] = 0.0
            io_left[i] = 0.0
            push(cpu_done[i], i)

    def drop_flow(i: int, now: float) -> None:
        """Node i's in-flight flow ends early (cancelled loser / steal
        drained the victim's remaining range): it leaves its datanode's
        reader set and the survivors are repriced causally at ``now`` —
        never retroactively."""
        d = reading[i]
        if d < 0:
            return
        reading[i] = -1
        io_left[i] = 0.0
        readers[d].discard(i)
        reprice(d, now)

    def refill(i: int, now: float) -> None:
        if dead[i] or draining[i]:
            return                     # dead/draining nodes pull nothing new
        if pull:
            nxt = shared.popleft() if shared else None
        else:
            nxt = private[i].popleft() if private[i] else None
        if nxt is not None:
            start_task(i, nxt, now)

    def finish(i: int, now: float) -> None:
        tk = task[i]
        records.append(TaskRecord(tk.task_id, nodes[i].name,
                                  t_started[i], now, attempt_work[i]))
        node_finish[nodes[i].name] = now
        task[i] = None
        loser = -1
        if mitigation is not None:
            done_durations.append(now - t_started[i])
            loser = twin[i]
            if loser >= 0:
                # first finisher wins: cancel the racing copy (no record,
                # no node_finish update — it completed nothing); its
                # in-flight flow is freed and the survivors repriced at
                # this instant
                twin[i] = twin[loser] = -1
                task[loser] = None
                version[loser] += 1   # drop its pending completion event
                drop_flow(loser, now)
        refill(i, now)
        if loser >= 0:
            refill(loser, now)

    def remaining_work(k: int, now: float) -> float:
        """Work of node k's attempt not yet executed at ``now`` (full work
        while still inside the overhead window)."""
        if now < launch_at[k]:
            return attempt_work[k]
        return cursors[k].work_between(now, cpu_done[k])

    # ---- fault handlers (repro.core.faults semantics) --------------------
    def wake_idle(now: float) -> None:
        """Hand queued work to idle usable nodes, ascending index (after a
        kill re-queued work or a recovery brought capacity back)."""
        for k in range(n):
            if task[k] is None:
                refill(k, now)

    def real_task(tk: SimTask) -> bool:
        """Zero-work, zero-byte tasks (an adaptive alive-masked replan
        parks them on dead nodes) are never worth waiting a recovery out
        for — they redistribute immediately instead of serializing the
        stage on a no-op."""
        return tk.cpu_work > _EPS or tk.io_mb > _EPS

    def requeue_task(tk: SimTask, victim: int, now: float) -> None:
        """Queue a task whose node died: pull goes to the back of the
        shared deque; a static victim that recovers later re-executes it on
        recovery (front of its own queue); otherwise the least-loaded alive
        non-draining node takes it (remaining attempt work + queued work,
        ties to the lowest index), falling back to the earliest-recovering
        dead node.  No candidate at all: the work is stranded."""
        if pull:
            shared.append(tk)
            return
        if faults.recovery_after(victim, now) is not None and real_task(tk):
            private[victim].appendleft(tk)
            return
        best, best_load = -1, math.inf
        for j in range(n):
            if dead[j] or draining[j]:
                continue
            load = (remaining_work(j, now) if task[j] is not None else 0.0) \
                + sum(q.cpu_work for q in private[j])
            if load < best_load:
                best, best_load = j, load
        if best < 0:
            best_rec = math.inf
            for j in range(n):
                rec = faults.recovery_after(j, now)
                if rec is not None and rec < best_rec:
                    best, best_rec = j, rec
        if best >= 0:
            private[best].append(tk)

    def shed_queue(i: int, now: float) -> None:
        """A dead static node's private queue: real tasks wait out a
        future recovery (none scheduled: all redistribute); zero-work
        zero-byte tasks redistribute immediately either way."""
        if pull or not private[i]:
            return
        if faults.recovery_after(i, now) is None:
            while private[i]:
                requeue_task(private[i].popleft(), i, now)
            return
        movers = [tk for tk in private[i] if not real_task(tk)]
        if movers:
            stay = [tk for tk in private[i] if real_task(tk)]
            private[i].clear()
            private[i].extend(stay)
            for tk in movers:
                requeue_task(tk, i, now)

    def fault_kill(i: int, now: float) -> None:
        dead[i] = True
        draining[i] = False
        tk = task[i]
        if tk is not None:
            executed = attempt_work[i] - remaining_work(i, now)
            saved = 0.0
            g = faults.checkpoint_grain
            if g > 0.0 and executed > 0.0:
                saved = min(math.floor((executed + _EPS) / g) * g,
                            attempt_work[i])
            if saved > _EPS:
                # grain-boundary checkpoint: the saved prefix survives as a
                # partial record ending at the kill instant
                records.append(TaskRecord(tk.task_id, nodes[i].name,
                                          t_started[i], now, saved))
                node_finish[nodes[i].name] = now
            surviving_copy = twin[i]
            task[i] = None
            version[i] += 1            # drop the pending completion event
            drop_flow(i, now)          # free the flow, reprice survivors
            if surviving_copy >= 0:
                # the racing copy outlives its victim and becomes the
                # task's only attempt: nothing re-queues, no retry charged
                twin[i] = twin[surviving_copy] = -1
            else:
                rem = attempt_work[i] - saved
                if rem > _EPS:
                    k = requeues.get(tk.task_id, 0)
                    if k < faults.retry.max_attempts - 1:
                        requeues[tk.task_id] = k + 1
                        pen = faults.retry.penalty(k + 1)
                        if pen > 0.0:
                            penalty[tk.task_id] = pen
                        # a restart re-fetches input proportional to the
                        # work it still has to do
                        if attempt_io[i] > _EPS and attempt_work[i] > _EPS:
                            io = attempt_io[i] * rem / attempt_work[i]
                        else:
                            io = 0.0
                        requeue_task(
                            SimTask(rem, io, tk.datanode if io > _EPS else -1,
                                    task_id=tk.task_id), i, now)
                    # else: retries exhausted — the residual is abandoned
        shed_queue(i, now)

    def offer_mitigation(now: float) -> None:
        """Fixpoint mitigation sweep (speculation-module semantics): offer
        idle nodes in ascending index; restart after each accepted action;
        schedule idle rechecks once no action is taken."""
        placement = getattr(mitigation, "placement", None)

        def dup_datanode(d: int) -> int:
            return d if placement is None else placement.choose(d)

        while True:
            running = [RunningAttempt(k, task[k].task_id, t_started[k],
                                      attempt_work[k],
                                      remaining_work(k, now),
                                      task[k].task_id in copied,
                                      attempt_io[k])
                       for k in range(n) if task[k] is not None]
            if not running:
                return
            by_node = {r.node: r for r in running}
            acted = False
            for k in range(n):
                if task[k] is not None or dead[k] or draining[k]:
                    continue          # mitigation never offers a dead or
                    #                   draining node new work
                if shared if pull else private[k]:
                    continue          # not idle: work still queued
                act = mitigation.offer(done_durations, running, now)
                if act is None:
                    continue
                victim = by_node[act.victim]
                vt = task[act.victim]
                if isinstance(act, Speculate):
                    # duplicate launch: full original work, from scratch;
                    # with effective I/O the copy re-fetches the full
                    # input as a new flow from the placement-chosen
                    # datanode (start_task joins it to the reader set and
                    # reprices that uplink)
                    copied.add(vt.task_id)
                    start_task(k, SimTask(vt.cpu_work, vt.io_mb,
                                          dup_datanode(vt.datanode),
                                          task_id=vt.task_id), now)
                    twin[k] = act.victim
                    twin[act.victim] = k
                else:                 # Steal: shrink the victim in place
                    v = act.victim
                    moved = 0.0       # input bytes of the stolen range
                    if attempt_io[v] > _EPS and victim.work > 0.0:
                        moved = attempt_io[v] * act.amount / victim.work
                        attempt_io[v] -= moved
                    attempt_work[v] -= act.amount
                    t0 = max(now, launch_at[v])
                    cpu_done[v] = cursors[v].finish_time(
                        victim.remaining - act.amount, t0)
                    if reading[v] >= 0 and moved > 0.0:
                        # the victim stops fetching the stolen range:
                        # checkpoint its flow at the steal instant, drop
                        # the moved bytes (clamped — bytes it already
                        # streamed are not refunded)
                        left = io_left[v] - io_rate[v] * (now - io_at[v])
                        io_left[v] = max(0.0, max(left, 0.0) - moved)
                        io_at[v] = now
                        if io_left[v] <= _EPS:
                            drop_flow(v, now)
                        else:
                            push(now + io_left[v] / io_rate[v], v)
                    if reading[v] < 0:
                        push(cpu_done[v], v)
                    start_task(k, SimTask(act.amount, moved,
                                          dup_datanode(vt.datanode)
                                          if moved > _EPS else -1,
                                          task_id=vt.task_id), now)
                acted = True
                break                 # state changed: restart the sweep
            if not acted:
                for k in range(n):
                    if (task[k] is not None or dead[k] or draining[k]
                            or (shared if pull else private[k])):
                        continue
                    nc = mitigation.next_check(done_durations, running, now)
                    if nc is not None:
                        push(nc, k)   # idle recheck event
                return

    for i in range(n):
        if dead[i] or draining[i]:
            continue                   # not primed: pulls nothing at start
        if pull:
            if shared:
                start_task(i, shared.popleft(), start_time)
        elif private[i]:
            start_task(i, private[i].popleft(), start_time)
    if faults is not None:
        if not pull:
            # nodes dead at the start shed what should not wait for them
            # (everything without a future recovery; no-op tasks always)
            for i in range(n):
                if dead[i]:
                    shed_queue(i, start_time)
            wake_idle(start_time)
        # fault sub-events ride the heap with negative versions: they
        # bypass the version-skip, order before any same-instant completion
        # of the same node, and keep the trace's (t, node, rank) order
        # among themselves
        nf = len(fevents)
        for idx, (ft, fnode, _) in enumerate(fevents):
            heapq.heappush(heap, (ft, fnode, idx - nf))
    if mitigation is not None:
        offer_mitigation(start_time)

    while heap:
        t, i, ver = heapq.heappop(heap)
        if ver < 0:
            kind = fevents[ver + len(fevents)][2]
            if kind == "kill":
                fault_kill(i, t)
                wake_idle(t)           # re-queued work may land on idlers
            elif kind == "drain":
                draining[i] = True
            else:                      # recover
                dead[i] = False
                wake_idle(t)
            if mitigation is not None:
                offer_mitigation(t)
            continue
        if ver != version[i]:
            continue
        if task[i] is None:
            if mitigation is not None:
                offer_mitigation(t)   # idle recheck
            continue
        if reading[i] >= 0:
            # predicted I/O completion for node i
            d = reading[i]
            io_left[i] = 0.0
            reading[i] = -1
            readers[d].discard(i)
            reprice(d, t)
            if t + _EPS >= cpu_done[i]:
                finish(i, t)
                if mitigation is not None:
                    offer_mitigation(t)
            else:
                push(cpu_done[i], i)
        elif t + _EPS >= cpu_done[i]:
            finish(i, t)
            if mitigation is not None:
                offer_mitigation(t)
        else:
            push(cpu_done[i], i)

    return _stage_result(records, node_finish, start_time)


# --------------------------------------------------------------------------
# closed-form fast paths
# --------------------------------------------------------------------------

def _constant_speeds(nodes: Sequence[SimNode]) -> Optional[List[float]]:
    """Per-node speed if every profile is single-segment positive, else None."""
    speeds = []
    for nd in nodes:
        if len(nd.profile) != 1 or nd.profile[0][1] <= 0.0:
            return None
        speeds.append(nd.profile[0][1])
    return speeds


def _io_active(tasks, uplink_bw: Optional[float]) -> bool:
    """True if any task's I/O can delay a completion (finite shared uplink)."""
    if not uplink_bw:
        return False
    return any(t.datanode >= 0 and t.io_mb > _EPS for t in tasks)


def _io_sym_spans_ok(oh: np.ndarray, sp: np.ndarray, work: np.ndarray,
                     io_mb: float, uplink_bw: float, n: int,
                     d: int = 1) -> bool:
    """Network-governed check for the symmetric co-reader closed form: task
    k lands on node ``k % n`` in round ``k // n``; its CPU span must fit
    inside that round's shared-drain time so every round stays a
    simultaneous drain.  ``d`` is the datanode stripe width (``d | n``):
    a full round puts ``n / d`` readers on each datanode; the tail round's
    datanode group j has ``c_j = |{i < q : i % d == j}|`` readers draining
    independently."""
    n_tasks = len(work)
    full_rounds, q = divmod(n_tasks, n)
    idx = np.arange(n_tasks) % n
    spans = oh[idx] + work / sp[idx]
    durations = np.full(n_tasks, io_mb / (uplink_bw / (n // d)))
    if q:
        cj = np.bincount(np.arange(q) % d, minlength=d)
        durations[full_rounds * n:] = \
            io_mb / (uplink_bw / cj[np.arange(q) % d])
    return bool((spans <= durations).all())


def _stripe_width(tasks: Sequence[SimTask], n: int) -> int:
    """Datanode stripe width d >= 1 of a symmetric pull queue: every task
    reads the same positive ``io_mb``, task k from ``dns[k % d]`` where
    ``dns`` is d distinct datanodes and ``d | n`` (so every full round
    loads each datanode with exactly ``n / d`` readers).  0 if the queue
    has no such structure (different io_mb, aperiodic datanodes, d not
    dividing n)."""
    d0, m = tasks[0].datanode, tasks[0].io_mb
    if d0 < 0 or m <= _EPS:
        return 0
    dns = [d0]
    for t in tasks[1:]:
        if t.datanode == d0:
            break
        dns.append(t.datanode)
    d = len(dns)
    if d > n or n % d or len(set(dns)) != d or any(x < 0 for x in dns):
        return 0
    for k, t in enumerate(tasks):
        # exact-routing guard: any io_mb inequality (even 1 ulp) just
        # falls back to the event path, never to a wrong closed form
        if t.datanode != dns[k % d] or t.io_mb != m:  # hemt-lint: disable=HL004
            return 0
    return d


def _io_symmetric(nodes: Sequence[SimNode], speeds: Sequence[float],
                  tasks: Sequence[SimTask], work: np.ndarray,
                  uplink_bw: Optional[float]) -> int:
    """Stripe width d >= 1 if the stage qualifies for
    ``closed-pull-io-sym`` (round-robin symmetric co-readers, CPU never
    governing a completion — see :func:`_stripe_width` and
    :func:`_io_sym_spans_ok`), else 0."""
    if not uplink_bw:
        return 0
    d = _stripe_width(tasks, len(nodes))
    if not d:
        return 0
    oh = np.asarray([nd.task_overhead for nd in nodes])
    if _io_sym_spans_ok(oh, np.asarray(speeds), work, tasks[0].io_mb,
                        uplink_bw, len(nodes), d):
        return d
    return 0


def _plan(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
          pull: bool, uplink_bw: Optional[float],
          ) -> Tuple[str, Optional[List[float]], Optional[np.ndarray]]:
    """Single-pass path selection: (path, speeds, pull work array)."""
    speeds = _constant_speeds(nodes)
    if speeds is None:
        return "event", None, None
    if pull:
        tasks = queues[0]
        if not tasks:
            return "event", speeds, None
        work = np.fromiter((t.cpu_work for t in tasks), np.float64,
                           count=len(tasks))
        if _io_active(tasks, uplink_bw):
            if _io_symmetric(nodes, speeds, tasks, work, uplink_bw):
                return "closed-pull-io-sym", speeds, work
            return "event", speeds, None
        if (work == work[0]).all():
            first = float(work[0])
            if all(nd.task_overhead + first / s > 0.0
                   for nd, s in zip(nodes, speeds)):
                return "closed-pull", speeds, work
            # zero-cost tasks: degenerate grid — the merge scan handles it
        return "closed-pull-hetero", speeds, work
    if any(_io_active(q, uplink_bw) for q in queues):
        return "event", speeds, None
    return "closed-static", speeds, None


def plan_path(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
              pull: bool, uplink_bw: Optional[float] = None) -> str:
    """Which execution path ``simulate_stage`` will take: 'closed-pull' |
    'closed-pull-hetero' | 'closed-pull-io-sym' | 'closed-static' |
    'event' (see the module-docstring selection table)."""
    return _plan(nodes, queues, pull, uplink_bw)[0]


def _empty_columns(names: Tuple[str, ...]) -> StageColumns:
    z = np.empty(0, np.float64)
    zi = np.empty(0, np.int64)
    return StageColumns(zi, zi, z, z, z, names)


def _closed_form_static(nodes: Sequence[SimNode], speeds: Sequence[float],
                        assignments: Sequence[Sequence[SimTask]],
                        start_time: float) -> StageResult:
    names = tuple(nd.name for nd in nodes)
    node_finish = {}
    ids_p, nidx_p, starts_p, ends_p, works_p = [], [], [], [], []
    for i, nd in enumerate(nodes):
        q = assignments[i]
        if not q:
            node_finish[nd.name] = start_time
            continue
        work = np.fromiter((t.cpu_work for t in q), np.float64, count=len(q))
        ends = start_time + np.cumsum(nd.task_overhead + work / speeds[i])
        starts = np.empty_like(ends)
        starts[0] = start_time
        starts[1:] = ends[:-1]
        node_finish[nd.name] = float(ends[-1])
        ids_p.append(np.fromiter((t.task_id for t in q), np.int64,
                                 count=len(q)))
        nidx_p.append(np.full(len(q), i, np.int64))
        starts_p.append(starts)
        ends_p.append(ends)
        works_p.append(work)
    if ids_p:
        ids = np.concatenate(ids_p)
        nidx = np.concatenate(nidx_p)
        starts = np.concatenate(starts_p)
        ends = np.concatenate(ends_p)
        works = np.concatenate(works_p)
        order = np.lexsort((nidx, ends))     # oracle order: (time, node idx)
        cols = StageColumns(ids[order], nidx[order], starts[order],
                            ends[order], works[order], names)
    else:
        cols = _empty_columns(names)
    return _stage_result_columns(cols, node_finish, start_time)


def _pull_uniform_grid(periods: np.ndarray, n_tasks: int,
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Solve the uniform-pull grid: node i is free to pull at grid times
    ``j * periods[i]``; the schedule is the n_tasks smallest grid points,
    ties resolved by node index (the oracle's lowest-index scan).  Bisect a
    threshold so only ~n_tasks candidates are materialized before the
    lexsort.  Returns ``(pull_node, pull_seq)``: the pulling node and its
    per-node pull sequence number for each scheduled task.  Shared by the
    record path and run_job's record-free summaries — one solver, one
    tie-break."""
    n = len(periods)
    lo, hi = 0.0, float(periods.min()) * (n_tasks + 1)
    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if int(np.floor(mid / periods).sum()) + n >= n_tasks:
            hi = mid
        else:
            lo = mid
    per_node = np.minimum(np.floor(hi / periods).astype(np.int64) + 2, n_tasks)
    node_idx = np.repeat(np.arange(n), per_node)
    seq = np.concatenate([np.arange(c) for c in per_node])
    times = seq * periods[node_idx]
    order = np.lexsort((node_idx, times))[:n_tasks]
    return node_idx[order], seq[order]


def _closed_form_pull_uniform(nodes: Sequence[SimNode], speeds: Sequence[float],
                              tasks: Sequence[SimTask], work: float,
                              start_time: float) -> StageResult:
    n, n_tasks = len(nodes), len(tasks)
    periods = np.asarray([nd.task_overhead + work / s
                          for nd, s in zip(nodes, speeds)])
    pull_node, pull_seq = _pull_uniform_grid(periods, n_tasks)
    starts = start_time + pull_seq * periods[pull_node]
    ends = start_time + (pull_seq + 1) * periods[pull_node]
    counts = np.bincount(pull_node, minlength=n)

    order = np.lexsort((pull_node, ends))    # completion order
    names = tuple(nd.name for nd in nodes)
    ids = np.fromiter((t.task_id for t in tasks), np.int64, count=n_tasks)
    cols = StageColumns(ids[order], pull_node[order], starts[order],
                        ends[order], np.full(n_tasks, work, np.float64),
                        names)
    node_finish = {
        nd.name: (start_time + float(counts[i] * periods[i])
                  if counts[i] else start_time)
        for i, nd in enumerate(nodes)}
    return _stage_result_columns(cols, node_finish, start_time)


def _pull_hetero_heap(oh: Sequence[float], speeds: Sequence[float],
                      works: Sequence[float], start_time: float,
                      ) -> Tuple[List[Tuple[float, int]], List[int]]:
    """Initial pulls of the merged-grid scan: node i takes task i at the
    stage start; the heap keys ``(end, node)`` reproduce the event
    calendar's lowest-index tie-break.  ``end = (free + overhead) +
    work/speed`` is the exact arithmetic of the constant-speed
    ``finish_time``, so end times match the event calendar bitwise."""
    n_live = min(len(speeds), len(works))
    cur_task = [-1] * len(speeds)
    heap: List[Tuple[float, int]] = []
    for i in range(n_live):
        w = works[i]
        e = start_time + oh[i]
        if w > 0.0:
            e += w / speeds[i]
        heap.append((e, i))
        cur_task[i] = i
    heapq.heapify(heap)
    return heap, cur_task


_RUN_BATCH_MIN = 32     # mean run length below which the heap scan wins


def _pull_hetero_try_batched(oh: Sequence[float], speeds: Sequence[float],
                             works: Sequence[float], start_time: float,
                             want_records: bool):
    """Run-length batched merged-grid scan (ROADMAP item: numpy batching).

    Real shuffle stages (Fig 18 skewed-hash buckets, even splits) enqueue
    *runs* of equal-sized tasks.  Within such a run the merge is the
    offset-uniform-grid problem: node i pulls at ``e_i + m * p_i`` with
    period ``p_i = oh_i + w / s_i``, and the run's schedule is its R
    lexicographically smallest ``(time, node)`` grid points — solved here
    with ``np.lexsort`` over per-node candidate grids instead of R heap
    steps, cutting the ~0.3 us/task pure-Python heap cost to amortized
    numpy.  Tie semantics match the heap exactly: within a path identical
    nodes generate bit-identical grids, and ``lexsort((node, time))``
    reproduces the ``(end, node)`` heap key order.

    Returns ``(node_end, counts, per_task)`` — ``per_task`` is
    ``(node_of, start_of, end_of)`` numpy arrays when ``want_records`` —
    or None when the input is a poor fit (short mean run length, or a
    degenerate zero period somewhere) and the caller should take the heap
    scan.
    """
    w_arr = np.asarray(works, np.float64)
    n_tasks = len(w_arr)
    n = len(speeds)
    if n_tasks < 2 * _RUN_BATCH_MIN:
        return None
    # exact run-length grouping: works that differ by any amount are
    # different runs; float noise only shrinks runs (slower, never wrong)
    change = np.flatnonzero(np.diff(w_arr) != 0.0) + 1  # hemt-lint: disable=HL004
    bounds = np.concatenate(([0], change, [n_tasks]))
    n_runs = len(bounds) - 1
    if n_runs * _RUN_BATCH_MIN > n_tasks:
        return None                     # mostly distinct sizes: heap wins
    oh_a = np.asarray(oh, np.float64)
    sp = np.asarray(speeds, np.float64)
    run_w = w_arr[bounds[:-1]]
    periods = oh_a[None, :] + run_w[:, None] / sp[None, :]   # [runs, n]
    if (periods <= 0.0).any():
        return None                     # zero-period degenerate: heap scan
    e = np.full(n, float(start_time))
    counts = np.zeros(n, np.int64)
    wsums = np.zeros(n, np.float64)
    if want_records:
        node_of = np.empty(n_tasks, np.int64)
        start_of = np.empty(n_tasks, np.float64)
        end_of = np.empty(n_tasks, np.float64)
    arange_n = np.arange(n)
    for r in range(n_runs):
        k0, k1 = int(bounds[r]), int(bounds[r + 1])
        big_r = k1 - k0
        p = periods[r]
        # candidate cap: the fluid pull time t0 solving
        # sum_i((t0 - e_i)/p_i + 1) = R, plus one max period.  count(t) =
        # sum_i max(0, floor((t - e_i)/p_i) + 1) satisfies count(t0) >=
        # R - n and gains >= n per max(p), so count(cap) >= R: every one
        # of the run's R merged pull points is <= cap.  +2 absorbs float
        # rounding at the boundary (over-generation is harmless — lexsort
        # keeps the R smallest — under-generation is not).
        inv = 1.0 / p
        t0 = (big_r - n + (e * inv).sum()) / inv.sum()
        cap = t0 + p.max()
        m = np.floor((cap - e) * inv).astype(np.int64) + 2
        np.clip(m, 0, big_r, out=m)
        if int(m.sum()) < big_r:      # fp paranoia: conservative re-cap
            cap = (e + (big_r - 1) * p).min()
            m = np.floor((cap - e) * inv).astype(np.int64) + 2
            np.clip(m, 0, big_r, out=m)
        node_idx = np.repeat(arange_n, m)
        seq = np.concatenate([np.arange(c) for c in m])
        times = e[node_idx] + seq * p[node_idx]
        order = np.lexsort((node_idx, times))[:big_r]
        sel = node_idx[order]
        taken = np.bincount(sel, minlength=n)
        if want_records:
            node_of[k0:k1] = sel
            pulls = times[order]
            start_of[k0:k1] = pulls
            end_of[k0:k1] = pulls + p[sel]
        e = e + taken * p
        counts += taken
        wsums += taken * run_w[r]
    node_end = np.where(counts > 0, e, start_time)
    per_task = (node_of, start_of, end_of) if want_records else None
    return node_end.tolist(), counts.tolist(), wsums.tolist(), per_task


def _pull_hetero_summary(oh: Sequence[float], speeds: Sequence[float],
                         works: Sequence[float], start_time: float,
                         ) -> Tuple[List[float], List[int], List[float]]:
    """Record-free merged-grid scan: per-node (last finish, task count,
    executed work) only — the whole-job (``run_job``) hot loop, with no
    per-task object work at all.  Blocky work sequences (runs of equal
    sizes) take the numpy run-length batched path."""
    batched = _pull_hetero_try_batched(oh, speeds, works, start_time, False)
    if batched is not None:
        return batched[0], batched[1], batched[2]
    n, n_tasks = len(speeds), len(works)
    heap, _ = _pull_hetero_heap(oh, speeds, works, start_time)
    counts = [0] * n
    wsums = [0.0] * n
    for _, i in heap:
        counts[i] = 1
        wsums[i] = works[i]
    replace = heapq.heapreplace
    for k in range(min(n, n_tasks), n_tasks):
        w = works[k]
        e0, i = heap[0]
        e = e0 + oh[i]
        if w > 0.0:
            e += w / speeds[i]
        counts[i] += 1
        wsums[i] += w
        replace(heap, (e, i))
    node_end = [start_time] * n
    for e0, i in heap:
        node_end[i] = e0
    return node_end, counts, wsums


def _closed_form_pull_hetero(nodes: Sequence[SimNode], speeds: Sequence[float],
                             tasks: Sequence[SimTask], work: np.ndarray,
                             start_time: float) -> StageResult:
    """Full merged-grid scan (see module docstring): FIFO hands task k to
    the owner of the k-th smallest end event; per-task (node, start, end)
    are stored into flat lists and records are materialized once at the
    end, in task order.  Blocky work sequences take the numpy run-length
    batched path (``_pull_hetero_try_batched``)."""
    n, n_tasks = len(nodes), len(tasks)
    oh = [nd.task_overhead for nd in nodes]
    names = tuple(nd.name for nd in nodes)
    ids = np.fromiter((t.task_id for t in tasks), np.int64, count=n_tasks)
    batched = _pull_hetero_try_batched(oh, speeds, work, start_time, True)
    if batched is not None:
        node_end, _, _, (node_arr, start_arr, end_arr) = batched
        cols = StageColumns(ids, node_arr.astype(np.int64, copy=False),
                            start_arr, end_arr,
                            np.asarray(work, np.float64), names)
        node_finish = {names[i]: node_end[i] for i in range(n)}
        return _stage_result_columns(cols, node_finish, start_time)
    works = work.tolist()
    heap, cur_task = _pull_hetero_heap(oh, speeds, works, start_time)
    node_of = list(range(min(n, n_tasks))) + [0] * (n_tasks - min(n, n_tasks))
    start_of = [start_time] * n_tasks
    end_of = [0.0] * n_tasks
    replace = heapq.heapreplace
    for k in range(min(n, n_tasks), n_tasks):
        e0, i = heap[0]
        end_of[cur_task[i]] = e0
        w = works[k]
        e = e0 + oh[i]
        if w > 0.0:
            e += w / speeds[i]
        start_of[k] = e0
        node_of[k] = i
        cur_task[i] = k
        replace(heap, (e, i))
    node_end = [start_time] * n
    while heap:
        e0, i = heapq.heappop(heap)
        end_of[cur_task[i]] = e0
        node_end[i] = e0
    cols = StageColumns(ids, np.asarray(node_of, np.int64),
                        np.asarray(start_of, np.float64),
                        np.asarray(end_of, np.float64),
                        np.asarray(work, np.float64), names)
    node_finish = {names[i]: node_end[i] for i in range(n)}
    return _stage_result_columns(cols, node_finish, start_time)


def _io_sym_schedule(n: int, n_tasks: int, io_mb: float, uplink_bw: float,
                     start_time: float, d: int = 1,
                     ) -> Tuple[np.ndarray, np.ndarray,
                                List[float], List[int]]:
    """Round times for ``closed-pull-io-sym``: task k runs on node ``k % n``
    in round ``k // n`` reading datanode ``k % d`` of the stripe (``d | n``,
    so each full round's datanode groups hold ``n / d`` co-readers each and
    all drain simultaneously after ``io_mb / (uplink_bw / (n/d))``; the
    tail round's group j, ``c_j`` readers, drains independently after
    ``io_mb / (uplink_bw / c_j)``).  Returns per-task (starts, ends) plus
    per-node (last finish, task count)."""
    full_rounds, q = divmod(n_tasks, n)
    full = io_mb / (uplink_bw / (n // d))
    ks = np.arange(n_tasks)
    starts = start_time + (ks // n) * full
    ends = starts + full
    tail_end = [start_time + full_rounds * full
                + (io_mb / (uplink_bw / int(c)) if c else 0.0)
                for c in np.bincount(np.arange(q) % d, minlength=d)]
    if q:
        ends[full_rounds * n:] = [tail_end[i % d] for i in range(q)]
    node_end, counts = [], []
    for i in range(n):
        if q and i < q:
            node_end.append(tail_end[i % d])
            counts.append(full_rounds + 1)
        elif full_rounds:
            node_end.append(start_time + full_rounds * full)
            counts.append(full_rounds)
        else:
            node_end.append(start_time)   # never ran
            counts.append(0)
    return starts, ends, node_end, counts


def _closed_form_pull_io_sym(nodes: Sequence[SimNode],
                             tasks: Sequence[SimTask], uplink_bw: float,
                             start_time: float) -> StageResult:
    n, n_tasks = len(nodes), len(tasks)
    starts, ends, node_end, _ = _io_sym_schedule(
        n, n_tasks, tasks[0].io_mb, uplink_bw, start_time,
        _stripe_width(tasks, n))
    names = tuple(nd.name for nd in nodes)
    cols = StageColumns(
        np.fromiter((t.task_id for t in tasks), np.int64, count=n_tasks),
        np.arange(n_tasks, dtype=np.int64) % n,
        np.asarray(starts, np.float64), np.asarray(ends, np.float64),
        np.fromiter((t.cpu_work for t in tasks), np.float64, count=n_tasks),
        names)
    node_finish = {names[i]: node_end[i] for i in range(n)}
    return _stage_result_columns(cols, node_finish, start_time)


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def simulate_stage(nodes: Sequence[SimNode], queues: Sequence[Sequence[SimTask]],
                   pull: bool, uplink_bw: Optional[float] = None,
                   start_time: float = 0.0, mitigation=None,
                   faults: Optional[FaultTrace] = None) -> StageResult:
    """Run one stage on the fastest applicable path (see module docstring).

    ``mitigation`` must be an event-level policy (SpeculativeCopies /
    WorkStealing); mitigated stages always take the event calendar — the
    closed forms model no cancel/re-launch events.  Barrier-level policies
    (ReskewHandoff) are applied by :func:`run_job`, not per stage.
    ``faults`` (a non-empty :class:`~repro.core.faults.FaultTrace`) also
    forces the event calendar — kills/drains/recoveries are point events
    with no closed form.
    """
    if faults is not None and faults.events:
        return run_stage_events(nodes, queues, pull, uplink_bw, start_time,
                                mitigation, faults)
    if mitigation is not None:
        return run_stage_events(nodes, queues, pull, uplink_bw, start_time,
                                mitigation)   # validates the policy kind
    path, speeds, work = _plan(nodes, queues, pull, uplink_bw)
    if path == "closed-pull":
        return _closed_form_pull_uniform(nodes, speeds, queues[0],
                                         float(work[0]), start_time)
    if path == "closed-pull-hetero":
        return _closed_form_pull_hetero(nodes, speeds, queues[0], work,
                                        start_time)
    if path == "closed-pull-io-sym":
        return _closed_form_pull_io_sym(nodes, queues[0], uplink_bw,
                                        start_time)
    if path == "closed-static":
        return _closed_form_static(nodes, speeds, queues, start_time)
    return run_stage_events(nodes, queues, pull, uplink_bw, start_time)


# --------------------------------------------------------------------------
# whole jobs: stage specs + barrier-carrying run_job
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PullSpec:
    """One HomT stage: a shared FIFO queue that idle nodes pull from.

    Either ``n_tasks`` uniform tasks of ``task_work`` each, or explicit
    per-task ``works`` in queue order (coerced to a tuple so specs stay
    hashable — equal specs share one cached solve inside ``run_job``).
    Optional symmetric I/O: every task reads ``io_mb`` from ``datanode``.
    ``mitigation`` is an event-level straggler policy from
    ``repro.core.speculation`` (hashable frozen dataclass) applied while
    the stage runs; pull stages reject barrier-level ReskewHandoff.
    """
    n_tasks: int = 0
    task_work: float = 0.0
    works: Optional[Tuple[float, ...]] = None
    io_mb: float = 0.0
    datanode: int = -1
    mitigation: Optional[object] = None

    def __post_init__(self):
        if self.works is not None:
            object.__setattr__(self, "works",
                               tuple(float(w) for w in self.works))
        if isinstance(self.mitigation, ReskewHandoff):
            raise ValueError("ReskewHandoff is barrier-level and applies to "
                             "StaticSpec stages only")

    def work_array(self) -> np.ndarray:
        if self.works is not None:
            return np.asarray(self.works, np.float64)
        return np.full(self.n_tasks, float(self.task_work))


@dataclass(frozen=True)
class StaticSpec:
    """One HeMT stage: ``works[i]`` is node i's single macrotask.  Every
    node runs exactly one task (zero-work macrotasks still pay the per-task
    overhead and count as having run, matching ``run_static_stage`` with
    one ``SimTask`` per node).  ``mitigation`` accepts event-level policies
    (applied while the stage runs) or barrier-level ReskewHandoff (applied
    by ``run_job`` at this stage's barrier: stragglers are cut and their
    residual work folds into the next stage's split).

    Optional I/O (the Claim 2 x mitigation cross setting): ``io_mb`` is the
    stage's TOTAL input, split across macrotasks proportionally to
    ``works`` (evenly when every work is zero) and read from ``datanode``
    through the flow-shared uplink.  Stages with effective I/O solve on
    the event calendar; mitigated ones launch duplicate readers there."""
    works: Tuple[float, ...]
    mitigation: Optional[object] = None
    io_mb: float = 0.0
    datanode: int = -1

    def __post_init__(self):
        object.__setattr__(self, "works",
                           tuple(float(w) for w in self.works))

    def io_split(self) -> Tuple[float, ...]:
        """Per-node input bytes: ``io_mb`` proportional to ``works``."""
        n = len(self.works)
        if self.io_mb <= 0.0 or self.datanode < 0 or n == 0:
            return (0.0,) * n
        total = sum(self.works)
        if total <= 0.0:
            return (self.io_mb / n,) * n
        return tuple(self.io_mb * w / total for w in self.works)


@dataclass
class StageSummary:
    """Record-free stage outcome (the whole-job analogue of StageResult)."""
    start: float
    completion: float
    idle_time: float
    node_finish: Dict[str, float]
    counts: Dict[str, int]           # tasks completed per node
    # CPU work each node actually executed (post re-skew cut, where one
    # applies) — what the OA-HeMT loop feeds the AR(1) estimator as d_i
    work: Dict[str, float] = field(default_factory=dict)

    @property
    def span(self) -> float:
        return self.completion - self.start


class JobContinuation(NamedTuple):
    """Splice point for a resumed :func:`run_job`: skip stages before
    ``next_stage`` and run the rest starting at absolute ``clock``, with an
    optional re-skew ``carry`` — ``(residual work, per-node throughputs)``
    exactly as a ReskewHandoff barrier produces — folded into the first
    resumed stage.  This is how a resident scheduler
    (:mod:`repro.core.resident`) hands a job's unaffected tail back to the
    closed-form solver after the last fault/resize has been spliced in."""
    next_stage: int
    clock: float
    carry: Optional[Tuple[float, Tuple[float, ...]]] = None


@dataclass
class JobSchedule:
    completion: float
    stages: List[StageSummary]
    # the continuation this schedule was resumed from (None: ran from
    # stage 0) — stages[k] is then the (continuation.next_stage + k)-th
    # program stage
    continuation: Optional[JobContinuation] = None

    @property
    def makespan(self) -> float:
        return self.completion


def _rel_from_offsets(offs: List[float], counts: List[int],
                      works: List[float],
                      ) -> Tuple[float, float, List[float], List[int],
                                 List[float]]:
    """(span, idle, offsets, counts, executed works) from per-node finish
    offsets; idle is the finish spread over nodes that ran >= 1 task
    (Claim 1 metric)."""
    ran = [o for o, c in zip(offs, counts) if c]
    span = max(offs) if offs else 0.0
    idle = (max(ran) - min(ran)) if ran else 0.0
    return span, idle, offs, counts, works


def _rel_summary_static(oh: Sequence[float], speeds: Sequence[float],
                        spec: StaticSpec):
    if len(spec.works) != len(speeds):
        raise ValueError("StaticSpec needs one macrotask work per node")
    offs = [o + w / s for o, w, s in zip(oh, spec.works, speeds)]
    return _rel_from_offsets(offs, [1] * len(offs), list(spec.works))


def _rel_summary_pull_uniform(oh: Sequence[float], speeds: Sequence[float],
                              n_tasks: int, work: float):
    """Counts + finish offsets of the uniform grid, record-free: the same
    ``_pull_uniform_grid`` solve as ``_closed_form_pull_uniform``, stopping
    at the per-node ``bincount``."""
    periods = np.asarray([o + work / s for o, s in zip(oh, speeds)])
    pull_node, _ = _pull_uniform_grid(periods, n_tasks)
    counts = np.bincount(pull_node, minlength=len(speeds))
    offs = [float(c * p) if c else 0.0 for c, p in zip(counts, periods)]
    return _rel_from_offsets(offs, counts.tolist(),
                             [float(c * work) for c in counts])


def _rel_summary_from_result(res: StageResult, names: Sequence[str],
                             start: float):
    """Per-node counts/works via the columnar view (a bincount — no
    ``TaskRecord`` is materialized on closed-form results)."""
    cols = res.columns()
    n = len(names)
    if cols.node_names == tuple(names):
        nidx = cols.node_index
    else:       # stage ran on a subset / different order of ``names``
        idx_of = {nm: i for i, nm in enumerate(names)}
        remap = np.asarray([idx_of[nm] for nm in cols.node_names], np.int64)
        nidx = remap[cols.node_index]
    counts = np.bincount(nidx, minlength=n)
    works = np.bincount(nidx, weights=cols.works, minlength=n)
    offs = [res.node_finish[nm] - start for nm in names]
    return _rel_from_offsets(offs, counts.tolist(), works.tolist())


def _spec_tasks(spec) -> Sequence[Sequence[SimTask]]:
    """Materialize a spec into engine queues (the event-path fallback)."""
    if isinstance(spec, StaticSpec):
        ios = spec.io_split()
        return [[SimTask(w, ios[i], spec.datanode if ios[i] > 0.0 else -1,
                         task_id=i)]
                for i, w in enumerate(spec.works)]
    return [[SimTask(float(w), spec.io_mb, spec.datanode, task_id=k)
             for k, w in enumerate(spec.work_array())]]


def _rel_summary(nodes: Sequence[SimNode], speeds: Sequence[float],
                 spec, uplink_bw: Optional[float]):
    """Solve one stage spec at relative start 0 on a constant-speed
    cluster: (span, idle, per-node finish offsets, per-node counts,
    per-node executed works).  Stages with an event-level mitigation
    policy — I/O or not — run the mitigated event calendar: flow sharing,
    elapsed-time triggers and placement are all relative to the stage
    start, so the solve is still start-invariant on constant speeds and
    stays shiftable and cacheable."""
    oh = [nd.task_overhead for nd in nodes]
    n = len(nodes)
    if is_event_policy(spec.mitigation):
        res = run_stage_events(nodes, _spec_tasks(spec),
                               pull=not isinstance(spec, StaticSpec),
                               uplink_bw=uplink_bw,
                               mitigation=spec.mitigation)
        return _rel_summary_from_result(res, [nd.name for nd in nodes], 0.0)
    if isinstance(spec, StaticSpec):
        if uplink_bw and spec.io_mb > _EPS and spec.datanode >= 0:
            res = run_stage_events(nodes, _spec_tasks(spec), pull=False,
                                   uplink_bw=uplink_bw)
            return _rel_summary_from_result(res, [nd.name for nd in nodes],
                                            0.0)
        return _rel_summary_static(oh, speeds, spec)
    works = spec.works
    n_tasks = spec.n_tasks if works is None else len(works)
    if n_tasks == 0:
        return 0.0, 0.0, [0.0] * n, [0] * n, [0.0] * n
    if uplink_bw and spec.io_mb > _EPS and spec.datanode >= 0:
        if _io_sym_spans_ok(np.asarray(oh), np.asarray(speeds),
                            spec.work_array(), spec.io_mb, uplink_bw, n):
            _, _, node_end, counts = _io_sym_schedule(
                n, n_tasks, spec.io_mb, uplink_bw, 0.0)
            wsums = np.bincount(np.arange(n_tasks) % n,
                                weights=spec.work_array(), minlength=n)
            return _rel_from_offsets(node_end, counts, wsums.tolist())
        res = run_stage_events(nodes, _spec_tasks(spec), pull=True,
                               uplink_bw=uplink_bw)
        return _rel_summary_from_result(res, [nd.name for nd in nodes], 0.0)
    w0 = float(spec.task_work) if works is None else works[0]
    uniform = works is None or all(w == w0 for w in works)
    if uniform and all(o + w0 / s > 0.0 for o, s in zip(oh, speeds)):
        return _rel_summary_pull_uniform(oh, speeds, n_tasks, w0)
    if works is None:               # uniform but degenerate (zero period)
        works = (w0,) * n_tasks
    node_end, counts, wsums = _pull_hetero_summary(oh, speeds, works, 0.0)
    return _rel_from_offsets(node_end, counts, wsums)


def _abs_summary(nodes: Sequence[SimNode], spec, uplink_bw: Optional[float],
                 start: float,
                 faults: Optional[FaultTrace] = None) -> StageSummary:
    """Non-shiftable fallback (multi-segment profiles, fault-affected
    windows): run the stage at its true absolute start through the
    auto-selecting engine."""
    mit = spec.mitigation if is_event_policy(spec.mitigation) else None
    res = simulate_stage(nodes, _spec_tasks(spec),
                         pull=not isinstance(spec, StaticSpec),
                         uplink_bw=uplink_bw, start_time=start,
                         mitigation=mit, faults=faults)
    names = [nd.name for nd in nodes]
    _, idle, offs, counts, wexec = _rel_summary_from_result(res, names, start)
    return StageSummary(start, res.completion, idle,
                        dict(res.node_finish),
                        {nm: c for nm, c in zip(names, counts)},
                        {nm: w for nm, w in zip(names, wexec)})


# Module-level LRU sharing constant-speed solves across run_job calls
# (ROADMAP item: repeated benchmark invocations and the adaptive
# schedulers resolve identical (cluster, spec) stages over and over).
_SOLVE_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_SOLVE_CACHE_MAX = 512


def run_job_cache_clear() -> None:
    """Drop the module-level (cluster signature, spec) solve cache."""
    _SOLVE_CACHE.clear()


def _cluster_signature(nodes: Sequence[SimNode]) -> Tuple:
    """Hashable timing identity of a cluster: per-node (overhead, profile)
    in node order.  Names are excluded — they label results but never
    affect timing."""
    return tuple((nd.task_overhead, tuple(nd.profile)) for nd in nodes)


def _apply_reskew(nodes: Sequence[SimNode], spec: "StaticSpec",
                  summ: StageSummary, names: Sequence[str],
                  ) -> Tuple[StageSummary, float, List[float]]:
    """Barrier-level re-skew hand-off (speculation-module semantics): cut
    nodes still running past ``cutoff_factor * median`` of the per-node
    finish offsets; return the clipped summary, the total residual
    (unexecuted) work, and the observed per-node throughputs the fold is
    proportional to."""
    offs = [summ.node_finish[nm] - summ.start for nm in names]
    ran = [o for nm, o in zip(names, offs) if summ.counts[nm]]
    cutoff = spec.mitigation.cutoff(ran)
    residual = 0.0
    clipped: List[float] = []
    executed: List[float] = []
    for nd, off, w in zip(nodes, offs, spec.works):
        if off > cutoff + _EPS:
            r = min(nd.work_between(summ.start + cutoff, summ.start + off), w)
            residual += r
            clipped.append(cutoff)
            executed.append(w - r)
        else:
            clipped.append(off)
            executed.append(w)
    if residual <= 0.0:
        return summ, 0.0, []
    throughputs = [x / c if c > 0.0 else 0.0
                   for x, c in zip(executed, clipped)]
    span, idle, offs2, _, _ = _rel_from_offsets(
        clipped, [summ.counts[nm] for nm in names], executed)
    new = StageSummary(summ.start, summ.start + span, idle,
                       {nm: summ.start + o for nm, o in zip(names, offs2)},
                       dict(summ.counts),
                       {nm: x for nm, x in zip(names, executed)})
    return new, residual, throughputs


def _fold_spec(spec, residual: float, throughputs: Sequence[float]):
    """Fold residual work into the next stage's split: StaticSpec works
    grow proportionally to observed throughput (``fold_residual``); a
    PullSpec scales uniformly — its shared queue self-balances, so where
    the residual lands is decided at run time anyway."""
    if isinstance(spec, StaticSpec):
        return StaticSpec(works=tuple(fold_residual(spec.works, residual,
                                                    throughputs)),
                          mitigation=spec.mitigation,
                          io_mb=spec.io_mb, datanode=spec.datanode)
    w = spec.work_array()
    total = float(w.sum())
    if total > 0.0:
        scaled = tuple(float(x) for x in w * (1.0 + residual / total))
    else:
        scaled = tuple(float(x) + residual / len(w) for x in w)
    return PullSpec(works=scaled, io_mb=spec.io_mb, datanode=spec.datanode,
                    mitigation=spec.mitigation)


class AdaptiveStageLog(NamedTuple):
    """One ``run_job`` stage as the adaptive plan finally shaped it."""
    index: int
    works: Optional[Tuple[float, ...]]   # final static split (None for pull)
    speeds: Optional[Tuple[float, ...]]  # estimates used (None: kept planned)
    replanned: bool


class AdaptivePlan:
    """Online-adaptive HeMT (paper §5) across ``run_job`` barriers.

    At every program barrier the finished stage's observed per-node
    (executed work, busy time) pairs are fed into an
    :class:`~repro.core.estimators.ARSpeedEstimator`; each upcoming
    :class:`StaticSpec` whose estimator already has direct observations is
    re-split ``d_i = D v_i / V`` from the updated estimates before it is
    solved.  The first stage (cold estimator) runs the caller's planned
    split — the paper's k=1 rule lives with the caller.  :class:`PullSpec`
    stages are never re-planned (the shared queue self-balances at run
    time) but still feed the estimator.

    Composition with :class:`~repro.core.speculation.ReskewHandoff`: the
    residual a cut stage carries is folded into the next stage's works
    *before* the re-plan, so the re-split redistributes planned work and
    residual together — both re-skewed by the freshest estimates.

    ``quantum`` makes re-planned splits integral: works become multiples
    of ``quantum`` via largest-remainder rounding (``proportional_split``),
    with at least ``min_units`` quanta per node — the HeMT-DP driver's
    whole-grain macrotasks (``min_units`` requires a quantum: a float
    split has no unit to floor by, so passing it without one raises
    rather than silently dropping the paper-§5.1 starvation guard).  A
    total that is not a whole number of quanta (a re-skew hand-off folds
    *continuous* residual work into the next stage) is conserved exactly:
    the whole quanta are split proportionally and the sub-quantum
    remainder rides as a fractional tail on the fastest-estimated
    executor.  Quantum plans observe speeds in **quanta per second**
    (executed work / quantum), the native unit of a whole-grain system —
    the same grains/sec the driver's :class:`~repro.core.planner.
    GrainPlanner` records, so sharing its estimator mixes no units
    (splits are ratio-based and unit-invariant either way).

    ``estimator`` may be shared with a scheduler
    (:meth:`repro.core.scheduler.AdaptiveHeMTScheduler.adaptive_plan`) so
    job-sequence learning and in-job barrier learning accumulate into one
    workload-specific state.  ``history`` logs every stage's final works
    (re-planned or kept), which is how drivers recover per-stage
    assignments from a record-free adaptive run.
    """

    def __init__(self, estimator: Optional[ARSpeedEstimator] = None, *,
                 alpha: float = 0.0, cold_start: str = "mean",
                 quantum: Optional[float] = None, min_units: int = 0):
        if estimator is None:
            estimator = ARSpeedEstimator(alpha=alpha, cold_start=cold_start)
        if quantum is not None and quantum <= 0.0:
            raise ValueError("quantum must be positive")
        if min_units < 0:
            raise ValueError("min_units must be >= 0")
        if min_units > 0 and quantum is None:
            raise ValueError("min_units needs a quantum to floor by "
                             "(float splits apply no per-node floor)")
        self.estimator = estimator
        self.quantum = quantum
        self.min_units = min_units
        self.history: List[AdaptiveStageLog] = []

    def _split_with(self, speeds: Sequence[float], total: float,
                    alive: Optional[Sequence[bool]] = None) -> List[float]:
        if alive is not None and not all(alive):
            # fault-aware re-split (run_job barriers): dead/draining nodes
            # get zero work, survivors split the whole total among
            # themselves (min_units floor applies to survivors only);
            # nobody alive falls back to the full split — the stage will
            # strand either way and the planned shape is as good as any
            idx = [i for i, a in enumerate(alive) if a]
            if idx:
                sub = self._split_with([speeds[i] for i in idx], total)
                out = [0.0] * len(speeds)
                for i, w in zip(idx, sub):
                    out[i] = w
                return out
        n = len(speeds)
        if not any(s > 0.0 for s in speeds):
            # V = 0 (every executor cold/zero-speed at this barrier):
            # d_i = D v_i / V is 0/0 — fall back to the even split instead
            # of dividing by zero (the paper's k=1 rule is exactly this)
            speeds = [1.0] * n
        if self.quantum is None:
            return hemt_split_floats(total, speeds)
        units = int(round(total / self.quantum))
        if abs(units * self.quantum - total) > 1e-9 * max(1.0, abs(total)):
            # continuous residual folded by a re-skew hand-off: split the
            # whole quanta, ride the sub-quantum remainder on the fastest
            # estimated executor (work is conserved exactly; a crash here
            # would strand the run mid-job on an internally-generated
            # total the caller never chose)
            units = int(total / self.quantum)
        if units == 0 or units < self.min_units * n:
            # Degenerate quantization: either D < quantum (no executor
            # can receive a whole quantum, so largest-remainder rounding
            # has nothing to round and the whole total would ride the
            # fastest executor) or D holds fewer whole quanta than the
            # min_units floor needs (a re-skew hand-off can fold an
            # arbitrarily small residual into the next stage) — both
            # cannot honor whole-grain proportional rounding, so split
            # the total evenly instead of raising "min_share infeasible"
            # mid-job on a total the caller never chose
            return [total / n] * n
        remainder = total - units * self.quantum
        works = [float(u * self.quantum) for u in
                 proportional_split(units, speeds,
                                    min_share=self.min_units)]
        if remainder > 0.0:
            works[max(range(len(works)), key=lambda i: speeds[i])] \
                += remainder
        return works

    def split(self, names: Sequence[str], total: float,
              alive: Optional[Sequence[bool]] = None) -> List[float]:
        """The current estimates' HeMT split of ``total`` work."""
        return self._split_with(self.estimator.speeds(names), total, alive)

    def replan(self, names: Sequence[str], spec,
               alive: Optional[Sequence[bool]] = None):
        """Re-derive a StaticSpec's split from the current estimates (any
        reskew residual has already been folded into ``spec.works``).
        ``alive`` (run_job under a fault trace) restricts the split to the
        nodes alive at the barrier — survivors keep their AR(1) estimates,
        dead/draining nodes get zero work.  Returns the spec to solve;
        logs it either way."""
        k = len(self.history)
        if isinstance(spec, StaticSpec) and self.estimator.known():
            speeds = self.estimator.speeds(names)
            works = tuple(self._split_with(speeds, sum(spec.works), alive))
            self.history.append(
                AdaptiveStageLog(k, works, tuple(speeds), True))
            return StaticSpec(works=works, mitigation=spec.mitigation,
                              io_mb=spec.io_mb, datanode=spec.datanode)
        works = spec.works if isinstance(spec, StaticSpec) else None
        self.history.append(AdaptiveStageLog(k, works, None, False))
        return spec

    def observe(self, names: Sequence[str], summ: StageSummary) -> None:
        """Feed one finished stage's per-node (executed work, busy time)
        into the estimator (nodes that executed nothing are skipped — the
        paper only updates observed executors).  Quantum plans record
        speeds in quanta/sec so a shared GrainPlanner estimator sees one
        consistent unit across per-step and windowed scheduling."""
        scale = self.quantum if self.quantum is not None else 1.0
        for nm in names:
            w = summ.work.get(nm, 0.0)
            dt = summ.node_finish[nm] - summ.start
            if w > 0.0 and dt > 0.0:
                self.estimator.observe(nm, w / scale, dt)


def run_job(nodes: Sequence[SimNode], stages: Sequence,
            uplink_bw: Optional[float] = None,
            start_time: float = 0.0,
            adaptive: Optional[AdaptivePlan] = None,
            faults: Optional[FaultTrace] = None,
            resume: Optional[JobContinuation] = None) -> JobSchedule:
    """Run a whole multi-stage job: each stage starts at the previous
    stage's completion (program barrier).

    ``stages`` is a sequence of :class:`PullSpec` / :class:`StaticSpec`.
    On constant-speed clusters each *distinct* spec is solved once
    (record-free) and every repetition is an O(n) shift of the cached
    per-node finish vector, so S-stage HomT/HeMT sweeps cost O(S·n) after
    the one-time per-spec solves; solves are further shared across calls
    via the module-level LRU (:func:`run_job_cache_clear` resets it).
    Clusters with multi-segment speed profiles are not start-invariant and
    fall back to per-stage ``simulate_stage`` at the true barrier times.

    Stage specs carry their own ``mitigation`` policies: event-level ones
    run inside the stage's solve; a StaticSpec with barrier-level
    :class:`~repro.core.speculation.ReskewHandoff` is cut at its barrier
    and the residual work is folded into the next stage's split (the last
    stage is never cut — there is no later split to fold into; a cut-off
    stage's residual skips empty stages until a foldable one appears).

    ``adaptive`` (an :class:`AdaptivePlan`) turns the barrier sequence
    into the paper's §5 OA-HeMT loop: each finished stage's per-node
    (executed work, busy time) feeds the plan's AR(1) estimator, and every
    upcoming ``StaticSpec`` is re-split from the updated estimates —
    residual fold first, re-plan second, so a re-skew hand-off's residual
    is re-skewed along with the split.  Solve caching stays exact without
    estimator state in the keys: a re-planned stage is a fresh
    ``StaticSpec`` *value*, and both cache levels key solves by spec value
    (the id() level never sees a re-planned spec twice), so adaptive
    stages can only share cache entries with identical splits — whose
    solves are identical.

    ``faults`` (a :class:`~repro.core.faults.FaultTrace` on the job's
    absolute clock) breaks start-invariance, handled honestly: each stage
    is first solved fault-free (cacheable as ever), and when its
    ``[start, completion]`` window overlaps a fault window — faults only
    *remove* capacity, so the fault-free span lower-bounds the true one
    and a non-overlapping window is exactly valid — the stage is re-solved
    on the absolute-time event path, bypassing **both** cache levels; the
    LRU only ever stores fault-free solves (pinned by the no-poisoning
    test in tests/test_faults.py).  At a fault-affected barrier, work the
    stage abandoned (retries exhausted / stranded) folds into the next
    stage's split via its :class:`~repro.core.speculation.ReskewHandoff`
    proportional to observed survivor throughput (without one the loss is
    eaten — HomT-style pull stages re-queue internally and rarely abandon
    anything); the straggler *cut* itself is skipped on fault-affected
    stages (its residual recompute assumes fault-free execution).  With
    ``adaptive``, each upcoming static stage is re-split over the nodes
    alive at its barrier — survivors keep their AR(1) estimates — and a
    crash marked ``cold_restart=True`` forgets the node's estimate at its
    recovery barrier so the replacement cold-starts at the survivor mean
    (paper §5.1).

    ``resume`` (a :class:`JobContinuation`) splices into a partially-run
    job: stages before ``resume.next_stage`` are skipped, the first
    resumed stage starts at ``resume.clock`` (overriding ``start_time``),
    and ``resume.carry`` — a ``(residual, throughputs)`` pair from an
    earlier re-skew barrier — folds into it before any adaptive re-plan,
    exactly as an in-run carry would.  Everything else (solve caching,
    adaptivity, faults on the absolute clock) behaves as if the earlier
    stages had run in this call; the returned schedule records the
    continuation so callers can align ``stages[k]`` with program stage
    ``resume.next_stage + k``.
    """
    speeds = _constant_speeds(nodes)
    names = [nd.name for nd in nodes]
    t = start_time
    summaries: List[StageSummary] = []
    # two-level cache: id() fast path for the common [spec] * S sharing one
    # object, module-level LRU keyed on (cluster signature, uplink, spec)
    # so distinct-but-equal specs share a solve across run_job calls.
    # Hashing a works tuple is O(T) (Python does not memoize tuple
    # hashes), so large-works specs are cached by id() only — a 10k-task
    # spec would otherwise pay more for hashing than solving.
    by_id: Dict[int, Tuple] = {}
    sig = _cluster_signature(nodes) if speeds is not None else None
    stage_list = list(stages)
    carry: Optional[Tuple[float, List[float]]] = None   # (residual, vhat)
    if resume is not None:
        if not 0 <= resume.next_stage <= len(stage_list):
            raise ValueError(
                f"resume.next_stage {resume.next_stage} outside the "
                f"{len(stage_list)}-stage program")
        stage_list = stage_list[resume.next_stage:]
        t = resume.clock
        if resume.carry is not None and resume.carry[0] > 0.0:
            carry = (resume.carry[0], list(resume.carry[1]))
    folded_alive: List = []   # keeps folded temporaries alive: by_id keys
    # are id()s, which CPython reuses once an object is collected
    if faults is not None and not faults.events:
        faults = None
    # cold-restart recoveries not yet past: forget the node's estimate at
    # the first barrier at/after its replacement comes up (§5.1)
    cold_pending = deque(faults.cold_restarts()) if faults is not None else ()
    for k, spec in enumerate(stage_list):
        if carry is not None and _spec_n_tasks(spec):
            spec = _fold_spec(spec, carry[0], carry[1])
            folded_alive.append(spec)
            carry = None
        if adaptive is not None:
            alive = None
            if faults is not None:
                while cold_pending and cold_pending[0][0] <= t + _EPS:
                    adaptive.estimator.forget(names[cold_pending.popleft()[1]])
                mask = faults.alive_mask(len(nodes), t)
                if not all(mask):
                    alive = mask
            spec = adaptive.replan(names, spec, alive)
            folded_alive.append(spec)
        faulted = False
        if speeds is None:
            summ = _abs_summary(nodes, spec, uplink_bw, t)
            if faults is not None and faults.overlaps(t, summ.completion):
                faulted = True
                summ = _abs_summary(nodes, spec, uplink_bw, t, faults)
        else:
            rel = by_id.get(id(spec))
            if rel is None:
                cheap_hash = not isinstance(spec, PullSpec) \
                    or spec.works is None or len(spec.works) <= 1024
                key = (sig, uplink_bw, spec) if cheap_hash else None
                rel = _SOLVE_CACHE.get(key) if cheap_hash else None
                if rel is not None:
                    _SOLVE_CACHE.move_to_end(key)
                else:
                    span, idle, offs, counts, wexec = _rel_summary(
                        nodes, speeds, spec, uplink_bw)
                    rel = (span, idle, tuple(offs), tuple(counts),
                           tuple(wexec))
                    if cheap_hash:
                        _SOLVE_CACHE[key] = rel
                        if len(_SOLVE_CACHE) > _SOLVE_CACHE_MAX:
                            _SOLVE_CACHE.popitem(last=False)
                by_id[id(spec)] = rel
            span, idle, offs, counts, wexec = rel
            summ = StageSummary(
                t, t + span, idle,
                {nm: t + o for nm, o in zip(names, offs)},
                {nm: c for nm, c in zip(names, counts)},
                {nm: w for nm, w in zip(names, wexec)})
            if faults is not None and faults.overlaps(t, summ.completion):
                # the fault-free solve above stays cached (it is a valid
                # fault-free solve); the fault-affected one replacing it
                # is never stored in either cache level
                faulted = True
                summ = _abs_summary(nodes, spec, uplink_bw, t, faults)
        if (isinstance(spec, StaticSpec)
                and isinstance(spec.mitigation, ReskewHandoff)
                and k + 1 < len(stage_list)):
            if faulted:
                # no straggler cut on a fault-affected stage (the cut's
                # residual recompute assumes fault-free execution); its
                # abandoned work still folds forward through the handoff,
                # proportional to observed survivor throughput
                lost = lost_work(_spec_total_work(spec),
                                 sum(summ.work.values()))
                if lost > 0.0:
                    offs = [summ.node_finish[nm] - summ.start for nm in names]
                    vhat = [summ.work.get(nm, 0.0) / o if o > 0.0 else 0.0
                            for nm, o in zip(names, offs)]
                    carry = (lost, vhat)
            else:
                summ, residual, vhat = _apply_reskew(nodes, spec, summ, names)
                if residual > 0.0:
                    carry = (residual, vhat)
        if adaptive is not None:
            adaptive.observe(names, summ)
        summaries.append(summ)
        t = summ.completion
    return JobSchedule(t, summaries, continuation=resume)


def _spec_n_tasks(spec) -> int:
    if isinstance(spec, StaticSpec):
        return len(spec.works)
    return spec.n_tasks if spec.works is None else len(spec.works)


def _spec_total_work(spec) -> float:
    if isinstance(spec, StaticSpec):
        return float(sum(spec.works))
    return float(spec.work_array().sum())
