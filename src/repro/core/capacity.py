"""Token-bucket (burstable instance) capacity model — paper §6.2.

A burstable node has CPU credits c (minutes of full-speed compute), earns
credits at its baseline rate rho while idle, runs at full speed 1.0 while
credits remain, then drops to rho. The per-node *workload-vs-time* curve

    W(t) = min(t, t_burst) + rho * max(0, t - t_burst),  t_burst = c / (1 - rho)

is piecewise linear (paper Figs 10-11). To split a job of size W0 over
nodes so they finish simultaneously, superpose What(t) = sum_i W_i(t),
solve What(t') = W0, and give node i the share W_i(t') (paper Fig 12).

Paper's worked example: t2.small, 4 initial credits, rho=0.2:
t_burst = 4/0.8 = 5 min; W(10) = 5 + 0.2*5 = 6. Three nodes with credits
{4, 8, 12} and rho=0.2 splitting W0=20: t' = 80/11, shares {60/11, 80/11,
80/11} = 3:4:4.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple


@dataclass(frozen=True)
class BurstableNode:
    """One token-bucket governed node.

    credits:   initial CPU credits, in minutes of full-speed work
    baseline:  rho in (0, 1]; fraction of a core when credits are exhausted
    peak:      full-speed rate (1.0 = one core at 100%)
    """
    credits: float
    baseline: float
    peak: float = 1.0

    def __post_init__(self):
        if self.credits < 0:
            raise ValueError("credits must be >= 0")
        if not 0 < self.baseline <= self.peak:
            raise ValueError("need 0 < baseline <= peak")

    @property
    def burst_time(self) -> float:
        """Time until credits deplete under full load: c / (1 - rho/peak)."""
        drain = self.peak - self.baseline  # net credit burn per unit time
        if drain <= 0:
            return math.inf
        return self.credits * self.peak / drain

    def work_by(self, t: float) -> float:
        """W(t): work completed by time t under continuous full load."""
        if t <= 0:
            return 0.0
        tb = self.burst_time
        if t <= tb:
            return self.peak * t
        return self.peak * tb + self.baseline * (t - tb)

    def time_for(self, w: float) -> float:
        """Inverse of work_by: time to finish w units."""
        if w <= 0:
            return 0.0
        tb = self.burst_time
        burst_work = self.peak * tb if math.isfinite(tb) else math.inf
        if w <= burst_work:
            return w / self.peak
        return tb + (w - burst_work) / self.baseline


def superposed_work(nodes: Sequence[BurstableNode], t: float) -> float:
    """What(t) = sum_i W_i(t)."""
    return sum(n.work_by(t) for n in nodes)


def solve_finish_time(nodes: Sequence[BurstableNode], total_work: float,
                      tol: float = 1e-12) -> float:
    """Solve What(t') = W0 exactly over the piecewise-linear segments."""
    if total_work <= 0:
        return 0.0
    if not nodes:
        raise ValueError("no nodes")
    # breakpoints: each node's burst_time
    bps = sorted({n.burst_time for n in nodes if math.isfinite(n.burst_time)})
    t_prev, w_prev = 0.0, 0.0
    for bp in bps:
        w_at = superposed_work(nodes, bp)
        if w_at >= total_work - tol:
            # target inside segment [t_prev, bp]: linear interpolation is
            # exact because every W_i is linear inside the segment
            rate = (w_at - w_prev) / (bp - t_prev)
            return t_prev + (total_work - w_prev) / rate
        t_prev, w_prev = bp, w_at
    # beyond all breakpoints: all nodes at baseline
    rate = sum(n.baseline for n in nodes)
    if rate <= 0:
        raise ValueError("zero aggregate baseline rate")
    return t_prev + (total_work - w_prev) / rate


def burstable_split(nodes: Sequence[BurstableNode], total_work: float,
                    ) -> Tuple[List[float], float]:
    """Paper §6.2 partitioning: shares W_i(t') so all nodes finish at t'.

    Returns (shares summing to total_work, t').
    """
    t_star = solve_finish_time(nodes, total_work)
    raw = [n.work_by(t_star) for n in nodes]
    s = sum(raw)
    if s <= 0:
        raise ValueError("degenerate capacity")
    shares = [r * total_work / s for r in raw]
    return shares, t_star


@dataclass
class TokenBucket:
    """Dynamic credit state for the cluster simulator (millisecond-level
    accrual/spend like EC2 T2, paper §6.2)."""
    credits: float            # current credits (minutes of full-speed work)
    baseline: float           # earn rate = baseline (credits/min at idle)
    peak: float = 1.0
    cap: float = math.inf     # max accumulated credits

    def run(self, dt: float, load: float = 1.0) -> float:
        """Advance dt minutes at `load` (0..1 requested utilization).
        Returns work done. Credits earn at baseline*(1) and burn at
        rate*(spent above baseline)."""
        if dt <= 0:
            return 0.0
        load = min(max(load, 0.0), 1.0)
        # rate achievable now
        rate = self.peak if self.credits > 0 else self.baseline
        rate = min(rate, self.peak * load) if load > 0 else 0.0
        burn = max(0.0, rate - self.baseline)  # net credit change per minute
        if burn > 0 and self.credits > 0:
            t_deplete = self.credits / burn
            if dt <= t_deplete:
                self.credits -= burn * dt
                return rate * dt
            # split: burst until depletion, then baseline
            work = rate * t_deplete
            self.credits = 0.0
            rem = dt - t_deplete
            return work + min(self.baseline, self.peak * load) * rem
        # earning or steady
        self.credits = min(self.cap, self.credits + (self.baseline - rate) * dt)
        return rate * dt
