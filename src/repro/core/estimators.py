"""Executor speed estimation (paper §5.1) + fudge-factor learning (§6.2).

The paper's first-order autoregressive estimator, per (job-class, executor):

    v_i  <-  (1 - alpha) * d_i / t_i  +  alpha * v_i ,   0 < alpha < 1

with the cold-start rule: executors never seen for this job class
(``L_k^o``) get the *mean* speed of the known ones (configurable to
min/max — the paper mentions those alternatives).

The fudge factor (§6.2): advertised capacity ratios (e.g. AWS t2.medium
baseline 40%) overestimate effective throughput because of cache/TLB
contention; short probe tasks measure the true ratio (paper learns
1:0.32 where the SLA said 1:0.4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


@dataclass
class SpeedEstimate:
    value: float
    n_obs: int = 0          # how many observations went into it
    cold: bool = True       # True until first direct observation


class ARSpeedEstimator:
    """Per-executor AR(1) speed estimates for ONE job class.

    Each application framework (job class) maintains its own instance —
    the paper stresses estimates are *workload specific*.
    """

    def __init__(self, alpha: float = 0.5, cold_start: str = "mean"):
        if not 0.0 <= alpha < 1.0:
            raise ValueError(f"forgetting factor alpha must be in [0,1): {alpha}")
        if cold_start not in ("mean", "min", "max"):
            raise ValueError(f"cold_start must be mean|min|max: {cold_start}")
        self.alpha = alpha
        self.cold_start = cold_start
        self._est: Dict[str, SpeedEstimate] = {}

    # -- queries -----------------------------------------------------------
    def known(self) -> Dict[str, float]:
        return {k: e.value for k, e in self._est.items() if not e.cold}

    def speed(self, executor: str) -> Optional[float]:
        e = self._est.get(executor)
        return None if e is None else e.value

    def speeds(self, executors: Sequence[str]) -> List[float]:
        """Speeds for a worker set; cold/unseen executors get the cold-start
        statistic of the known ones (paper: v_i = v-bar for i in L_k^o)."""
        known = [e.value for e in self._est.values() if not e.cold]
        if known:
            fill = {"mean": sum(known) / len(known),
                    "min": min(known), "max": max(known)}[self.cold_start]
        else:
            fill = 1.0
        out = []
        for ex in executors:
            e = self._est.get(ex)
            out.append(fill if e is None or e.cold else e.value)
        return out

    # -- updates -----------------------------------------------------------
    def observe(self, executor: str, work: float, elapsed: float) -> float:
        """Record that `executor` processed `work` units in `elapsed` seconds."""
        if elapsed <= 0:
            raise ValueError("elapsed must be positive")
        sample = work / elapsed
        e = self._est.get(executor)
        if e is None or e.cold:
            # first direct observation: v_i = d_i / t_i  (paper, k=1 case)
            self._est[executor] = SpeedEstimate(sample, 1, cold=False)
        else:
            e.value = (1.0 - self.alpha) * sample + self.alpha * e.value
            e.n_obs += 1
        return self._est[executor].value

    def observe_many(self, results: Mapping[str, Tuple[float, float]]) -> None:
        for ex, (work, elapsed) in results.items():
            self.observe(ex, work, elapsed)

    def forget(self, executor: str) -> None:
        """Drop an executor (revoked instance / dead node)."""
        self._est.pop(executor, None)


@dataclass
class FudgeFactorLearner:
    """§6.2: learn effective capacity ratio from short probe tasks.

    Advertised ratio r_adv (e.g. 0.4) is corrected by the measured probe
    throughput ratio; exponential smoothing across probes.
    """
    advertised: float
    smoothing: float = 0.3
    _learned: Optional[float] = field(default=None, init=False)

    @property
    def effective(self) -> float:
        return self.advertised if self._learned is None else self._learned

    def probe(self, fast_rate: float, slow_rate: float) -> float:
        """Feed one probe pair (work/sec on the full-speed node vs the
        throttled node); returns the updated effective ratio."""
        if fast_rate <= 0 or slow_rate <= 0:
            raise ValueError("probe rates must be positive")
        measured = slow_rate / fast_rate
        if self._learned is None:
            self._learned = measured
        else:
            self._learned = (1 - self.smoothing) * self._learned \
                + self.smoothing * measured
        return self._learned


def normalized(speeds: Iterable[float]) -> List[float]:
    s = list(speeds)
    tot = sum(s)
    if tot <= 0 or any(x < 0 for x in s):
        raise ValueError(f"speeds must be non-negative with positive sum: {s}")
    return [x / tot for x in s]


def synchronization_delay(finish_times: Sequence[float]) -> float:
    """Paper's resource idling time: latest finish - earliest finish."""
    return max(finish_times) - min(finish_times) if finish_times else 0.0


def estimate_quality(true_speeds: Sequence[float],
                     est_speeds: Sequence[float]) -> float:
    """Relative L1 error of normalized speed estimates (diagnostic)."""
    t, e = normalized(true_speeds), normalized(est_speeds)
    return sum(abs(a - b) for a, b in zip(t, e))
