"""Speculation & work-stealing: straggler mitigation for the whole-job engine.

The paper's HomT-vs-HeMT comparison hinges on straggler mitigation: pull
auto-balances (Claim 1) while HeMT with stale capacity estimates strands
work on slow nodes.  This module supplies pluggable mitigation policies
consumed by ``engine.run_stage_events(mitigation=...)`` (cancel/re-launch
inside a stage) and by ``engine.run_job`` (re-skew hand-off at program
barriers), so HomT / HeMT / HeMT+mitigation sweeps run through one engine
(benchmarks/bench_speculation.py reproduces the ordering: learned-capacity
HeMT plus cheap mitigation beats both pure baselines under stale
estimates).

Event semantics (shared verbatim by the engine and the differential-test
oracle in tests/test_speculation.py):

* Mitigation is **offered at event instants only**: after the initial task
  assignments, after every task completion (and the queue re-pull it
  triggers), and at scheduled idle re-checks.  At each such instant idle
  nodes are offered mitigation in **ascending node index**; after an
  accepted action the sweep restarts from node 0 (state changed); the
  fixpoint ends when no idle node takes an action.  A node is idle when it
  has no running attempt and its queue (shared queue when pull, private
  queue otherwise) is empty.
* **Speculative copies** (:class:`SpeculativeCopies`, Spark-style): when at
  least ``min_completed`` attempts have completed and a running attempt's
  elapsed time (``now - start``, overhead included) reaches ``factor *
  quantile(completed durations, quantile)``, an idle node launches a
  duplicate of that attempt's task — the **full original work, from
  scratch**, paying the idle node's own ``task_overhead``.  Among eligible
  victims the longest-elapsed wins (ties: lowest victim node index).  A
  task is copied **at most once per stage** (``has_copy`` marks original
  and copy).  First finisher wins: the winning attempt produces the task's
  only record; the losing attempt is cancelled at that instant, produces
  no record, and the freed node immediately re-enters the queue-pull /
  mitigation flow.  A cancel-vs-finish tie (both attempts' completion
  events at the same time) resolves by the engine's event order
  ``(time, node index)``: the lower-indexed node's completion is processed
  first and wins.  When no attempt is past threshold yet, the idle node
  schedules a re-check at the earliest instant one could cross it
  (``min over eligible attempts of start + threshold``).
* **Work stealing** (:class:`WorkStealing`): an idle node steals from the
  most-backlogged running attempt (largest remaining work, ties: lowest
  victim node index), provided the victim retains at least ``2 * grain``
  remaining.  The stolen amount is the unstarted **remainder split at a
  grain boundary**: ``floor(remaining / 2 / grain) * grain`` (so thief and
  victim each keep >= ``grain``).  The victim's attempt shrinks in place —
  its completion event is re-predicted from the steal instant; work it
  already executed stays executed.  The thief starts a new attempt of the
  stolen work (same ``task_id``, its own overhead), so a stolen task
  yields one :class:`~repro.core.simulator.TaskRecord` **per executed
  piece**.  Remaining work only shrinks over time, so no re-check timer is
  needed: new opportunities appear only at event instants, where the
  fixpoint re-offers every idle node.
* **Re-skew hand-off** (:class:`ReskewHandoff`, barrier-level — accepted
  only by ``run_job`` on :class:`~repro.core.engine.StaticSpec` stages):
  at the stage's program barrier, nodes still running past ``cutoff_factor
  * median(per-node finish offsets)`` are cut off at that instant; their
  residual (unexecuted) work is folded into the **next** stage's split,
  distributed proportionally to the observed per-node throughput of the
  cut stage (executed work / busy time).  A next-stage ``PullSpec`` simply
  scales (the shared queue absorbs residual wherever capacity is).  The
  final stage is never cut (there is no later split to fold into).  With
  homogeneous finishes the cutoff sits at/above the max finish and the
  policy is a no-op.

* **I/O-aware duplicates** (stages with effective I/O — finite shared
  uplink and at least one reading task): a duplicate launch must re-fetch
  its input, and it does so as a **new flow** through the engine's
  flow-shared uplink model, joining the same incremental per-datanode
  repricing primary readers use.  The semantics, shared by engine and
  oracle:

  - A **speculative copy** re-fetches the victim attempt's **full input
    bytes** from the datanode its
    :class:`~repro.core.hdfs_model.DuplicatePlacement` chooses (default:
    the original datanode, fairly sharing its uplink with the primary
    flow; ``"replica"`` reads the ring-adjacent replica instead).  The
    copy completes when both its re-fetch and its CPU work are done.
  - A **stolen remainder** re-fetches the stolen range's bytes — the
    ``amount / attempt work`` fraction of the attempt's input — from the
    placement-chosen datanode, and the victim stops fetching that range:
    its remaining bytes shrink by the moved bytes, clamped at zero (bytes
    it already streamed past the retained range are not refunded — the
    engine charges duplicate reads, never negative ones).  A drained
    victim flow leaves its uplink at the steal instant.
  - **Cancelling the loser frees its flow**: at the winner's completion
    instant the losing attempt's in-flight flow (if any) leaves its
    datanode's reader set and the survivors are repriced **causally at
    that instant — never retroactively** (the soundness property the
    engine's incremental repricing maintains everywhere).
  - The speculation **trigger gains an I/O cost term**: an attempt with
    input bytes crosses threshold at ``elapsed >= factor *
    quantile(done) + io_cost_per_mb * attempt_io_mb`` — a copy is only
    launched when the straggler is late enough that paying the re-fetch
    can still win.  ``io_cost_per_mb`` (seconds per MB, default 0)
    estimates the re-fetch rate; idle re-checks use the same per-attempt
    threshold.  Completed durations already include I/O time (durations
    are wall-clock ``finish - start``).

Policies are frozen (hashable) dataclasses so they can ride the hashable
``PullSpec``/``StaticSpec`` stage specs through ``run_job``'s solve caches.
The runtime monitor (``repro.runtime.ft.FleetMonitor``), the legacy
helper ``repro.core.straggler.speculative_copies``, and the engine all
share :meth:`SpeculativeCopies.should_speculate` — one at-threshold
(``>=``) trigger rule, so a task running exactly ``factor * quantile``
gets the same verdict from every exposure.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Sequence, Union

from repro.core.hdfs_model import DuplicatePlacement


class RunningAttempt(NamedTuple):
    """Observable state of one in-flight attempt, as the mitigation drivers
    (engine event calendar / test oracle) expose it to policies."""
    node: int           # node index running the attempt
    task_id: int
    start: float        # when the attempt started (overhead included after)
    work: float         # total work of this attempt
    remaining: float    # work not yet executed at the offer instant
    has_copy: bool      # a speculative copy of this task exists/existed
    io_mb: float = 0.0  # the attempt's input bytes (0 when I/O is not
    #                     effective: infinite uplink or no datanode)


class Speculate(NamedTuple):
    """Launch a duplicate of the victim node's running task on the idle
    node (full original work, from scratch)."""
    victim: int


class Steal(NamedTuple):
    """Move ``amount`` of the victim node's remaining work to the idle
    node as a new attempt."""
    victim: int
    amount: float


Action = Union[Speculate, Steal]

# At-threshold float guard: idle re-checks are scheduled at the exact
# crossing instant ``start + threshold``, and at a nonzero absolute start
# the round-trip ``(start + thr) - start`` can round a hair BELOW ``thr`` —
# the trigger would miss, no further re-check would be scheduled, and a
# shifted solve would silently diverge from its relative-0 twin (breaking
# the start-invariance run_job's solve caches rely on).  The guard mirrors
# the engine event loop's ``t + eps >= cpu_done`` causal comparisons.
_EPS = 1e-9


def quantile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile (numpy's default rule; q=0.5 is the
    median).  Pure Python so engine, oracle, and runtime advisors share one
    deterministic definition."""
    if not values:
        raise ValueError("quantile of empty sequence")
    s = sorted(values)
    h = q * (len(s) - 1)
    lo = math.floor(h)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (h - lo) * (s[hi] - s[lo])


@dataclass(frozen=True)
class SpeculativeCopies:
    """Spark-style quantile-triggered duplicate launch (module docstring).

    quantile:       which quantile of completed durations sets the baseline
    factor:         speculation threshold = factor * that quantile
    min_completed:  completions required before any copy may launch
    io_cost_per_mb: re-fetch cost term (s/MB): an attempt with input bytes
                    only triggers once its elapsed time also covers the
                    estimated cost of re-fetching its input (module
                    docstring, I/O-aware duplicates)
    placement:      where a copy re-fetches from
                    (:class:`~repro.core.hdfs_model.DuplicatePlacement`;
                    None = the original datanode)
    """
    quantile: float = 0.75
    factor: float = 1.5
    min_completed: int = 1
    io_cost_per_mb: float = 0.0
    placement: Optional[DuplicatePlacement] = None

    def __post_init__(self):
        if not 0.0 <= self.quantile <= 1.0:
            raise ValueError("quantile must be in [0, 1]")
        if self.factor <= 0.0:
            raise ValueError("factor must be positive")
        if self.min_completed < 1:
            raise ValueError("min_completed must be >= 1")
        if self.io_cost_per_mb < 0.0:
            raise ValueError("io_cost_per_mb must be >= 0")

    def threshold(self, done_durations: Sequence[float],
                  io_mb: float = 0.0) -> float:
        """Per-attempt trigger threshold: the quantile baseline plus the
        re-fetch cost term for the attempt's input bytes."""
        return (self.factor * quantile(done_durations, self.quantile)
                + self.io_cost_per_mb * io_mb)

    def should_speculate(self, done_durations: Sequence[float],
                         elapsed: float, io_mb: float = 0.0) -> bool:
        """The shared trigger rule (engine, FleetMonitor and the legacy
        ``straggler.speculative_copies`` helper all call this): enough
        completions and the attempt's elapsed time at/over its per-attempt
        threshold — ``>=`` with the module's 1e-9 float guard, so a task
        running exactly ``factor * quantile`` triggers in every
        exposure."""
        if len(done_durations) < self.min_completed:
            return False
        return elapsed + _EPS >= self.threshold(done_durations, io_mb)

    def offer(self, done_durations: Sequence[float],
              running: Sequence[RunningAttempt], now: float,
              ) -> Optional[Speculate]:
        """Pick the longest-elapsed past-threshold un-copied attempt (ties:
        lowest victim node index, via the ascending scan)."""
        if len(done_durations) < self.min_completed:
            return None
        best, best_elapsed = None, -math.inf
        for r in running:                      # ascending node index
            if r.has_copy:
                continue
            elapsed = now - r.start
            if (elapsed + _EPS >= self.threshold(done_durations, r.io_mb)
                    and elapsed > best_elapsed):
                best, best_elapsed = r, elapsed
        return None if best is None else Speculate(best.node)

    def next_check(self, done_durations: Sequence[float],
                   running: Sequence[RunningAttempt], now: float,
                   ) -> Optional[float]:
        """Earliest future instant an eligible attempt crosses its
        per-attempt threshold (None when nothing can: all copied, or too
        few completions — completions themselves are events that
        re-offer)."""
        if len(done_durations) < self.min_completed:
            return None
        t = min((r.start + self.threshold(done_durations, r.io_mb)
                 for r in running if not r.has_copy),
                default=None)
        return t if t is not None and t > now else None


@dataclass(frozen=True)
class WorkStealing:
    """Idle-node work stealing, split at a grain boundary (module
    docstring).  ``grain`` is the indivisible work quantum (e.g. one HDFS
    block / one microbatch in work units).  On stages with effective I/O
    the thief re-fetches the stolen range's bytes as a new flow from the
    ``placement``-chosen datanode (None = the victim's datanode)."""
    grain: float
    placement: Optional[DuplicatePlacement] = None

    def __post_init__(self):
        if self.grain <= 0.0:
            raise ValueError("grain must be positive")

    def offer(self, done_durations: Sequence[float],
              running: Sequence[RunningAttempt], now: float,
              ) -> Optional[Steal]:
        best, best_remaining = None, 0.0
        for r in running:                      # ascending node index
            if r.remaining >= 2.0 * self.grain and r.remaining > best_remaining:
                best, best_remaining = r, r.remaining
        if best is None:
            return None
        amount = math.floor(best.remaining / 2.0 / self.grain) * self.grain
        return Steal(best.node, amount)

    def next_check(self, done_durations: Sequence[float],
                   running: Sequence[RunningAttempt], now: float,
                   ) -> Optional[float]:
        return None       # remaining work only shrinks; events re-offer


@dataclass(frozen=True)
class ReskewHandoff:
    """Barrier-level HeMT re-skew hand-off (module docstring): cut
    stragglers at ``cutoff_factor * median`` of the stage's per-node finish
    offsets and fold the residual into the next stage's split."""
    cutoff_factor: float = 1.5

    def __post_init__(self):
        if self.cutoff_factor < 1.0:
            raise ValueError("cutoff_factor must be >= 1.0")

    def cutoff(self, finish_offsets: Sequence[float]) -> float:
        """Cut instant (stage-relative) given offsets of nodes that ran."""
        return self.cutoff_factor * quantile(finish_offsets, 0.5)


EventPolicy = (SpeculativeCopies, WorkStealing)


def is_event_policy(mitigation: object) -> bool:
    """True for policies the event calendar applies inside a stage (vs.
    barrier-level policies applied by ``run_job``)."""
    return isinstance(mitigation, EventPolicy)


def fold_residual(works: Sequence[float], residual: float,
                  throughputs: Sequence[float]) -> List[float]:
    """Fold ``residual`` work into a static split, proportional to observed
    throughputs (uniform when all throughputs are zero — nothing observed).
    Used by ``run_job``'s re-skew hand-off; restated independently by the
    differential tests."""
    if residual <= 0.0:
        return list(works)
    total = sum(throughputs)
    n = len(works)
    if total <= 0.0:
        return [w + residual / n for w in works]
    return [w + residual * v / total for w, v in zip(works, throughputs)]
