"""HeMT grain planner — the paper's scheduler as a first-class feature of
the training runtime (HeMT-DP, DESIGN.md §2).

A global training step processes G grains (fixed-shape microbatches).
Slices (SPMD islands / pods) are the paper's "executors"; the planner
assigns per-slice grain counts k_i ~ v_i (AR(1)-estimated slice throughput,
grains/sec), so all slices reach the cross-slice gradient barrier together.

HomT mode (the baseline the paper compares against) assigns grains evenly
and lets fast slices steal pending grains from a shared queue — Claim 1
bounds the barrier idle time by one grain-time on the slowest slice, at the
cost of per-steal overhead (host RPC + input re-route).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.estimators import ARSpeedEstimator
from repro.core.partitioner import even_split, proportional_split


@dataclass
class SlicePlan:
    slice_names: List[str]
    grains: List[int]              # per-slice grain counts, sum = G
    weights: List[float]           # normalized speed estimates used
    mode: str                      # "hemt" | "homt"

    def grains_for(self, name: str) -> int:
        return self.grains[self.slice_names.index(name)]


class GrainPlanner:
    """Per-job-class planner with online speed adaptation.

    alpha: AR(1) forgetting factor (paper §5.1). The default 0.3 keeps some
    memory to average out per-grain difficulty variation while staying
    responsive to interference changes (paper's Fig 7 uses 0.0; configurable).
    """

    def __init__(self, slices: Sequence[str], alpha: float = 0.3,
                 min_grains: int = 1, mode: str = "hemt"):
        if mode not in ("hemt", "homt"):
            raise ValueError(mode)
        self.slices = list(slices)
        self.estimator = ARSpeedEstimator(alpha=alpha)
        self.min_grains = min_grains
        self.mode = mode
        self.step_log: List[SlicePlan] = []

    # ------------------------------------------------------------------
    def plan(self, total_grains: int) -> SlicePlan:
        n = len(self.slices)
        if self.mode == "homt" or not self.estimator.known():
            grains = even_split(total_grains, n)
            weights = [1.0 / n] * n
        else:
            speeds = self.estimator.speeds(self.slices)
            s = sum(speeds)
            weights = [v / s for v in speeds]
            grains = proportional_split(total_grains, speeds,
                                        min_share=self.min_grains)
        plan = SlicePlan(list(self.slices), grains, weights, self.mode)
        self.step_log.append(plan)
        return plan

    def observe(self, slice_name: str, grains_done: int, elapsed_s: float,
                ) -> None:
        if grains_done > 0 and elapsed_s > 0:
            self.estimator.observe(slice_name, grains_done, elapsed_s)

    def observe_step(self, results: Dict[str, Dict[str, float]]) -> None:
        """results: slice -> {"grains": int, "elapsed": seconds}."""
        for name, r in results.items():
            self.observe(name, r["grains"], r["elapsed"])

    # ------------------------------------------------------------------
    # elasticity (paper §5.1 cold-start rule + straggler re-skew)
    def resize(self, new_slices: Sequence[str]) -> None:
        """Slice set changed (preemption / scale-up). Estimates of surviving
        slices are kept; new slices get the cold-start mean automatically."""
        gone = set(self.slices) - set(new_slices)
        for g in gone:
            self.estimator.forget(g)
        self.slices = list(new_slices)

    def predicted_barrier_idle(self, plan: SlicePlan) -> float:
        """Predicted sync delay of a plan given current speed estimates
        (seconds, relative): max_i k_i/v_i - min_i k_i/v_i."""
        speeds = self.estimator.speeds(plan.slice_names)
        times = [k / v for k, v in zip(plan.grains, speeds)]
        return max(times) - min(times)


@dataclass
class WorkStealingQueue:
    """HomT grain queue with steal accounting (per-steal overhead modeled
    by the runtime; Claim 1 applies to the resulting schedule)."""
    pending: List[int] = field(default_factory=list)
    steals: int = 0

    def seed(self, total_grains: int) -> None:
        self.pending = list(range(total_grains))

    def pull(self, k: int = 1) -> List[int]:
        got = self.pending[:k]
        del self.pending[:k]
        if got:
            self.steals += 1
        return got

    def __len__(self) -> int:
        return len(self.pending)
