"""Fault injection & recovery for the whole-job engine.

The paper's setting is the *public cloud*: capacity is not just
heterogeneous, it is revocable.  Nodes crash mid-stage, spot instances are
preempted with a short warning, and the in-flight work of a dead node is
simply gone.  The engine's multi-segment profiles and burstable credits can
only express graceful *slowdowns*; this module adds the loss of a node and
its in-flight attempt, so the HomT-vs-HeMT-vs-OA-HeMT comparison gains the
overhead-vs-resilience axis (HomT's pull queue self-heals by construction —
Claim 1 — while a static split must retry or eat the loss).

Fault models are frozen (hashable) dataclasses composed into a per-run
:class:`FaultTrace`, consumed by ``engine.run_stage_events(faults=...)``
and threaded through whole jobs by ``engine.run_job(faults=...)``.  All
event times are **absolute** (the same clock as ``start_time``), so one
trace describes a whole multi-stage job.

Exact semantics (shared verbatim by the engine and the naive full-rescan
fault oracle in tests/test_faults.py):

* **Node state.** A :class:`NodeCrash` makes its node *dead* during
  ``[at, recover_at)`` (forever when ``recover_at`` is None).  A
  :class:`SpotPreemption` makes its node *draining* during
  ``[at, at + warning)`` and dead from ``at + warning`` on (spot capacity
  never comes back).  A draining node keeps executing its current attempt
  but **pulls no new work** — the warning is the drain window.  Per node,
  event intervals must be disjoint and a preemption must be the node's
  last event.

* **Priming.** A node dead or draining at the stage start is not primed.
  A node dead at the start with **no future recovery** hands its private
  queue (HeMT macrotasks) to survivors immediately — see *re-queueing*;
  with a future recovery its queue waits and is executed on recovery.
  Exception: zero-work zero-byte tasks (an adaptive alive-masked replan
  parks them on dead nodes) never wait out a recovery — they redistribute
  immediately so the stage does not serialize on a no-op.

* **Kill instant** (``at`` of a crash; ``at + warning`` of a preemption):
  the victim's in-flight attempt is killed.  Work it executed is lost,
  unless the run checkpoints at grain boundaries
  (``FaultTrace.checkpoint_grain`` g > 0): then
  ``floor(executed / g) * g`` survives as a partial
  :class:`~repro.core.simulator.TaskRecord` ending at the kill instant
  (this is also how a preemption "drains at a grain boundary" — the drain
  window lets more grains complete before the kill).  The attempt's
  in-flight uplink flow is freed through the engine's causal ``drop_flow``
  repricing — survivors speed up at that instant, never retroactively.
  Killed attempts never feed the mitigation policies' completed-duration
  statistics.  A completion tied exactly with its node's kill instant is
  killed (fault sub-events order before same-time completions of the same
  node; across nodes the lower index goes first, as everywhere in the
  engine).

* **Speculation composition.** If the killed attempt has a racing
  speculative copy, the copy survives its victim's death and becomes the
  task's only (primary) attempt: nothing is re-queued and no retry is
  charged.

* **Re-queueing & retries** (:class:`RetryPolicy`): the killed attempt's
  residual work ``attempt_work - saved`` re-enters the stage as a fresh
  task with a proportional share of the attempt's input bytes (a restart
  re-fetches input for work it still has to do; checkpointed work's bytes
  are not re-fetched).  Destination:

  - *pull*: the back of the shared deque (the queue self-heals);
  - *static, victim recovers later*: the front of the victim's own queue,
    re-executed on recovery;
  - *static, victim dead for good*: redistributed to the candidate with
    the least load (remaining work of its current attempt plus queued
    work; ties to the lowest index) among alive non-draining nodes — or,
    when none is alive, the dead node with the earliest future recovery.
    With no candidate at all the work is stranded (abandoned).

  Each re-queue of a task id counts against ``retry.max_attempts`` (the
  initial launch is attempt 1; once ``max_attempts`` launches have been
  consumed, further kills abandon the task's residual work).  The k-th
  re-launch pays ``relaunch_overhead * backoff**(k-1)`` extra seconds at
  its next launch, wherever it lands (at most one pending re-launch
  penalty per task id).

* **Recovery instant**: the node is alive again and immediately pulls
  from its queue (its own private queue for static stages, the shared
  deque for pull); with mitigation it re-enters the offer fixpoint.
  Mitigation never offers a dead or draining node work.

* **Whole jobs** (``run_job(faults=...)``): faults break start-invariance,
  so a stage whose ``[start, completion]`` window overlaps any fault
  window (dead interval of a crash, ``[at, inf)`` of a preemption) is
  solved on the absolute-time event path and **bypasses both solve
  caches** — the start-invariant LRU only ever holds fault-free solves
  (pinned by the no-poisoning test).  At barriers, abandoned (lost) work
  of a fault-affected :class:`~repro.core.engine.StaticSpec` carrying
  :class:`~repro.core.speculation.ReskewHandoff` folds forward into the
  next stage's split proportional to observed survivor throughput, and an
  :class:`~repro.core.engine.AdaptivePlan` re-splits upcoming stages over
  the nodes alive at the barrier (spot warnings are visible to the
  scheduler — that is what the warning is for), survivors keeping their
  AR(1) estimates; a crash marked ``cold_restart=True`` forgets the
  node's estimate at its recovery barrier so the replacement cold-starts
  at the survivor mean (paper §5.1's ``L_k^o`` rule).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

ALIVE, DRAINING, DEAD = 0, 1, 2

# ordering of same-instant fault sub-events on one node: a recovery ending
# one interval precedes the kill starting the next; a drain warning (which
# only exists with warning > 0) can never tie with its own kill.  Public:
# the resident calendar (repro.core.resident) extends this ranking with
# resize (3) and arrival (4) events for its all-externals-first ordering.
SUB_EVENT_RANK = {"recover": 0, "drain": 1, "kill": 2}
_RANK = SUB_EVENT_RANK   # backwards-compatible alias


@dataclass(frozen=True)
class NodeCrash:
    """Node ``node`` dies abruptly at absolute time ``at``; optionally a
    replacement comes up at ``recover_at``.  ``cold_restart`` marks the
    recovered instance as a *new* machine: an adaptive ``run_job`` forgets
    its AR(1) estimate at the recovery barrier (paper §5.1 cold start)."""
    node: int
    at: float
    recover_at: Optional[float] = None
    cold_restart: bool = False

    def __post_init__(self):
        if self.node < 0:
            raise ValueError("node index must be >= 0")
        if self.recover_at is not None and self.recover_at <= self.at:
            raise ValueError("recover_at must be after the crash instant")

    @property
    def dead_until(self) -> float:
        return math.inf if self.recover_at is None else self.recover_at


@dataclass(frozen=True)
class SpotPreemption:
    """Node ``node`` receives a preemption warning at ``at`` and is
    reclaimed at ``at + warning``; during the warning window it drains —
    keeps executing its current attempt, pulls nothing new."""
    node: int
    at: float
    warning: float = 0.0

    def __post_init__(self):
        if self.node < 0:
            raise ValueError("node index must be >= 0")
        if self.warning < 0.0:
            raise ValueError("warning lead time must be >= 0")

    @property
    def kill_at(self) -> float:
        return self.at + self.warning


FaultEvent = Union[NodeCrash, SpotPreemption]


@dataclass(frozen=True)
class RetryPolicy:
    """Re-queue semantics for killed attempts: a task id may be launched
    ``max_attempts`` times in total; the k-th re-launch adds
    ``relaunch_overhead * backoff**(k-1)`` seconds before its node's own
    task overhead (container re-provisioning, state re-load)."""
    max_attempts: int = 3
    relaunch_overhead: float = 0.0
    backoff: float = 1.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.relaunch_overhead < 0.0:
            raise ValueError("relaunch_overhead must be >= 0")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1.0")

    def penalty(self, relaunch_index: int) -> float:
        """Extra launch latency of the k-th re-launch (k >= 1)."""
        return self.relaunch_overhead * self.backoff ** (relaunch_index - 1)


@dataclass(frozen=True)
class FaultTrace:
    """A run's faults: events + retry policy + checkpoint granularity.

    ``checkpoint_grain`` g > 0 preserves ``floor(executed / g) * g`` of a
    killed attempt's work as a partial record (g == 0: everything in
    flight is lost).  Frozen and hashable so traces can ride frozen specs
    and be compared/deduped; events are kept sorted by ``(at, node)``.
    """
    events: Tuple[FaultEvent, ...] = ()
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    checkpoint_grain: float = 0.0

    def __post_init__(self):
        if self.checkpoint_grain < 0.0:
            raise ValueError("checkpoint_grain must be >= 0")
        events = tuple(sorted(self.events, key=lambda e: (e.at, e.node)))
        object.__setattr__(self, "events", events)
        per_node = {}
        for ev in events:
            per_node.setdefault(ev.node, []).append(ev)
        for node, evs in per_node.items():
            open_until = -math.inf
            for ev in evs:
                if ev.at < open_until:
                    raise ValueError(
                        f"overlapping fault events on node {node}")
                open_until = (math.inf if isinstance(ev, SpotPreemption)
                              else ev.dead_until)

    # -- state queries ------------------------------------------------------
    def state_at(self, node: int, t: float) -> int:
        """ALIVE / DRAINING / DEAD status of ``node`` at absolute ``t``."""
        for ev in self.events:
            if ev.node != node:
                continue
            if isinstance(ev, SpotPreemption):
                if ev.at <= t < ev.kill_at:
                    return DRAINING
                if t >= ev.kill_at:
                    return DEAD
            elif ev.at <= t < ev.dead_until:
                return DEAD
        return ALIVE

    def alive_mask(self, n: int, t: float) -> List[bool]:
        """Which of ``n`` nodes are alive (not dead, not draining) at t."""
        return [self.state_at(i, t) == ALIVE for i in range(n)]

    def recovery_after(self, node: int, t: float) -> Optional[float]:
        """The recovery instant of the dead interval containing ``t``
        (None when the node is not dead at t, or dead for good)."""
        for ev in self.events:
            if (isinstance(ev, NodeCrash) and ev.node == node
                    and ev.recover_at is not None
                    and ev.at <= t < ev.recover_at):
                return ev.recover_at
        return None

    # -- run_job plumbing ---------------------------------------------------
    def windows(self) -> Tuple[Tuple[float, float], ...]:
        """Per-event affected interval ``[start, end)``: the dead window of
        a crash, ``[at, inf)`` for a preemption (drain included)."""
        return tuple(
            (ev.at, math.inf) if isinstance(ev, SpotPreemption)
            else (ev.at, ev.dead_until)
            for ev in self.events)

    def overlaps(self, t0: float, t1: float, eps: float = 1e-9) -> bool:
        """True if any fault window intersects the stage window
        ``[t0, t1]`` (inclusive at t1: a completion tied with a kill is
        killed, so a window starting exactly at the stage end affects
        it)."""
        return any(s < t1 + eps and e > t0 + eps for s, e in self.windows())

    def sub_events(self, start_time: float,
                   ) -> List[Tuple[float, int, str]]:
        """Kill / drain / recover sub-events strictly after ``start_time``
        as ``(t, node, kind)``, in processing order ``(t, node, rank)``;
        state already in force at ``start_time`` is queried via
        :meth:`state_at` instead."""
        out: List[Tuple[float, int, str]] = []
        for ev in self.events:
            if isinstance(ev, SpotPreemption):
                if ev.warning > 0.0 and ev.at > start_time:
                    out.append((ev.at, ev.node, "drain"))
                if ev.kill_at > start_time:
                    out.append((ev.kill_at, ev.node, "kill"))
            else:
                if ev.at > start_time:
                    out.append((ev.at, ev.node, "kill"))
                if ev.recover_at is not None and ev.recover_at > start_time:
                    out.append((ev.recover_at, ev.node, "recover"))
        out.sort(key=lambda e: (e[0], e[1], _RANK[e[2]]))
        return out

    def cold_restarts(self) -> List[Tuple[float, int]]:
        """``(recover_at, node)`` of crashes whose replacement is a fresh
        machine — the adaptive loop forgets their estimates at the
        recovery barrier."""
        return sorted((ev.recover_at, ev.node) for ev in self.events
                      if isinstance(ev, NodeCrash) and ev.cold_restart
                      and ev.recover_at is not None)

    def max_node(self) -> int:
        return max((ev.node for ev in self.events), default=-1)

    def restrict(self, keep: Sequence[int]) -> "FaultTrace":
        """The trace over a surviving subset of nodes: events of dropped
        nodes are removed and survivors renumbered to their position in
        ``keep`` — elastic drivers that shrink the fleet mid-run remap the
        trace alongside the slice list."""
        pos = {orig: new for new, orig in enumerate(keep)}
        kept = tuple(
            SpotPreemption(pos[ev.node], ev.at, ev.warning)
            if isinstance(ev, SpotPreemption)
            else NodeCrash(pos[ev.node], ev.at, ev.recover_at,
                           ev.cold_restart)
            for ev in self.events if ev.node in pos)
        return FaultTrace(kept, self.retry, self.checkpoint_grain)

    def shift(self, dt: float) -> "FaultTrace":
        """The same trace on a clock offset by ``dt`` (drivers whose node
        profiles are re-anchored to a moving fleet clock shift the trace
        alongside)."""
        moved = tuple(
            SpotPreemption(ev.node, ev.at + dt, ev.warning)
            if isinstance(ev, SpotPreemption)
            else NodeCrash(ev.node, ev.at + dt,
                           None if ev.recover_at is None
                           else ev.recover_at + dt, ev.cold_restart)
            for ev in self.events)
        return FaultTrace(moved, self.retry, self.checkpoint_grain)


def lost_work(planned_total: float, executed_total: float,
              eps: float = 1e-9) -> float:
    """Work a fault-affected stage abandoned (retries exhausted / stranded):
    planned minus recorded, clamped at zero (a winning speculative copy
    records its full work even when its victim also checkpointed a partial
    piece, which can push recorded above planned)."""
    lost = planned_total - executed_total
    return lost if lost > eps else 0.0
