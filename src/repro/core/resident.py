"""Resident multi-tenant cluster loop: splice-in recovery + fair-share jobs.

``run_window``-style drivers used to re-enter ``run_job`` from scratch
after every mid-window event, discarding in-flight schedule state.  The
paper's own premise — capacity change is a *re-skew*, not a restart —
argues for a **resident** event calendar instead: one loop that owns the
cluster for its whole lifetime, extends each job's barrier sequence
lazily, and lets fault recoveries (:mod:`repro.core.faults` traces) and
elastic resizes **splice into** the adaptive schedule.  Survivors keep
their AR(1) state, lost work folds forward, nothing restarts.

On top of the single-job splice the calendar adds **multi-job
admission**: concurrent jobs space-share the nodes under weighted fair
shares and share the per-datanode uplinks through the engine's
incremental flow repricing (readers of a datanode are global across
jobs — PR 5's machinery, now fair-sharing across *jobs*, not just
tasks), with per-job deadlines/SLOs, retry budgets with backoff, and
graceful degradation: when capacity drops below the admitted load the
lowest-priority jobs are *shed* (paused, attempts checkpointed, no
retry charge) instead of failing the fleet, and every re-quantization
happens at the owning job's next barrier.

Exact semantics (shared verbatim by :class:`ResidentCalendar` and the
naive restart-per-event oracle in tests/test_resident.py — the oracle
recomputes rates, next events and partitions from scratch at every
event, while the calendar splices incrementally; both must agree to
1e-9):

* **Ranking & fair shares.**  Active jobs (arrived, not finished, not
  stranded) are ranked by ``(priority, arrival, name)`` — lower
  priority value is more important.  With ``U`` usable nodes (alive,
  not draining) the first ``k = min(n_active, U)`` ranked jobs are
  *entitled*; their node shares are ``proportional_split(U, weights,
  min_share=1)`` (largest-remainder, every entitled job gets >= 1
  node); the rest have share 0 — see *shedding*.

* **Lazy sticky assignment.**  Assignments change only at these
  points, never continuously:

  - a job's **own barrier**: its assignment is trimmed/grown to its
    share — it keeps its lowest-indexed held usable nodes up to the
    share, releases the rest, then takes free nodes ascending;
  - **node loss** (kill / drain start / resize drop): the node leaves
    its owner immediately and is *not* replaced mid-stage — the job
    runs narrow until its next barrier (the splice);
  - a mid-stage job that loses **all** nodes, and any waiting/stalled
    job, is rescued at the next *rescue pass* (run after every
    external event, barrier, admission and completion): ranked jobs
    with no nodes and a positive share take free nodes ascending, up
    to the share.  Running jobs that still hold >= 1 node never grab
    free nodes mid-stage; a recovered node idles in the free pool
    until some job's barrier or rescue claims it.

* **Compatibility (sparse task->server pruning).**  A job created with
  ``allowed={names}`` only ever takes nodes whose names are in the
  set — at barrier growth and at rescue; its fair share is computed
  as usual, so capacity the job cannot hold stays in the free pool
  for lower-ranked jobs in the same pass.  This is the resident form
  of the rate-matrix pruning knob (Zhao & Mukherjee 2023, PAPERS.md):
  request classes whose service rate on a server is pruned simply
  never land there.  A job whose allowed nodes never free up waits
  (and strands if the calendar drains first).

* **Shedding (graceful degradation).**  A rebalance that finds a
  node-holding job with share 0 sheds it: every in-flight attempt is
  killed *with* the checkpoint-grain flooring of a fault kill but
  *without* a retry charge, the residual re-enters the job's overflow
  queue, its nodes return to the free pool, and the job stalls until
  a rescue pass re-admits it.  Queued work is untouched.

* **Stage materialization.**  At admission / each barrier the stage's
  total work is ``spec total + carry`` (carry = the previous stage's
  lost work, folded forward; jobs created with ``fold_lost=False``
  eat the loss instead — the windowed driver's historical contract).
  A :class:`~repro.core.engine.StaticSpec` is re-quantized to the
  current assignment: the *base split* is the job's ``proportions``
  (by node name, missing names weight 1.0) when given, else the
  spec's own works when the width matches and carry == 0, else even;
  an adaptive job then runs ``AdaptivePlan.replan`` on the base spec
  (fold first, re-plan second — exactly ``run_job``).  One macrotask
  per assigned node launches immediately (zero-work macrotasks still
  pay the overhead); ``io_mb`` splits works-proportionally.  A
  :class:`~repro.core.engine.PullSpec` enqueues its tasks (works
  scaled uniformly by the carry, as ``run_job`` folds pull specs)
  into the job's shared deque and assigned idle nodes pull ascending.

* **Execution & flows.**  Identical to ``run_stage_events``: a task
  completes when its CPU work (overhead + profile integral) and its
  I/O are both done; active readers of a datanode — *across all
  jobs* — share ``uplink_bw`` equally, repriced causally at every
  reader-set change.

* **Refill.**  An idle usable node owned by job j takes, in order:
  the head of j's overflow deque (requeued residuals), then the head
  of j's shared pull deque.  Static stages hand work to nodes only at
  materialization and through the overflow queue — residents do not
  use the single-stage engine's wait-for-recovery / least-loaded
  destinations: the next idle owned node is the least-loaded by
  construction.

* **Kills, retries, SLOs.**  A fault kill checkpoints
  ``floor(executed / g) * g`` (g = the trace's ``checkpoint_grain``)
  as executed work, then requeues the residual to the owner's
  overflow per the *job's* :class:`~repro.core.faults.RetryPolicy`
  (each requeue of a task id counts against ``max_attempts``; the
  k-th relaunch pays ``relaunch_overhead * backoff**(k-1)`` at its
  next launch; exhausted retries abandon the residual, which folds
  forward at the barrier).  A job finishing at ``t`` attains its SLO
  iff ``t <= deadline`` (jobs without deadlines always attain).  Jobs
  still unfinished when the calendar drains (no events left, no
  usable capacity coming back) are **stranded**: completion = inf,
  SLO missed.

* **Event order.**  All external events at an instant process before
  any completion at that instant, ordered ``(t, rank, key)`` with
  rank recover(0) < drain(1) < kill(2) < resize(3) < arrival(4) (the
  fault ranks are :data:`repro.core.faults.SUB_EVENT_RANK`); within a
  resize, drops apply before adds.  Completions order by ``(t, node
  index)``.  After each external event one rebalance (+ rescue) pass
  runs.

* **Recovery modes.**  ``recovery="splice"`` (default) is everything
  above.  ``recovery="restart"`` is the baseline the benchmarks beat:
  after *every* external capacity event (kill / drain / recover /
  resize — not arrivals) every running job abandons its stage —
  in-flight attempts cancelled with nothing saved, queues cleared,
  partial stage statistics discarded — and re-materializes it from
  scratch at that instant over its current nodes (the old
  ``run_window`` re-enter-per-event behavior, made explicit).

* **Tail fast-forward (the resumable-``run_job`` splice).**  In
  splice mode, when a barrier finds exactly one unfinished job, no
  pending external events, zero carry and the job holding every
  usable node, the rest of its schedule is handed to
  ``run_job(resume=JobContinuation(...))`` — the remaining stages
  re-based to the surviving width — so the tail runs through the
  cached closed forms instead of the event loop.  The oracle keeps
  looping; both must agree to 1e-9.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.engine import (
    AdaptivePlan, JobContinuation, ProfileCursor, PullSpec, StageSummary,
    StaticSpec, run_job,
)
from repro.core.faults import (
    DEAD, DRAINING, SUB_EVENT_RANK, FaultTrace, RetryPolicy, lost_work,
)
from repro.core.partitioner import hemt_split_floats, proportional_split
from repro.core.simulator import SimNode, SimTask

_EPS = 1e-9

_EXT_RANK = dict(SUB_EVENT_RANK, resize=3, arrive=4)


# --------------------------------------------------------------------------
# job & event models
# --------------------------------------------------------------------------

@dataclass
class ResidentJob:
    """One admitted job: stages + scheduling identity + SLO.

    ``priority`` ranks jobs (lower = more important), ``weight`` sizes the
    fair share among entitled jobs, ``deadline`` is the absolute SLO
    instant, ``retry`` is the *job's* kill-requeue budget, ``adaptive``
    (an :class:`~repro.core.engine.AdaptivePlan`, optionally sharing a
    scheduler's estimator) re-splits static stages at every barrier,
    ``proportions`` (node name -> weight) is the static split of a
    non-adaptive job (the "stale HeMT" baseline), ``fold_lost=False``
    eats abandoned work instead of folding it into the next stage,
    ``allowed`` (a set of node names) restricts which nodes the job may
    ever hold — the sparse task->server compatibility mask of the
    rate-matrix pruning idea (see the module docstring).
    Stage specs must not carry mitigation policies — the resident loop's
    recovery *is* the mitigation."""
    name: str
    stages: Tuple[object, ...]
    arrival: float = 0.0
    priority: int = 0
    weight: float = 1.0
    deadline: Optional[float] = None
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    adaptive: Optional[AdaptivePlan] = None
    proportions: Optional[Dict[str, float]] = None
    fold_lost: bool = True
    allowed: Optional[frozenset] = None

    def __post_init__(self):
        if not self.stages:
            raise ValueError(f"job {self.name!r} has no stages")
        if self.weight <= 0.0:
            raise ValueError("weight must be positive")
        if self.allowed is not None:
            self.allowed = frozenset(self.allowed)
            if not self.allowed:
                raise ValueError(
                    f"job {self.name!r} has an empty allowed set "
                    "(omit the mask to allow every node)")
        for spec in self.stages:
            if not isinstance(spec, (PullSpec, StaticSpec)):
                raise ValueError("stages must be PullSpec/StaticSpec")
            if spec.mitigation is not None:
                raise ValueError(
                    "resident jobs carry no per-stage mitigation policies "
                    "(splice-in recovery and barrier folds are built in)")


@dataclass(frozen=True)
class ResizeEvent:
    """Elastic fleet change at ``at``: ``drop`` removes cluster node
    indices for good (in-flight attempts requeue with checkpoint credit,
    no retry charge), ``add`` appends new nodes (absolute-clock profiles,
    fresh names) to the free pool."""
    at: float
    add: Tuple[SimNode, ...] = ()
    drop: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.at < 0.0:
            raise ValueError("resize time must be >= 0")
        if any(i < 0 for i in self.drop):
            raise ValueError("drop indices must be >= 0")


@dataclass
class JobOutcome:
    """Per-job result: completion/SLO plus per-stage summaries and the
    planned per-node split of every static stage (None for pull stages) —
    how drivers recover barrier assignments from a record-free run."""
    name: str
    completion: float
    deadline: Optional[float]
    attained: bool
    status: str                       # "done" | "stranded"
    admitted_at: Optional[float]
    stages: List[StageSummary]
    planned: List[Optional[Dict[str, float]]]
    lost: float = 0.0                 # work abandoned for good
    retries: int = 0                  # kill-requeues charged
    sheds: int = 0                    # times degraded to zero nodes


@dataclass
class ResidentResult:
    outcomes: Dict[str, JobOutcome]
    makespan: float                   # last finite job completion
    alive: List[str]                  # usable node names at calendar end

    def attainment(self) -> float:
        """Fraction of deadline-carrying jobs that met their SLO (1.0
        when no job carries one)."""
        slo = [o for o in self.outcomes.values() if o.deadline is not None]
        if not slo:
            return 1.0
        return sum(o.attained for o in slo) / len(slo)


def fair_shares(ranked: Sequence[Tuple[str, float]], capacity: int,
                ) -> Dict[str, int]:
    """Node shares of rank-ordered ``(name, weight)`` jobs over
    ``capacity`` usable nodes: the first ``min(n, capacity)`` jobs split
    the capacity proportionally to weight with a floor of one node each;
    the rest get 0 (shed).  Pure policy — shared by the calendar and the
    differential oracle."""
    shares = {name: 0 for name, _ in ranked}
    k = min(len(ranked), capacity)
    if k:
        entitled = ranked[:k]
        for (name, _), s in zip(
                entitled,
                proportional_split(capacity, [w for _, w in entitled],
                                   min_share=1)):
            shares[name] = s
    return shares


# --------------------------------------------------------------------------
# internal per-job runtime state
# --------------------------------------------------------------------------

class _JobState:
    __slots__ = (
        "job", "status", "arrived", "admitted_at", "nodes", "stage_idx",
        "stage_start", "stage_total", "carry", "pending_materialize",
        "open_tasks", "overflow", "shared", "exec_work", "counts", "fin",
        "planned_dict", "requeues", "penalty", "task_seq", "cold",
        "summaries", "planned", "completion", "lost", "retries", "sheds",
    )

    def __init__(self, job: ResidentJob, cold: List[Tuple[float, int]]):
        self.job = job
        self.status = "idle"          # "idle" | "running" | "done"
        self.arrived = False
        self.admitted_at: Optional[float] = None
        self.nodes: List[int] = []
        self.stage_idx = 0
        self.stage_start = 0.0
        self.stage_total = 0.0
        self.carry = 0.0
        self.pending_materialize = True
        self.open_tasks = 0
        self.overflow: Deque[SimTask] = deque()
        self.shared: Deque[SimTask] = deque()
        self.exec_work: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.fin: Dict[str, float] = {}
        self.planned_dict: Optional[Dict[str, float]] = None
        self.requeues: Dict[int, int] = {}
        self.penalty: Dict[int, float] = {}
        self.task_seq = 0
        self.cold = deque(cold)       # pending cold-restart forgettings
        self.summaries: List[StageSummary] = []
        self.planned: List[Optional[Dict[str, float]]] = []
        self.completion = math.inf
        self.lost = 0.0
        self.retries = 0
        self.sheds = 0

    def rank(self) -> Tuple:
        return (self.job.priority, self.job.arrival, self.job.name)

    def active(self) -> bool:
        return self.arrived and self.status != "done"

    def next_tid(self) -> int:
        self.task_seq += 1
        return self.task_seq


# --------------------------------------------------------------------------
# the calendar
# --------------------------------------------------------------------------

class ResidentCalendar:
    """A resident cluster scheduler (single-use: build, :meth:`run`, read
    the :class:`ResidentResult`).  See the module docstring for the
    normative semantics; ``recovery`` selects ``"splice"`` (default) or
    the ``"restart"``-per-event baseline."""

    def __init__(self, nodes: Sequence[SimNode],
                 uplink_bw: Optional[float] = None,
                 faults: Optional[FaultTrace] = None,
                 resizes: Sequence[ResizeEvent] = (),
                 recovery: str = "splice"):
        if recovery not in ("splice", "restart"):
            raise ValueError("recovery must be 'splice' or 'restart'")
        # an event-free trace still configures the checkpoint grain (sheds
        # and resize drops checkpoint too); only the event machinery is
        # skippable
        self.ckpt_grain = faults.checkpoint_grain if faults is not None \
            else 0.0
        if faults is not None and not faults.events:
            faults = None
        self.nodes = list(nodes)
        self.uplink_bw = uplink_bw if uplink_bw else None
        self.faults = faults
        self.resizes = sorted(resizes, key=lambda r: r.at)
        self.recovery = recovery
        n_total = len(self.nodes) + sum(len(r.add) for r in self.resizes)
        if faults is not None and faults.max_node() >= n_total:
            raise ValueError(
                f"fault trace names node {faults.max_node()} but the "
                f"calendar ever has {n_total} nodes")
        self._ran = False

    # ------------------------------------------------------------------
    def run(self, jobs: Sequence[ResidentJob]) -> ResidentResult:
        if self._ran:
            raise RuntimeError("ResidentCalendar is single-use")
        self._ran = True
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError("job names must be unique")
        if not jobs:
            return ResidentResult({}, 0.0, [nd.name for nd in self.nodes])
        fast = self._whole_job_fast_path(jobs)
        if fast is not None:
            return fast
        return self._run_loop(jobs)

    # ------------------------------------------------------------------
    def _whole_job_fast_path(self, jobs) -> Optional[ResidentResult]:
        """One job, arrival 0, no externals: resident semantics coincide
        with ``run_job`` exactly (full assignment at every barrier, no
        splice points), so delegate to the closed forms + solve LRU."""
        if (len(jobs) != 1 or self.faults is not None or self.resizes
                or self.recovery != "splice"):
            return None
        job = jobs[0]
        if job.arrival > 0.0 or job.proportions is not None:
            return None
        if job.allowed is not None \
                and not {nd.name for nd in self.nodes} <= job.allowed:
            return None
        n = len(self.nodes)
        if any(isinstance(s, StaticSpec) and len(s.works) != n
               for s in job.stages):
            return None
        sched = run_job(self.nodes, list(job.stages), self.uplink_bw,
                        adaptive=job.adaptive)
        h = len(job.adaptive.history) - len(job.stages) \
            if job.adaptive is not None else 0
        node_names = [nd.name for nd in self.nodes]
        planned: List[Optional[Dict[str, float]]] = []
        for k, spec in enumerate(job.stages):
            if not isinstance(spec, StaticSpec):
                planned.append(None)
            elif job.adaptive is not None:
                works = job.adaptive.history[h + k].works
                planned.append(dict(zip(node_names, works)))
            else:
                planned.append(dict(zip(node_names, spec.works)))
        out = JobOutcome(
            job.name, sched.completion, job.deadline,
            job.deadline is None or sched.completion <= job.deadline + _EPS,
            "done", 0.0, sched.stages, planned)
        return ResidentResult({job.name: out}, sched.completion, node_names)

    # ------------------------------------------------------------------
    def _run_loop(self, jobs) -> ResidentResult:
        n = len(self.nodes)
        self.names = [nd.name for nd in self.nodes]
        self.cursors = [ProfileCursor(nd.profile) for nd in self.nodes]
        self.overheads = [nd.task_overhead for nd in self.nodes]
        self.dead = [False] * n
        self.draining = [False] * n
        self.owner: List[Optional[_JobState]] = [None] * n
        self.task: List[Optional[SimTask]] = [None] * n
        self.t_started = [0.0] * n
        self.launch_at = [0.0] * n
        self.attempt_work = [0.0] * n
        self.attempt_io = [0.0] * n
        self.cpu_done = [0.0] * n
        self.io_left = [0.0] * n
        self.io_rate = [0.0] * n
        self.io_at = [0.0] * n
        self.reading = [-1] * n
        self.version = [0] * n
        self.readers: Dict[int, Set[int]] = {}
        self.heap: List[Tuple[float, int, int]] = []
        self.ckpt = self.ckpt_grain

        cold = self.faults.cold_restarts() if self.faults else []
        self.jobs = [_JobState(j, cold) for j in jobs]

        # external events, processed (t, rank, key) — see module docstring
        externals: List[Tuple[float, int, Tuple, str, object]] = []
        if self.faults is not None:
            for i in range(n):
                st = self.faults.state_at(i, 0.0)
                self.dead[i] = st == DEAD
                self.draining[i] = st == DRAINING
            for (t, node, kind) in self.faults.sub_events(0.0):
                externals.append((t, _EXT_RANK[kind], (node,), kind, node))
        for seq, rz in enumerate(self.resizes):
            externals.append((rz.at, _EXT_RANK["resize"], (seq,),
                              "resize", rz))
        for js in self.jobs:
            if js.job.arrival <= 0.0:
                js.arrived = True
            else:
                externals.append((js.job.arrival, _EXT_RANK["arrive"],
                                  (js.job.priority, js.job.name),
                                  "arrive", js))
        externals.sort(key=lambda e: (e[0], e[1], e[2]))
        self._externals = externals
        self._ext_left = len(externals)
        for idx, (t, _, _, _, _) in enumerate(externals):
            heapq.heappush(self.heap, (t, -1, idx))

        self._rebalance(0.0)

        guard = 0
        limit = 1000 * (len(self.jobs) + 1) * (n + 8) \
            * (1 + sum(len(js.job.stages) for js in self.jobs))
        while self.heap:
            guard += 1
            if guard > limit:
                raise RuntimeError("resident calendar failed to converge")
            t, i, ver = heapq.heappop(self.heap)
            if i < 0:
                _, _, _, kind, payload = self._externals[ver]
                self._ext_left -= 1
                self._handle_external(kind, payload, t)
                continue
            if ver != self.version[i] or self.task[i] is None:
                continue
            if self.reading[i] >= 0:
                d = self.reading[i]
                self.io_left[i] = 0.0
                self.reading[i] = -1
                self.readers[d].discard(i)
                self._reprice(d, t)
                if t + _EPS >= self.cpu_done[i]:
                    self._finish(i, t)
                else:
                    self._push(self.cpu_done[i], i)
            elif t + _EPS >= self.cpu_done[i]:
                self._finish(i, t)
            else:
                self._push(self.cpu_done[i], i)

        return self._result()

    # ------------------------------------------------------------------
    # engine-mirrored flow/attempt primitives
    # ------------------------------------------------------------------
    def _push(self, t: float, i: int) -> None:
        self.version[i] += 1
        heapq.heappush(self.heap, (t, i, self.version[i]))

    def _reprice(self, d: int, now: float) -> None:
        rd = self.readers.get(d)
        if not rd:
            return
        drained = []
        for i in rd:
            left = self.io_left[i] - self.io_rate[i] * (now - self.io_at[i])
            self.io_left[i] = left if left > 0.0 else 0.0
            self.io_at[i] = now
            if self.io_left[i] <= _EPS:
                drained.append(i)
        for i in drained:
            rd.discard(i)
            self.reading[i] = -1
            self._push(max(now, self.cpu_done[i]), i)
        if not rd:
            return
        rate = self.uplink_bw / len(rd)
        for i in rd:
            self.io_rate[i] = rate
            self._push(now + self.io_left[i] / rate, i)

    def _start_task(self, i: int, js: _JobState, tk: SimTask,
                    now: float) -> None:
        launch = now + self.overheads[i] + js.penalty.pop(tk.task_id, 0.0)
        self.task[i] = tk
        self.t_started[i] = now
        self.launch_at[i] = launch
        self.attempt_work[i] = tk.cpu_work
        self.cpu_done[i] = self.cursors[i].finish_time(tk.cpu_work, launch)
        if (self.uplink_bw is not None and tk.datanode >= 0
                and tk.io_mb > _EPS):
            self.attempt_io[i] = tk.io_mb
            self.io_left[i] = tk.io_mb
            self.io_at[i] = now
            self.io_rate[i] = 0.0
            self.reading[i] = tk.datanode
            self.readers.setdefault(tk.datanode, set()).add(i)
            self._reprice(tk.datanode, now)
        else:
            self.attempt_io[i] = 0.0
            self.io_left[i] = 0.0
            self._push(self.cpu_done[i], i)

    def _drop_flow(self, i: int, now: float) -> None:
        d = self.reading[i]
        if d < 0:
            return
        self.reading[i] = -1
        self.io_left[i] = 0.0
        self.readers[d].discard(i)
        self._reprice(d, now)

    def _remaining(self, i: int, now: float) -> float:
        if now < self.launch_at[i]:
            return self.attempt_work[i]
        return self.cursors[i].work_between(now, self.cpu_done[i])

    def _refill(self, i: int, now: float) -> None:
        js = self.owner[i]
        if (js is None or self.task[i] is not None or self.dead[i]
                or self.draining[i]):
            return
        if js.overflow:
            self._start_task(i, js, js.overflow.popleft(), now)
        elif js.shared:
            self._start_task(i, js, js.shared.popleft(), now)

    def _wake(self, js: _JobState, now: float) -> None:
        for i in js.nodes:
            if self.task[i] is None:
                self._refill(i, now)

    def _record(self, js: _JobState, name: str, work: float,
                now: float) -> None:
        js.exec_work[name] = js.exec_work.get(name, 0.0) + work
        js.counts[name] = js.counts.get(name, 0) + 1
        js.fin[name] = now

    def _finish(self, i: int, now: float) -> None:
        js = self.owner[i]
        self._record(js, self.names[i], self.attempt_work[i], now)
        self.task[i] = None
        js.open_tasks -= 1
        if self.draining[i]:
            # a draining node leaves its owner the moment its in-flight
            # attempt completes (it can take nothing new)
            self._release_node(i)
        else:
            self._refill(i, now)
        if js.open_tasks == 0:
            self._barrier(js, now)

    # ------------------------------------------------------------------
    # kills, sheds, externals
    # ------------------------------------------------------------------
    def _cancel_attempt(self, i: int, now: float, *, checkpoint: bool,
                        charge: bool) -> None:
        """Kill node i's in-flight attempt.  ``checkpoint``: grain-floored
        prefix survives as executed work; residual requeues to the
        owner's overflow per the job's retry policy (``charge=False``:
        scheduler-initiated — shed / resize drop — no retry charge)."""
        js, tk = self.owner[i], self.task[i]
        if js is None or tk is None:
            return
        executed = self.attempt_work[i] - self._remaining(i, now)
        saved = 0.0
        if checkpoint and self.ckpt > 0.0 and executed > 0.0:
            saved = min(math.floor((executed + _EPS) / self.ckpt)
                        * self.ckpt, self.attempt_work[i])
        if saved > _EPS:
            self._record(js, self.names[i], saved, now)
        self.task[i] = None
        self.version[i] += 1
        self._drop_flow(i, now)
        rem = self.attempt_work[i] - saved
        if rem <= _EPS:
            js.open_tasks -= 1
            return
        if charge:
            k = js.requeues.get(tk.task_id, 0)
            if k >= js.job.retry.max_attempts - 1:
                js.open_tasks -= 1          # retries exhausted: abandoned
                return
            js.requeues[tk.task_id] = k + 1
            js.retries += 1
            pen = js.job.retry.penalty(k + 1)
            if pen > 0.0:
                js.penalty[tk.task_id] = pen
        if self.attempt_io[i] > _EPS and self.attempt_work[i] > _EPS:
            io = self.attempt_io[i] * rem / self.attempt_work[i]
        else:
            io = 0.0
        js.overflow.append(SimTask(rem, io,
                                   tk.datanode if io > _EPS else -1,
                                   task_id=tk.task_id))

    def _release_node(self, i: int) -> None:
        js = self.owner[i]
        if js is not None:
            js.nodes.remove(i)
            self.owner[i] = None

    def _shed(self, js: _JobState, now: float) -> None:
        js.sheds += 1
        for i in list(js.nodes):
            if not self._usable(i):
                continue   # draining: finishes its attempt, releases itself
            self._cancel_attempt(i, now, checkpoint=True, charge=False)
            self._release_node(i)
        if not js.nodes:
            js.status = "idle"
        if js.open_tasks == 0 and not js.pending_materialize:
            self._barrier(js, now)

    def _handle_external(self, kind: str, payload, now: float) -> None:
        if kind == "kill":
            i = payload
            if i < len(self.nodes):
                self.dead[i] = True
                self.draining[i] = False
                js = self.owner[i]
                self._cancel_attempt(i, now, checkpoint=True, charge=True)
                self._release_node(i)
                if js is not None and js.open_tasks == 0 \
                        and not js.pending_materialize:
                    self._barrier(js, now)
                elif js is not None and not js.nodes:
                    js.status = "idle"
        elif kind == "drain":
            i = payload
            if i < len(self.nodes):
                self.draining[i] = True
                if self.task[i] is None:
                    self._release_node(i)
        elif kind == "recover":
            i = payload
            if i < len(self.nodes):
                self.dead[i] = False
                self.draining[i] = False
                if self.owner[i] is not None and self.task[i] is None:
                    self._release_node(i)   # rejoins via the free pool
        elif kind == "resize":
            for i in payload.drop:
                if i >= len(self.nodes) or self.dead[i]:
                    continue
                js = self.owner[i]
                self._cancel_attempt(i, now, checkpoint=True, charge=False)
                self._release_node(i)
                self.dead[i] = True      # removed for good
                self.draining[i] = False
                if js is not None and js.open_tasks == 0 \
                        and not js.pending_materialize:
                    self._barrier(js, now)
                elif js is not None and not js.nodes:
                    js.status = "idle"
            for nd in payload.add:
                if nd.name in self.names:
                    raise ValueError(f"added node {nd.name!r} duplicates "
                                     "an existing name")
                self.names.append(nd.name)
                self.cursors.append(ProfileCursor(nd.profile))
                self.overheads.append(nd.task_overhead)
                for arr, zero in ((self.dead, False), (self.draining, False),
                                  (self.owner, None), (self.task, None),
                                  (self.reading, -1), (self.version, 0)):
                    arr.append(zero)
                for arr in (self.t_started, self.launch_at,
                            self.attempt_work, self.attempt_io,
                            self.cpu_done, self.io_left, self.io_rate,
                            self.io_at):
                    arr.append(0.0)
                self.nodes.append(nd)
        else:                            # arrive
            payload.arrived = True
        self._rebalance(now)
        if self.recovery == "restart" and kind != "arrive":
            for js in self._ranked():
                if js.status == "running":
                    self._restart_stage(js, now)

    # ------------------------------------------------------------------
    # partitioning
    # ------------------------------------------------------------------
    def _ranked(self) -> List[_JobState]:
        return sorted((js for js in self.jobs if js.active()),
                      key=_JobState.rank)

    def _usable(self, i: int) -> bool:
        return not self.dead[i] and not self.draining[i]

    def _free_nodes(self) -> List[int]:
        return [i for i in range(len(self.nodes))
                if self._usable(i) and self.owner[i] is None]

    def _permits(self, js: _JobState, i: int) -> bool:
        return js.job.allowed is None or self.names[i] in js.job.allowed

    def _rebalance(self, now: float,
                   barrier_job: Optional[_JobState] = None) -> None:
        ranked = self._ranked()
        capacity = sum(self._usable(i) for i in range(len(self.nodes)))
        shares = fair_shares([(js.job.name, js.job.weight) for js in ranked],
                             capacity)
        for js in ranked:
            if shares[js.job.name] == 0 \
                    and any(self._usable(i) for i in js.nodes):
                self._shed(js, now)
        if barrier_job is not None:
            share = shares.get(barrier_job.job.name, 0)
            if share > 0:
                held = sorted(i for i in barrier_job.nodes
                              if self._usable(i))
                for i in held[share:]:
                    self._release_node(i)
                free = [i for i in self._free_nodes()
                        if self._permits(barrier_job, i)]
                for i in free[:share - len(barrier_job.nodes)]:
                    self.owner[i] = barrier_job
                    barrier_job.nodes.append(i)
                barrier_job.nodes.sort()
        for js in ranked:
            if js.status == "done" or js.nodes or shares[js.job.name] == 0:
                continue
            free = [i for i in self._free_nodes() if self._permits(js, i)]
            if not free:
                continue
            for i in free[:shares[js.job.name]]:
                self.owner[i] = js
                js.nodes.append(i)
            js.nodes.sort()
            if js.admitted_at is None:
                js.admitted_at = now
            js.status = "running"
            if js.pending_materialize:
                self._materialize(js, now)
            else:
                self._wake(js, now)
        # queued work freed by a kill/shed may be waiting on nodes that
        # went idle earlier in the stage — hand it out now
        for js in self.jobs:
            if (js.status == "running" and js.nodes
                    and not js.pending_materialize):
                self._wake(js, now)

    # ------------------------------------------------------------------
    # barriers & materialization
    # ------------------------------------------------------------------
    def _base_split(self, js: _JobState, spec, total: float,
                    names: Sequence[str]) -> List[float]:
        if js.job.proportions is not None:
            weights = [js.job.proportions.get(nm, 1.0) for nm in names]
            return hemt_split_floats(total, weights)
        # carry == 0.0 is the "no reskew residual" sentinel (set from the
        # literal, never computed); a near-zero computed residual keeps
        # the conservative re-split branch, which is still correct
        if (isinstance(spec, StaticSpec) and len(spec.works) == len(names)
                and js.carry == 0.0):  # hemt-lint: disable=HL004
            return list(spec.works)
        return [total / len(names)] * len(names)

    def _materialize(self, js: _JobState, now: float,
                     total_override: Optional[float] = None) -> None:
        spec = js.job.stages[js.stage_idx]
        if js.job.adaptive is not None:
            while js.cold and js.cold[0][0] <= now + _EPS:
                t_rec, node = js.cold.popleft()
                if node < len(self.names):
                    js.job.adaptive.estimator.forget(self.names[node])
        names = [self.names[i] for i in js.nodes]
        js.exec_work, js.counts, js.fin = {}, {}, {}
        js.stage_start = now
        js.pending_materialize = False
        js.status = "running"
        if isinstance(spec, StaticSpec):
            if total_override is None:
                total = sum(spec.works) + js.carry
            else:
                total = total_override
            base = self._base_split(js, spec, total, names)
            js.carry = 0.0
            if js.job.adaptive is not None:
                base_spec = StaticSpec(works=tuple(base), io_mb=spec.io_mb,
                                       datanode=spec.datanode)
                works = list(js.job.adaptive.replan(names, base_spec).works)
            else:
                works = base
            js.stage_total = sum(works)
            js.planned_dict = dict(zip(names, works))
            wsum = js.stage_total
            for i, w in zip(js.nodes, works):
                if spec.io_mb > 0.0 and spec.datanode >= 0:
                    io = spec.io_mb * (w / wsum if wsum > 0.0
                                       else 1.0 / len(works))
                else:
                    io = 0.0
                js.open_tasks += 1
                self._start_task(i, js, SimTask(
                    w, io, spec.datanode if io > _EPS else -1,
                    task_id=js.next_tid()), now)
        else:
            w = spec.work_array()
            wtot = float(w.sum())
            if total_override is not None:
                carry = total_override - wtot
            else:
                carry = js.carry
            js.carry = 0.0
            if carry > 0.0:
                if wtot > 0.0:
                    w = w * (1.0 + carry / wtot)
                else:
                    w = w + carry / len(w)
            js.stage_total = float(w.sum())
            js.planned_dict = None
            js.shared = deque(
                SimTask(float(x), spec.io_mb, spec.datanode,
                        task_id=js.next_tid())
                for x in w)
            js.open_tasks += len(js.shared)
            self._wake(js, now)

    def _restart_stage(self, js: _JobState, now: float) -> None:
        """restart-per-event baseline: abandon the running stage — nothing
        saved, queues cleared, partial stats discarded — and re-run it
        from scratch at ``now`` over the current nodes."""
        for i in list(js.nodes):
            if self.task[i] is not None:
                self.task[i] = None
                self.version[i] += 1
                self._drop_flow(i, now)
            if not self._usable(i):
                self._release_node(i)
        js.overflow.clear()
        js.shared.clear()
        js.open_tasks = 0
        total = js.stage_total
        if js.nodes:
            self._materialize(js, now, total_override=total)
        else:
            js.carry = 0.0
            js.stage_total = total
            js.pending_materialize = True
            js.status = "idle"

    def _barrier(self, js: _JobState, now: float) -> None:
        names = list(self.names)
        offs = [js.fin.get(nm, js.stage_start) - js.stage_start
                for nm in names]
        ran = [o for nm, o in zip(names, offs) if js.counts.get(nm, 0)]
        idle = (max(ran) - min(ran)) if ran else 0.0
        summ = StageSummary(
            js.stage_start, now, idle,
            {nm: js.stage_start + o for nm, o in zip(names, offs)},
            {nm: js.counts.get(nm, 0) for nm in names},
            {nm: js.exec_work.get(nm, 0.0) for nm in names})
        js.summaries.append(summ)
        js.planned.append(dict(js.planned_dict)
                          if js.planned_dict is not None else None)
        if js.job.adaptive is not None:
            js.job.adaptive.observe(names, summ)
        lost = lost_work(js.stage_total, sum(js.exec_work.values()))
        js.stage_total = 0.0   # consumed — a stranded job only reports
        #                        unexecuted work of a *materialized* stage
        js.stage_idx += 1
        last = js.stage_idx >= len(js.job.stages)
        if lost > 0.0:
            if js.job.fold_lost and not last:
                js.carry = lost
            else:
                js.lost += lost
        js.requeues.clear()
        js.penalty.clear()
        if last:
            js.status = "done"
            js.completion = now
            for i in list(js.nodes):
                self._release_node(i)
            self._rebalance(now)
            return
        js.pending_materialize = True
        self._rebalance(now, barrier_job=js)
        if not js.nodes:
            js.status = "idle"
            return
        if self._can_fast_forward(js):
            self._fast_forward(js, now)
            return
        self._materialize(js, now)

    # ------------------------------------------------------------------
    # tail fast-forward through resumable run_job
    # ------------------------------------------------------------------
    def _can_fast_forward(self, js: _JobState) -> bool:
        if self.recovery != "splice" or self._ext_left > 0:
            return False
        # same carry sentinel as _base_split: nonzero residual (however
        # small) must keep the event-by-event path, so exact is safe
        if js.carry != 0.0:  # hemt-lint: disable=HL004
            return False
        if any(other is not js and other.active() for other in self.jobs):
            return False
        usable = [i for i in range(len(self.nodes)) if self._usable(i)]
        return usable == js.nodes

    def _fast_forward(self, js: _JobState, now: float) -> None:
        if js.job.adaptive is not None:
            # run_job gets no fault trace (the tail is event-free), so any
            # cold restarts already past must be forgotten here, exactly
            # where the materialize path would have
            while js.cold and js.cold[0][0] <= now + _EPS:
                _, node = js.cold.popleft()
                if node < len(self.names):
                    js.job.adaptive.estimator.forget(self.names[node])
        sub = [self.nodes[i] for i in js.nodes]
        names = [self.names[i] for i in js.nodes]
        stages: List[object] = []
        for k, spec in enumerate(js.job.stages):
            if k < js.stage_idx or not isinstance(spec, StaticSpec):
                stages.append(spec)
            elif len(spec.works) == len(sub) \
                    and js.job.proportions is None:
                stages.append(spec)
            else:
                total = sum(spec.works)
                stages.append(StaticSpec(
                    works=tuple(self._base_split(js, spec, total, names)),
                    io_mb=spec.io_mb, datanode=spec.datanode))
        h0 = len(js.job.adaptive.history) if js.job.adaptive else 0
        sched = run_job(sub, stages, self.uplink_bw,
                        adaptive=js.job.adaptive,
                        resume=JobContinuation(js.stage_idx, now))
        for m, summ in enumerate(sched.stages):
            k = js.stage_idx + m
            js.summaries.append(summ)
            spec = stages[k]
            if not isinstance(spec, StaticSpec):
                js.planned.append(None)
            elif js.job.adaptive is not None:
                works = js.job.adaptive.history[h0 + m].works
                js.planned.append(dict(zip(names, works)))
            else:
                js.planned.append(dict(zip(names, spec.works)))
        js.stage_idx = len(js.job.stages)
        js.status = "done"
        js.completion = sched.completion
        js.pending_materialize = False
        for i in list(js.nodes):
            self._release_node(i)

    # ------------------------------------------------------------------
    def _result(self) -> ResidentResult:
        outcomes = {}
        makespan = 0.0
        for js in self.jobs:
            done = js.status == "done"
            completion = js.completion if done else math.inf
            if done:
                makespan = max(makespan, completion)
            elif js.stage_total:
                js.lost += lost_work(js.stage_total,
                                     sum(js.exec_work.values()))
            dl = js.job.deadline
            outcomes[js.job.name] = JobOutcome(
                js.job.name, completion, dl,
                done and (dl is None or completion <= dl + _EPS),
                "done" if done else "stranded",
                js.admitted_at, js.summaries, js.planned,
                js.lost, js.retries, js.sheds)
        alive = [self.names[i] for i in range(len(self.nodes))
                 if self._usable(i)]
        return ResidentResult(outcomes, makespan, alive)
