"""Skewed hash partitioner — paper Algorithm 1 (§7).

Assigns a record to a shuffle bucket by hashing into the capacity-weighted
prefix-sum space: bucket b receives a share of hash space proportional to
executor b's capacity. The paper expresses it as "the number of elements in
the (prefix-summed) capacities array >= hash"; equivalently a searchsorted
over the exclusive prefix sums.

Two implementations:
  * numpy / python — used by the scheduler & shuffle simulator,
  * jnp — used inside jitted code (MoE overflow re-bucketing, data shuffle);
    `repro.kernels.skewed_bucket` is the Pallas TPU version of the same map.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

import jax.numpy as jnp


def integer_capacities(weights: Sequence[float], resolution: int = 1 << 16,
                       ) -> np.ndarray:
    """Scale float capacities to integers summing to `resolution` (largest
    remainder), the hash-space size of Algorithm 1."""
    w = np.asarray(weights, np.float64)
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError("capacities must be non-negative with positive sum")
    share = w / w.sum() * resolution
    base = np.floor(share).astype(np.int64)
    rem = resolution - int(base.sum())
    order = np.argsort(-(share - np.floor(share)))
    base[order[:rem]] += 1
    return base


def bucket_of(hash_codes: np.ndarray, capacities: np.ndarray) -> np.ndarray:
    """Vectorized Algorithm 1. hash_codes: int array; capacities: ints.

    h = hash mod sum(capacities); bucket = #(prefix_sums <= h) -- i.e. the
    unique b with cum_{b} <= h < cum_{b+1} (cum exclusive prefix sums).
    """
    caps = np.asarray(capacities, np.int64)
    total = int(caps.sum())
    h = np.mod(np.asarray(hash_codes, np.int64), total)
    cum = np.cumsum(caps)  # inclusive prefix sums
    return np.searchsorted(cum, h, side="right").astype(np.int32)


def bucket_of_jnp(hash_codes: jnp.ndarray, capacities: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of `bucket_of` for use inside jit."""
    caps = capacities.astype(jnp.int64)
    total = jnp.sum(caps)
    h = jnp.mod(hash_codes.astype(jnp.int64), total)
    cum = jnp.cumsum(caps)
    return jnp.searchsorted(cum, h, side="right").astype(jnp.int32)


def expected_shares(capacities: Sequence[int]) -> List[float]:
    caps = np.asarray(capacities, np.float64)
    return list(caps / caps.sum())


def skewed_shuffle_counts(n_records: int, capacities: Sequence[int],
                          seed: int = 0) -> np.ndarray:
    """Simulate a shuffle of n_records uniformly-hashed records through
    Algorithm 1; returns per-bucket record counts."""
    rng = np.random.default_rng(seed)
    hashes = rng.integers(0, np.iinfo(np.int64).max, size=n_records)
    b = bucket_of(hashes, np.asarray(capacities))
    return np.bincount(b, minlength=len(list(capacities)))
