"""Discrete-event cluster simulator — the paper's EC2/Mesos testbed in code.

Models, per §5-§6 of the paper:
  * nodes with piecewise-constant speed profiles (static container shares,
    interference injections at arbitrary times, burstable token-bucket
    two-segment profiles),
  * per-task overhead (scheduling + launch + I/O setup) — the microtasking
    cost the paper analyzes,
  * pull-based task assignment (HomT; Claim 1's setting) and static
    macrotask assignment (HeMT),
  * a flow-level storage model: tasks read input from datanodes whose
    uplinks are fairly shared by concurrent readers (Claim 2 / Fig 5/15);
    a task completes when both its I/O and CPU work are done.

All times are seconds, work is in abstract units (1 unit = 1 second on a
speed-1.0 node), I/O sizes in MB, bandwidths in MB/s.

``run_pull_stage``/``run_static_stage`` dispatch to the layered fast-path
engine in ``repro.core.engine`` (event calendar + vectorized closed forms);
the ``_run_stage`` rescan loop below is retained as the reference oracle the
engine's differential tests are pinned against.  Whole multi-stage jobs
(``run_job`` + ``PullSpec``/``StaticSpec``/``JobSchedule``/``StageSummary``,
re-exported lazily below to avoid the import cycle) carry per-node finish
vectors across program barriers so S-stage sweeps cost O(S·n) on
constant-speed clusters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.capacity import BurstableNode


# --------------------------------------------------------------------------
# node model
# --------------------------------------------------------------------------

@dataclass
class SimNode:
    """A computing node with a piecewise-constant speed profile.

    profile: [(t_start, speed), ...] sorted by t_start, first at t=0.
    """
    name: str
    profile: List[Tuple[float, float]] = field(default_factory=lambda: [(0.0, 1.0)])
    task_overhead: float = 0.0          # seconds added per task

    def __post_init__(self):
        # constructor contract: profiles are authored literals and must
        # start at exactly t=0; exact != is the validation, not arithmetic
        if not self.profile or self.profile[0][0] != 0.0:  # hemt-lint: disable=HL004
            raise ValueError("profile must start at t=0")
        for (t0, _), (t1, _) in zip(self.profile, self.profile[1:]):
            if t1 <= t0:
                raise ValueError("profile times must increase")

    @classmethod
    def constant(cls, name: str, speed: float, overhead: float = 0.0) -> "SimNode":
        return cls(name, [(0.0, speed)], overhead)

    @classmethod
    def burstable(cls, name: str, node: BurstableNode, overhead: float = 0.0,
                  ) -> "SimNode":
        """Two-segment profile: peak until credit depletion, then baseline."""
        tb = node.burst_time
        if math.isinf(tb):
            return cls(name, [(0.0, node.peak)], overhead)
        if tb <= 0.0:     # zero credits: at baseline from the start
            return cls(name, [(0.0, node.baseline)], overhead)
        return cls(name, [(0.0, node.peak), (tb, node.baseline)], overhead)

    def speed_at(self, t: float) -> float:
        s = self.profile[0][1]
        for t0, sp in self.profile:
            if t0 <= t:
                s = sp
            else:
                break
        return s

    def work_between(self, t0: float, t1: float) -> float:
        """Integrate speed over [t0, t1]."""
        if t1 <= t0:
            return 0.0
        total, t = 0.0, t0
        segs = self.profile + [(math.inf, 0.0)]
        for (s0, sp), (s1, _) in zip(segs, segs[1:]):
            lo, hi = max(t, s0), min(t1, s1)
            if hi > lo:
                total += sp * (hi - lo)
        return total

    def finish_time(self, work: float, t0: float) -> float:
        """Earliest t with work_between(t0, t) >= work."""
        if work <= 0:
            return t0
        t, rem = t0, work
        segs = self.profile + [(math.inf, 0.0)]
        for (s0, sp), (s1, _) in zip(segs, segs[1:]):
            lo, hi = max(t0, s0), s1
            if hi <= t0:
                continue
            span = hi - lo
            if sp > 0 and rem <= sp * span:
                return lo + rem / sp
            rem -= sp * span
            if math.isinf(hi):
                break
        if rem > 1e-12:
            raise RuntimeError(f"node {self.name} can never finish work={work}")
        return hi


# --------------------------------------------------------------------------
# tasks & storage
# --------------------------------------------------------------------------

@dataclass(slots=True)
class SimTask:
    """cpu_work: seconds-at-speed-1; io_mb: input bytes to fetch;
    datanode: which storage node serves it (-1 = no I/O)."""
    cpu_work: float
    io_mb: float = 0.0
    datanode: int = -1
    task_id: int = -1


class TaskRecord(NamedTuple):
    # NamedTuple (C-level tuple construction) rather than a dataclass: the
    # closed forms and the event calendar materialize one record per task,
    # so construction cost is on every stage's critical path.
    task_id: int
    node: str
    start: float
    end: float
    cpu_work: float


class StageColumns(NamedTuple):
    """Columnar view of a stage's completed attempts, in record order.

    ``node_index`` indexes into ``node_names`` (stage node order), so batch
    consumers can ``np.bincount`` per-node aggregates without touching a
    single ``TaskRecord``.
    """
    task_ids: "np.ndarray"      # int64  [T]
    node_index: "np.ndarray"    # int64  [T]
    starts: "np.ndarray"        # float64 [T]
    ends: "np.ndarray"          # float64 [T]
    works: "np.ndarray"         # float64 [T] cpu work per attempt
    node_names: Tuple[str, ...]


class StageResult:
    """Stage outcome, lazy between two equivalent per-task representations.

    The closed forms build **columnar** results (parallel numpy arrays, no
    per-task Python objects); the event paths still build the legacy
    ``TaskRecord`` list.  Whichever view a caller asks for is derived from
    the other on first access and cached: ``.records`` materializes the
    NamedTuples only when a record-consuming caller (driver counts-by-node,
    scheduler steal accounting, tests) actually needs them, while
    ``.columns()`` hands batch consumers (benchmarks, whole-job summaries,
    serving sweeps) the arrays directly.
    """

    __slots__ = ("node_finish", "completion", "idle_time", "_records", "_cols")

    def __init__(self, node_finish: Dict[str, float], completion: float,
                 idle_time: float, *,
                 records: Optional[List[TaskRecord]] = None,
                 cols: Optional[StageColumns] = None):
        if records is None and cols is None:
            raise ValueError("StageResult needs records or cols")
        self.node_finish = node_finish
        self.completion = completion     # max end
        # Claim 1 quantity: max finish - min finish over nodes that ran
        # >= 1 task (a node that never received work sits at start_time
        # and would otherwise inflate the barrier-idle metric).
        self.idle_time = idle_time
        self._records = records
        self._cols = cols

    @property
    def makespan(self) -> float:
        return self.completion

    @property
    def records(self) -> List[TaskRecord]:
        if self._records is None:
            c = self._cols
            names = c.node_names
            self._records = [
                TaskRecord(tid, names[ni], s, e, w)
                for tid, ni, s, e, w in zip(
                    c.task_ids.tolist(), c.node_index.tolist(),
                    c.starts.tolist(), c.ends.tolist(), c.works.tolist())
            ]
        return self._records

    def columns(self) -> StageColumns:
        if self._cols is None:
            rs = self._records
            # node_finish insertion order == stage node order on every
            # constructing path, so it doubles as the name table.
            names = tuple(self.node_finish)
            idx_of = {nm: i for i, nm in enumerate(names)}
            m = len(rs)
            self._cols = StageColumns(
                np.fromiter((r.task_id for r in rs), np.int64, count=m),
                np.fromiter((idx_of[r.node] for r in rs), np.int64, count=m),
                np.fromiter((r.start for r in rs), np.float64, count=m),
                np.fromiter((r.end for r in rs), np.float64, count=m),
                np.fromiter((r.cpu_work for r in rs), np.float64, count=m),
                names)
        return self._cols

    def __repr__(self) -> str:    # keep debugging output bounded
        n = len(self._records) if self._records is not None \
            else self._cols.task_ids.size
        return (f"StageResult(n_records={n}, completion={self.completion!r}, "
                f"idle_time={self.idle_time!r})")


def _stage_result(records: List[TaskRecord], node_finish: Dict[str, float],
                  start_time: float) -> StageResult:
    """Shared result assembly (legacy oracle + engine event paths): idle
    time is the finish spread over nodes that actually ran work, 0 if
    none did."""
    ran = {r.node for r in records}
    if ran:
        finishes = [node_finish[name] for name in ran]
        idle = max(finishes) - min(finishes)
    else:
        idle = 0.0
    completion = max(node_finish.values()) if node_finish else start_time
    return StageResult(node_finish, completion, idle, records=records)


def _stage_result_columns(cols: StageColumns, node_finish: Dict[str, float],
                          start_time: float) -> StageResult:
    """Columnar twin of :func:`_stage_result` — the closed forms hand their
    arrays straight in and no ``TaskRecord`` is built unless asked for."""
    if cols.node_index.size:
        ran = np.unique(cols.node_index)
        fins = np.fromiter((node_finish[cols.node_names[i]] for i in ran),
                           np.float64, count=ran.size)
        idle = float(fins.max() - fins.min())
    else:
        idle = 0.0
    completion = max(node_finish.values()) if node_finish else start_time
    return StageResult(node_finish, completion, idle, cols=cols)


# --------------------------------------------------------------------------
# the event loop
# --------------------------------------------------------------------------

_EPS = 1e-9


def _run_stage(nodes: Sequence[SimNode], queues: List[List[SimTask]],
               pull: bool, uplink_bw: Optional[float] = None,
               start_time: float = 0.0) -> StageResult:
    """Core fluid/event simulation — the reference oracle (O(N·T) rescan
    loop; the fast paths in ``repro.core.engine`` are differential-tested
    against it).

    queues: if pull, queues[0] is the shared pending queue; otherwise
    queues[i] is node i's private queue (HeMT macrotask list).

    I/O model: active readers of datanode d share `uplink_bw` equally
    (progressive filling, recomputed at every event). A task must finish
    its I/O and its CPU work; both progress concurrently (pipelined
    read-process, as in Spark).
    """
    n = len(nodes)
    shared = queues[0] if pull else None
    private = None if pull else [list(q) for q in queues]

    # per-node running task state
    @dataclass
    class Running:
        task: SimTask
        io_left: float
        cpu_done_at: float   # absolute time CPU work completes (fixed at start)
        start: float

    running: List[Optional[Running]] = [None] * n
    node_finish = {nd.name: start_time for nd in nodes}
    records: List[TaskRecord] = []
    t = start_time

    def io_rates() -> Dict[int, float]:
        """Current per-reader rate for each datanode."""
        readers: Dict[int, int] = {}
        for r in running:
            if r and r.io_left > _EPS and r.task.datanode >= 0:
                readers[r.task.datanode] = readers.get(r.task.datanode, 0) + 1
        return {d: (uplink_bw / c if uplink_bw else math.inf)
                for d, c in readers.items()}

    def next_task_for(i: int) -> Optional[SimTask]:
        if pull:
            return shared.pop(0) if shared else None
        return private[i].pop(0) if private[i] else None

    def start_task(i: int, task: SimTask, now: float):
        nd = nodes[i]
        launch = now + nd.task_overhead
        cpu_end = nd.finish_time(task.cpu_work, launch)
        running[i] = Running(task, task.io_mb, cpu_end, now)

    # prime all nodes
    for i in range(n):
        tk = next_task_for(i)
        if tk:
            start_task(i, tk, t)

    guard = 0
    while any(running):
        guard += 1
        if guard > 10_000_000:
            raise RuntimeError("simulator event-loop runaway")
        rates = io_rates()
        # per-reader rate, computed once per iteration and shared by the
        # event search and the io advancement below
        node_rate = [rates.get(r.task.datanode, math.inf)
                     if r and r.io_left > _EPS and r.task.datanode >= 0
                     else None for r in running]
        # next event: earliest of (cpu completion if io done / will be done,
        # io completion) over running tasks
        t_next, who = math.inf, -1
        for i, r in enumerate(running):
            if not r:
                continue
            rate = node_rate[i]
            if rate is not None:
                t_io = t + (r.io_left / rate if math.isfinite(rate) else 0.0)
                cand = max(t_io, r.cpu_done_at)
                # but an io completion *event* (another flow freeing up) can
                # change rates: we only advance to the earliest *completion*;
                # flows finishing earlier are themselves completions.
                cand_evt = t_io if t_io < r.cpu_done_at else cand
            else:
                cand_evt = r.cpu_done_at
            if cand_evt < t_next:
                t_next, who = cand_evt, i
        # advance io progress to t_next
        for i, r in enumerate(running):
            rate = node_rate[i]
            if rate is not None:
                if math.isfinite(rate):
                    r.io_left = max(0.0, r.io_left - rate * (t_next - t))
                else:
                    r.io_left = 0.0
        t = t_next
        r = running[who]
        if r.io_left <= _EPS and t + _EPS >= r.cpu_done_at:
            # task complete
            records.append(TaskRecord(r.task.task_id, nodes[who].name,
                                      r.start, t, r.task.cpu_work))
            node_finish[nodes[who].name] = t
            running[who] = None
            tk = next_task_for(who)
            if tk:
                start_task(who, tk, t)
        # else: io finished but cpu still running (or vice versa): loop again;
        # rates recompute naturally.

    return _stage_result(records, node_finish, start_time)


def run_pull_stage(nodes: Sequence[SimNode], tasks: Sequence[SimTask],
                   uplink_bw: Optional[float] = None,
                   start_time: float = 0.0, mitigation=None,
                   faults=None) -> StageResult:
    """HomT: shared queue, idle nodes pull (paper Claim 1 setting).

    Rides the fast-path engine: vectorized closed form for uniform tasks on
    constant-speed nodes without effective I/O, event calendar otherwise.
    ``mitigation`` (an event-level policy from ``repro.core.speculation``)
    adds straggler speculation / work stealing on the event calendar.
    ``faults`` (a ``repro.core.faults.FaultTrace``) injects node crashes /
    spot preemptions; killed work re-enters the shared queue.
    """
    from repro.core.engine import simulate_stage
    return simulate_stage(nodes, [tasks], pull=True, uplink_bw=uplink_bw,
                          start_time=start_time, mitigation=mitigation,
                          faults=faults)


def run_static_stage(nodes: Sequence[SimNode],
                     assignments: Sequence[Sequence[SimTask]],
                     uplink_bw: Optional[float] = None,
                     start_time: float = 0.0, mitigation=None,
                     faults=None) -> StageResult:
    """HeMT: one (or more) pre-assigned macrotasks per node.

    Rides the fast-path engine: per-node vectorized cumsum for constant
    speeds without effective I/O, event calendar otherwise.  ``mitigation``
    (an event-level policy from ``repro.core.speculation``) lets idle nodes
    speculate on or steal from straggling macrotasks.  ``faults`` (a
    ``repro.core.faults.FaultTrace``) injects node crashes / spot
    preemptions; a dead node's macrotasks are re-executed on recovery or
    redistributed to survivors per the trace's retry policy.
    """
    if len(assignments) != len(nodes):
        raise ValueError("need one task list per node")
    from repro.core.engine import simulate_stage
    return simulate_stage(nodes, assignments, pull=False,
                          uplink_bw=uplink_bw, start_time=start_time,
                          mitigation=mitigation, faults=faults)


_ENGINE_EXPORTS = ("run_job", "PullSpec", "StaticSpec", "JobSchedule",
                   "StageSummary", "plan_path", "run_job_cache_clear",
                   "AdaptivePlan")


def __getattr__(name: str):
    """Lazy re-export of the whole-job engine API (PEP 562): the engine
    imports this module at top level, so a direct top-level import here
    would be circular."""
    if name in _ENGINE_EXPORTS:
        from repro.core import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# --------------------------------------------------------------------------
# convenience: whole-job helpers used by benchmarks
# --------------------------------------------------------------------------

def homt_job(nodes: Sequence[SimNode], total_work: float, n_tasks: int,
             io_mb_total: float = 0.0, uplink_bw: Optional[float] = None,
             n_datanodes: int = 4, replica: int = 2, seed: int = 0,
             ) -> StageResult:
    """Evenly partition total_work into n_tasks and run pull-based."""
    import numpy as np
    rng = np.random.default_rng(seed)
    per_cpu = total_work / n_tasks
    per_io = io_mb_total / n_tasks
    tasks = []
    # block -> datanode selection with replica-aware choice (Claim 2 model):
    # consecutive tasks read consecutive ranges, tasks sharing a block pick
    # uniformly among its replicas.
    n_blocks = max(1, min(n_tasks, 64))
    placement = [rng.choice(n_datanodes, size=min(replica, n_datanodes),
                            replace=False) for _ in range(n_blocks)]
    for i in range(n_tasks):
        dn = int(rng.choice(placement[i * n_blocks // n_tasks])) \
            if io_mb_total > 0 else -1
        tasks.append(SimTask(per_cpu, per_io, dn, task_id=i))
    return run_pull_stage(nodes, tasks, uplink_bw=uplink_bw)


def hemt_job(nodes: Sequence[SimNode], total_work: float,
             weights: Sequence[float], io_mb_total: float = 0.0,
             uplink_bw: Optional[float] = None, n_datanodes: int = 4,
             replica: int = 2, seed: int = 0) -> StageResult:
    """One macrotask per node, sized by weights (paper §5.1)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    s = sum(weights)
    assignments = []
    for i, (_nd, w) in enumerate(zip(nodes, weights)):
        dn = int(rng.integers(0, n_datanodes)) if io_mb_total > 0 else -1
        assignments.append([SimTask(total_work * w / s,
                                    io_mb_total * w / s, dn, task_id=i)])
    return run_static_stage(nodes, assignments, uplink_bw=uplink_bw)
