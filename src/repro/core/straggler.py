"""Straggler analytics & mitigation.

Claim 1 (paper §3): with pull-based assignment, even partitioning and
constant node speeds, idle time <= max_i T_i (single-task duration on the
slowest node). `claim1_bound` computes the bound; the simulator validates
it (tests + bench_claim1).

Runtime mitigation used by the training framework (runtime/ft.py):
  * z-score detection on per-grain rates (the paper's "execution time
    variation at program barriers" signal),
  * speculative re-execution for pull-mode stages,
  * HeMT re-skew (capacity loss absorbed by the next plan, no restart).

Simulated, engine-backed mitigation lives in ``repro.core.speculation``:
SpeculativeCopies / WorkStealing run on the event calendar
(``run_stage_events(mitigation=...)``) and ReskewHandoff folds straggler
residuals across ``run_job`` barriers.  The advisory helpers below
(``speculative_copies``) share the SpeculativeCopies trigger rule, so the
runtime monitor and the simulator speculate under one definition.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.simulator import SimNode, SimTask, run_pull_stage
from repro.core.speculation import SpeculativeCopies


def claim1_bound(total_work: float, n_tasks: int,
                 speeds: Sequence[float]) -> float:
    """Upper bound on resource idling time: single task duration on the
    slowest node = (D/m) / min_i v_i."""
    per_task = total_work / n_tasks
    return per_task / min(speeds)


def verify_claim1(total_work: float, n_tasks: int, speeds: Sequence[float],
                  overhead: float = 0.0) -> Tuple[float, float, bool]:
    """Simulate pull-based HomT; return (idle_time, bound, holds)."""
    nodes = [SimNode.constant(f"n{i}", v, overhead)
             for i, v in enumerate(speeds)]
    per = total_work / n_tasks
    tasks = [SimTask(per, task_id=i) for i in range(n_tasks)]
    res = run_pull_stage(nodes, tasks)
    # the bound is on pure compute idling; per-task overhead adds to both
    bound = claim1_bound(total_work, n_tasks, speeds) + overhead
    return res.idle_time, bound, res.idle_time <= bound + 1e-9


@dataclass
class StragglerReport:
    """One flagged executor.  ``index`` is positional within the rate list
    handed to :func:`detect_stragglers` — under an elastic fleet that list
    shrinks as nodes die, so consumers that outlive one call
    (``FleetMonitor``) attach the stable slice ``name``."""
    index: int
    rate: float
    zscore: float
    name: str = ""


def detect_stragglers(rates: Sequence[float], z_threshold: float = -1.5,
                      ) -> List[StragglerReport]:
    """Flag executors whose work rate z-score is below threshold."""
    if len(rates) < 3:
        return []
    mu = statistics.fmean(rates)
    sd = statistics.pstdev(rates)
    if sd == 0:
        return []
    out = []
    for i, r in enumerate(rates):
        z = (r - mu) / sd
        if z < z_threshold:
            out.append(StragglerReport(i, r, z))
    return out


def speculative_copies(records_end: Dict[int, Optional[float]], now: float,
                       running_starts: Dict[int, float],
                       timeout_factor: float = 2.0) -> List[int]:
    """Opportunistic speculation (paper §8 survey, [45,6,5]): re-launch tasks
    still running at/over timeout_factor x median completed duration.

    Advisory twin of the engine-backed
    :class:`repro.core.speculation.SpeculativeCopies` policy (median =
    quantile 0.5), routed through the shared ``should_speculate`` rule so
    a task running *exactly* ``timeout_factor * median`` gets the same
    at-threshold (``>=``) verdict here, in
    ``FleetMonitor.speculation_candidates``, and inside the engine's
    ``run_stage_events(mitigation=...)`` cancel/re-launch events.
    """
    done = [e for e in records_end.values() if e is not None]
    if not done:
        return []
    policy = SpeculativeCopies(quantile=0.5, factor=timeout_factor,
                               min_completed=1)
    return [tid for tid, st in running_starts.items()
            if policy.should_speculate(done, now - st)]


def rebalance_after_loss(weights: Sequence[float], lost: Sequence[int],
                         cold_start: str = "mean") -> Dict[int, float]:
    """HeMT elastic response to node loss: drop lost executors, renormalize.

    Returns ``{surviving original index: renormalized weight}`` so callers
    can map each weight back to the executor it belongs to — a bare
    renormalized list loses that mapping the moment indices shift.
    (Speeds of later replacement nodes get the cold-start rule — see
    estimators.ARSpeedEstimator.speeds.)"""
    lost_set = set(lost)
    kept = [(i, w) for i, w in enumerate(weights) if i not in lost_set]
    if not kept:
        raise ValueError("all executors lost")
    s = sum(w for _, w in kept)
    return {i: w / s for i, w in kept}
