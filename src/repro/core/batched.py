"""Batched many-solve planner: the closed forms over ``[B, n]`` stacks.

Every capacity-planning question the HeMT story raises ("how many nodes
hold this traffic at this p99?", "where does the HomT/HeMT crossover sit
on this fleet?") is thousands of *independent* closed-form solves, but
:mod:`repro.core.engine` solves one (cluster, spec) pair at a time — a
Monte-Carlo planner pays Python-loop and cache-lookup overhead per solve.
This module lifts the three dominant closed forms to array form, one
vectorized pass over a stack of clusters:

* :func:`batched_closed_static` — HeMT macrotasks: per-node finish is
  ``overhead + works / speeds``, row makespan its max;
* :func:`batched_closed_pull` — HomT uniform microtasks: ``n_tasks``
  equal pulls of ``task_work`` each;
* :func:`batched_closed_pull_hetero` — heterogeneous FIFO pull of a
  ``[B, T]`` work grid.

Both pull solvers share :func:`pull_scan`, a scan over the task axis
whose per-step state is a ``[B, n]`` end-time matrix — the batched
restatement of the engine's merged-grid ``(end, node)`` heap.  The
``argmin`` per step resolves ties to the lowest node index, which is
exactly the heap's tie-break, and the update arithmetic mirrors the
heap's ``e0 + oh`` then ``+= w / speed`` so the two agree bitwise on the
same row.  The randomized differential suites in ``tests/test_batched.py``
pin all three solvers against scalar :func:`repro.core.engine.run_job`
at 1e-9.

The same scan is exposed in jax form (:func:`pull_scan_jax`:
``lax.scan`` stepped under ``vmap``), jit-able and differentiable with
respect to the work grid and speeds, so the ``kernels/`` accelerator
port can pick it up without re-deriving the schedule semantics.

Where the scalar path leans on ``run_job``'s module-level solve LRU, the
batched path demotes that cache to **cross-batch de-dup**
(:func:`dedup_rows`): identical rows of a batch are detected up front
with one ``np.unique(axis=0)``, solved once, and scattered back — a
Monte-Carlo sweep whose sampler repeats scenarios (or runs cv=0) pays
one scan per *distinct* row and zero per-solve cache probes.

:func:`plan_capacity` is the Monte-Carlo capacity planner on top: the
smallest fleet size whose ``percentile``-th makespan over sampled speed
jitter meets a target, one batched solve per candidate size.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "BatchResult",
    "CapacityReport",
    "batched_closed_pull",
    "batched_closed_pull_hetero",
    "batched_closed_static",
    "dedup_rows",
    "plan_capacity",
    "pull_scan",
    "pull_scan_jax",
]


class BatchResult(NamedTuple):
    """One batch of stage solves, stage-relative (start = 0).

    Mirrors the scalar ``StageSummary`` fields row-wise: ``node_finish``
    are per-node finish *offsets* (0.0 for a node that never ran, like
    the scalar summaries), ``idle`` the finish spread over nodes that
    ran at least one task.
    """
    makespan: np.ndarray       # float64 [B]
    idle: np.ndarray           # float64 [B]
    node_finish: np.ndarray    # float64 [B, n]
    executed: np.ndarray       # float64 [B, n] work run per node
    counts: np.ndarray         # int64   [B, n] tasks run per node


def _as_2d(a, name: str) -> np.ndarray:
    arr = np.atleast_2d(np.asarray(a, dtype=np.float64))
    if arr.ndim != 2:
        raise ValueError(f"{name} must be at most 2-D, got shape {arr.shape}")
    return arr


def _broadcast_overheads(overheads, shape) -> np.ndarray:
    oh = np.asarray(overheads, dtype=np.float64)
    try:
        oh = np.broadcast_to(oh, shape)
    except ValueError:
        raise ValueError(
            f"overheads shape {oh.shape} does not broadcast to "
            f"{shape}") from None
    if np.any(oh < 0.0):
        raise ValueError("overheads must be >= 0")
    return oh


def _check_speeds(sp: np.ndarray) -> None:
    if sp.size and not np.all(sp > 0.0):
        raise ValueError("speeds must be > 0")


def _finish_stats(node_end: np.ndarray, counts: np.ndarray,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """(makespan, idle) rows from per-node finish offsets; idle spans only
    nodes that ran, matching the scalar summaries."""
    ran = counts > 0
    any_ran = ran.any(axis=1)
    makespan = node_end.max(axis=1) if node_end.size else \
        np.zeros(node_end.shape[0])
    hi = np.where(ran, node_end, -np.inf).max(axis=1, initial=-np.inf)
    lo = np.where(ran, node_end, np.inf).min(axis=1, initial=np.inf)
    idle = np.where(any_ran, hi - lo, 0.0)
    return makespan, idle


def batched_closed_static(speeds, works, overheads=0.0) -> BatchResult:
    """Array-form ``closed-static``: row b, node i finishes its macrotask
    at ``overheads[b, i] + works[b, i] / speeds[b, i]``.

    ``speeds`` and ``works`` broadcast against each other to a common
    ``[B, n]`` (so one split vector can be scored against B sampled speed
    vectors, or vice versa); ``overheads`` broadcasts as scalar, ``[n]``
    or ``[B, n]``.  Counts are all-ones per the scalar engine semantics —
    a zero-work macrotask still pays its pull overhead.
    """
    sp = _as_2d(speeds, "speeds")
    wk = _as_2d(works, "works")
    sp, wk = np.broadcast_arrays(sp, wk)
    _check_speeds(sp)
    if np.any(wk < 0.0):
        raise ValueError("works must be >= 0")
    oh = _broadcast_overheads(overheads, sp.shape)
    fin = oh + wk / sp
    counts = np.ones(sp.shape, dtype=np.int64)
    makespan, idle = _finish_stats(fin, counts)
    return BatchResult(makespan, idle, fin,
                       np.array(wk, dtype=np.float64), counts)


def pull_scan(overheads: np.ndarray, speeds: np.ndarray, works: np.ndarray,
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The batched merged-grid FIFO scan: ``[B, n]`` overheads/speeds and a
    ``[B, T]`` work grid -> per-node ``(finish, counts, executed)``.

    Step state is the ``[B, n]`` end-time matrix ``e``.  The first
    ``min(n, T)`` tasks prime nodes 0..n-1 (the engine's initial pulls);
    every later task goes to each row's ``argmin(e)`` — first index on
    ties, the heap's ``(end, node)`` key.  The update ``base = e + oh``
    then ``+ w / speed`` reproduces the heap arithmetic term-for-term, so
    a batched row is bitwise the scalar scan of that row.
    """
    oh, sp, wk = (np.ascontiguousarray(a, dtype=np.float64)
                  for a in (overheads, speeds, works))
    B, n = sp.shape
    T = wk.shape[1]
    e = np.zeros((B, n), dtype=np.float64)
    counts = np.zeros((B, n), dtype=np.int64)
    executed = np.zeros((B, n), dtype=np.float64)
    k0 = min(n, T)
    if k0:
        e[:, :k0] = oh[:, :k0] + wk[:, :k0] / sp[:, :k0]
        counts[:, :k0] = 1
        executed[:, :k0] = wk[:, :k0]
    if T > k0:
        # Hot loop on flat [B*n] views: per step only the end-time matrix
        # is updated; the winning flat index is logged and counts/executed
        # fold up in two bincounts afterwards.
        ef, ohf, spf = e.reshape(-1), oh.reshape(-1), sp.reshape(-1)
        row_base = np.arange(B, dtype=np.int64) * n
        assign = np.empty((T - k0, B), dtype=np.int64)
        for t, k in enumerate(range(k0, T)):
            idx = row_base + e.argmin(axis=1)
            assign[t] = idx
            ef[idx] = (ef[idx] + ohf[idx]) + wk[:, k] / spf[idx]
        flat = assign.reshape(-1)
        counts += np.bincount(flat, minlength=B * n).reshape(B, n)
        executed += np.bincount(
            flat, weights=wk[:, k0:].T.reshape(-1),
            minlength=B * n).reshape(B, n)
    node_end = np.where(counts > 0, e, 0.0)
    return node_end, counts, executed


def dedup_rows(key: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Cross-batch de-dup — the batched demotion of the scalar solve LRU.

    ``key`` is a ``[B, K]`` matrix where equal rows are guaranteed equal
    solves.  Returns ``(uniq_idx, inverse)``: solve ``key[uniq_idx]``
    (one row per distinct key, first occurrence order) and scatter each
    per-row result with ``result[inverse]`` to recover the full batch.

    Keys are matched on exact bytes (a dict over row buffers, not
    ``np.unique(axis=0)`` — the lexicographic row sort costs more than
    the solves it saves at planner batch sizes).
    """
    key = np.ascontiguousarray(key)
    seen: Dict[bytes, int] = {}
    uniq: list = []
    inverse = np.empty(key.shape[0], dtype=np.int64)
    for b in range(key.shape[0]):
        j = seen.setdefault(key[b].tobytes(), len(uniq))
        if j == len(uniq):
            uniq.append(b)
        inverse[b] = j
    return np.asarray(uniq, dtype=np.int64), inverse


def _pull_batch(oh: np.ndarray, sp: np.ndarray, wk: np.ndarray,
                dedup: bool) -> BatchResult:
    if dedup and sp.shape[0] > 1:
        key = np.hstack([oh, sp, wk])
        uniq_idx, inverse = dedup_rows(key)
        if uniq_idx.size < sp.shape[0]:
            node_end, counts, executed = pull_scan(
                oh[uniq_idx], sp[uniq_idx], wk[uniq_idx])
            node_end, counts, executed = (
                node_end[inverse], counts[inverse], executed[inverse])
            makespan, idle = _finish_stats(node_end, counts)
            return BatchResult(makespan, idle, node_end, executed, counts)
    node_end, counts, executed = pull_scan(oh, sp, wk)
    makespan, idle = _finish_stats(node_end, counts)
    return BatchResult(makespan, idle, node_end, executed, counts)


def batched_closed_pull(speeds, n_tasks: int, task_work, overheads=0.0,
                        *, dedup: bool = True) -> BatchResult:
    """Array-form uniform ``closed-pull``: each row pulls ``n_tasks``
    microtasks of ``task_work`` (scalar or per-row ``[B]``) each.

    Routed through the same scan as the hetero solver — exact by
    construction, including the lowest-node tie-break uniform grids hit
    constantly.  De-dup runs on the compact ``(overheads, speeds,
    task_work)`` key before the grid is expanded.
    """
    if n_tasks < 0:
        raise ValueError("n_tasks must be >= 0")
    sp = _as_2d(speeds, "speeds")
    _check_speeds(sp)
    B, n = sp.shape
    oh = _broadcast_overheads(overheads, sp.shape)
    tw = np.broadcast_to(
        np.asarray(task_work, dtype=np.float64), (B,)).reshape(B, 1)
    if np.any(tw < 0.0):
        raise ValueError("task_work must be >= 0")
    if dedup and B > 1:
        key = np.hstack([oh, sp, tw])
        uniq_idx, inverse = dedup_rows(key)
        if uniq_idx.size < B:
            u = uniq_idx.size
            wk = np.broadcast_to(tw[uniq_idx], (u, max(n_tasks, 1)))
            node_end, counts, executed = pull_scan(
                oh[uniq_idx], sp[uniq_idx], wk[:, :n_tasks])
            node_end, counts, executed = (
                node_end[inverse], counts[inverse], executed[inverse])
            makespan, idle = _finish_stats(node_end, counts)
            return BatchResult(makespan, idle, node_end, executed, counts)
    wk = np.broadcast_to(tw, (B, max(n_tasks, 1)))[:, :n_tasks]
    return _pull_batch(oh, sp, wk, dedup=False)


def batched_closed_pull_hetero(speeds, works, overheads=0.0,
                               *, dedup: bool = True) -> BatchResult:
    """Array-form ``closed-pull-hetero``: row b FIFO-pulls the ``[B, T]``
    work grid ``works[b]`` over speeds ``speeds[b]``.

    ``speeds`` may be ``[n]`` or ``[B, n]`` (a single cluster scored
    against B work grids broadcasts for free); ``works`` may be ``[T]``
    or ``[B, T]``.  ``dedup=True`` collapses identical
    ``(overheads, speeds, works)`` rows to one scan each.
    """
    sp = _as_2d(speeds, "speeds")
    wk = _as_2d(works, "works")
    if sp.shape[0] == 1 and wk.shape[0] > 1:
        sp = np.broadcast_to(sp, (wk.shape[0], sp.shape[1]))
    elif wk.shape[0] == 1 and sp.shape[0] > 1:
        wk = np.broadcast_to(wk, (sp.shape[0], wk.shape[1]))
    if sp.shape[0] != wk.shape[0]:
        raise ValueError(
            f"batch mismatch: speeds {sp.shape} vs works {wk.shape}")
    _check_speeds(sp)
    if np.any(wk < 0.0):
        raise ValueError("works must be >= 0")
    oh = _broadcast_overheads(overheads, sp.shape)
    return _pull_batch(oh, sp, wk, dedup=dedup)


def pull_scan_jax(overheads, speeds, works):
    """jax twin of :func:`pull_scan`: ``lax.scan`` over the task axis,
    ``vmap`` over the batch — jit-able, and differentiable w.r.t. the
    work grid and speeds (makespan gradients for learned split policies).

    Unprimed nodes carry ``+inf`` end times so the argmin never selects
    them before their forced priming turn (step k < n takes node k, the
    engine's initial pulls).  Precision follows the active jax dtype:
    enable ``jax_enable_x64`` to reproduce the numpy scan at 1e-9.
    Returns ``(node_end, counts, executed)`` like the numpy scan.
    """
    import jax
    import jax.numpy as jnp

    oh = jnp.asarray(overheads)
    sp = jnp.asarray(speeds)
    wk = jnp.asarray(works)
    n = sp.shape[-1]
    T = wk.shape[-1]

    def one(oh1, sp1, wk1):
        def step(carry, xs):
            e, cnt, ex = carry
            w, k = xs
            i = jnp.where(k < n, k, jnp.argmin(e))
            prev = jnp.where(jnp.isinf(e[i]), 0.0, e[i])
            e = e.at[i].set((prev + oh1[i]) + w / sp1[i])
            cnt = cnt.at[i].add(1)
            ex = ex.at[i].add(w)
            return (e, cnt, ex), None

        init = (jnp.full((n,), jnp.inf, dtype=wk1.dtype),
                jnp.zeros((n,), dtype=jnp.int32),
                jnp.zeros((n,), dtype=wk1.dtype))
        (e, cnt, ex), _ = jax.lax.scan(
            step, init, (wk1, jnp.arange(T)))
        node_end = jnp.where(cnt > 0, e, 0.0)
        return node_end, cnt, ex

    return jax.vmap(one)(oh, sp, wk)


class CapacityReport(NamedTuple):
    """Result of :func:`plan_capacity`."""
    chosen: Optional[int]            # smallest passing fleet size, or None
    quantiles: Dict[int, float]      # fleet size -> percentile makespan
    makespans: Dict[int, np.ndarray]  # fleet size -> [samples] makespans
    target: float
    percentile: float
    mode: str


_CAPACITY_MODES = ("hemt", "oracle", "homt")


def plan_capacity(speed_pool: Sequence[float], total_work: float, *,
                  target: float, n_range: Sequence[int], mode: str = "hemt",
                  percentile: float = 99.0, samples: int = 1000,
                  cv: float = 0.2, overhead: float = 0.0, n_tasks: int = 0,
                  seed: int = 0) -> CapacityReport:
    """Monte-Carlo capacity planning: the smallest fleet size whose
    ``percentile``-th makespan meets ``target``.

    For candidate size ``n``, the fleet's advertised means cycle through
    ``speed_pool`` (node j advertises ``speed_pool[j % len(pool)]``);
    each of ``samples`` draws jitters every node's true speed lognormally
    around its mean with coefficient of variation ``cv`` (mean-preserving;
    ``cv=0`` is deterministic, and the pull de-dup then collapses the
    whole batch to a single scan).  Modes:

    * ``"hemt"``   — static split proportional to the *advertised* means
      (what a non-adaptive HeMT planner knows at split time);
    * ``"oracle"`` — split proportional to each sample's *true* speeds,
      the clairvoyant lower envelope;
    * ``"homt"``   — uniform pull of ``n_tasks`` microtasks (default 4
      per node when 0) of ``total_work / n_tasks`` each.
    """
    if mode not in _CAPACITY_MODES:
        raise ValueError(f"mode must be one of {_CAPACITY_MODES}, got {mode!r}")
    pool = np.asarray(list(speed_pool), dtype=np.float64)
    if pool.size == 0 or np.any(pool <= 0.0):
        raise ValueError("speed_pool must be non-empty and > 0")
    sizes = sorted(set(int(n) for n in n_range))
    if not sizes or sizes[0] < 1:
        raise ValueError("n_range must contain sizes >= 1")
    if total_work < 0.0:
        raise ValueError("total_work must be >= 0")
    if target <= 0.0:
        raise ValueError("target must be > 0")
    if samples < 1:
        raise ValueError("samples must be >= 1")
    if cv < 0.0:
        raise ValueError("cv must be >= 0")
    if not 0.0 < percentile <= 100.0:
        raise ValueError("percentile must be in (0, 100]")

    rng = np.random.default_rng(seed)
    quantiles: Dict[int, float] = {}
    makespans: Dict[int, np.ndarray] = {}
    chosen: Optional[int] = None
    for n in sizes:
        means = pool[np.arange(n) % pool.size]
        if cv > 0.0:
            # mean-preserving lognormal jitter (RequestModel idiom):
            # sigma^2 = log(1 + cv^2), mu = log(mean) - sigma^2 / 2
            sigma = np.sqrt(np.log1p(cv * cv))
            mu = np.log(means) - 0.5 * sigma * sigma
            sp = rng.lognormal(mean=mu, sigma=sigma, size=(samples, n))
        else:
            sp = np.broadcast_to(means, (samples, n))
        if mode == "homt":
            k = n_tasks if n_tasks > 0 else 4 * n
            res = batched_closed_pull(sp, k, total_work / k, overhead)
        else:
            if mode == "hemt":
                split = total_work * means / means.sum()
                res = batched_closed_static(sp, split[None, :], overhead)
            else:   # oracle: clairvoyant split on the sampled true speeds
                split = total_work * sp / sp.sum(axis=1, keepdims=True)
                res = batched_closed_static(sp, split, overhead)
        q = float(np.percentile(res.makespan, percentile))
        quantiles[n] = q
        makespans[n] = res.makespan
        if chosen is None and q <= target:
            chosen = n
    return CapacityReport(chosen, quantiles, makespans, target, percentile,
                          mode)
