"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D).

    GQA by head grouping; full-precision softmax.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, sq, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    rel = qpos - kpos
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= rel >= 0
    if window > 0:
        ok &= rel < window
    logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                 B: jnp.ndarray, C: jnp.ndarray,
                 init_state: Optional[jnp.ndarray] = None,
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential (exact) SSD recurrence — the trusted oracle.

    x: (batch, S, H, P); dt: (batch, S, H); a_log: (H,);
    B, C: (batch, S, G, N) with G | H.
    h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t h_t (+ no D skip).
    Returns (y (batch,S,H,P), final_state (batch,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        st = carry
        xt, dtt, bt, ct = inp       # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * a)    # (b,h)
        st = st * decay[..., None, None] + \
            jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        yt = jnp.einsum("bhpn,bhn->bhp", st, ct)
        return st, yt

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final, ys = jax.lax.scan(step, init_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def skewed_bucket_ref(hashes: jnp.ndarray, capacities: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 1: bucket = #(inclusive-prefix-sums <= h), h = hash mod total."""
    caps = capacities.astype(jnp.int32)
    total = jnp.sum(caps)
    h = jnp.mod(hashes.astype(jnp.int32), total)
    cum = jnp.cumsum(caps)
    return jnp.sum(cum[None, :] <= h[:, None], axis=-1).astype(jnp.int32)
