"""Blockwise (flash) attention Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §2): the GPU flash-attention tiling is
re-thought for the TPU memory hierarchy — q/k/v tiles live in VMEM via
BlockSpec, the (bq x bk) logits tile feeds the 128x128 MXU, online-softmax
running stats (m, l) and the output accumulator sit in VMEM scratch that
persists across the sequential kv-block grid dimension (TPU grids execute
in order, unlike CUDA thread blocks). Block shapes default to MXU-aligned
(128, 128).

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost/sequential.
GQA: the k/v BlockSpec index_map folds the q-head onto its kv head
(h -> h // group), so no head replication materializes in HBM.

Causal + sliding-window masks are applied with block-level early-outs:
fully-masked (q_blk, kv_blk) tiles are skipped entirely (the dominant win
for long-context sliding-window archs like gemma3).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 bq: int, bk: int, n_kv_blocks: int, sk_actual: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # block-level skip: [q0, q0+bq) x [k0, k0+bk)
    q0 = qi * bq
    k0 = ki * bk
    live = True
    if causal:
        live = q0 + bq - 1 >= k0               # any pair with q >= k
    if window > 0:
        live = jnp.logical_and(live, q0 < k0 + bk + window - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = q @ k.T                                      # (bq, bk)

        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        rel = qpos - kpos
        ok = kpos < sk_actual          # mask padded kv columns
        if causal:
            ok &= rel >= 0
        if window > 0:
            ok &= rel < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)       # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l_sum = l_scr[...]
        # rows with no live kv block (can happen off the padded tail) -> 0
        denom = jnp.where(l_sum == 0.0, 1.0, l_sum)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D).

    Sq/Sk are padded to block multiples internally; GQA via Hq = g * Hkv.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)

    bq = min(block_q, _round_up(sq, 8))
    bk = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, bq)
    sk_p = _round_up(sk, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    n_q_blocks = sq_p // bq
    n_kv_blocks = sk_p // bk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv_blocks=n_kv_blocks, sk_actual=sk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, n_q_blocks, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq, :]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
