"""Paper Algorithm 1 (skewed hash partitioner) as a Pallas TPU kernel.

bucket(r) = #( inclusive-prefix-sums(capacities) <= hash(r) mod sum(caps) )

Used on the shuffle/dispatch hot path (MoE token -> expert-shard routing,
data-shuffle re-bucketing). The capacities vector is tiny (#executors /
#experts), so every grid step keeps the whole prefix-sum array resident in
VMEM and streams hash tiles through; the bucket search is a broadcast
compare + row-sum on the VPU (8x128 lanes) — no gather, no sort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bucket_kernel(h_ref, cum_ref, out_ref, *, total: int):
    h = h_ref[...].astype(jnp.int32)                       # (bt,)
    hm = jnp.mod(h, total)
    cum = cum_ref[...].astype(jnp.int32)                   # (E,)
    # bucket = number of inclusive prefix sums <= h
    out_ref[...] = jnp.sum(
        (cum[None, :] <= hm[:, None]).astype(jnp.int32), axis=1)


def skewed_bucket(hashes: jnp.ndarray, capacities: jnp.ndarray, *,
                  block: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """hashes: (T,) int32; capacities: (E,) int32 (static shape).

    Returns (T,) int32 bucket ids in [0, E). The capacity *values* may be
    traced (HeMT re-skews them between steps without recompiling), but the
    hash-space size is their sum — we fold the mod into the kernel with the
    total passed as an operand to stay trace-safe.
    """
    t = hashes.shape[0]
    e = capacities.shape[0]
    tp = _round_up(t, block)
    if tp != t:
        hashes = jnp.pad(hashes, (0, tp - t))
    cum = jnp.cumsum(capacities.astype(jnp.int32))
    total = int(capacities.sum()) if _is_static(capacities) else None

    if total is None:
        # traced capacities: fall back to a two-operand kernel with the
        # total folded into the hashes outside (mod is cheap in XLA)
        hm = jnp.mod(hashes.astype(jnp.int32), cum[-1])
        kernel = functools.partial(_bucket_kernel, total=jnp.iinfo(jnp.int32).max)
        src = hm
    else:
        kernel = functools.partial(_bucket_kernel, total=total)
        src = hashes

    out = pl.pallas_call(
        kernel,
        grid=(tp // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tp,), jnp.int32),
        interpret=interpret,
    )(src, cum)
    return out[:t]


def _is_static(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
