"""jit'd wrappers around the Pallas kernels, in model-native layouts.

On CPU (this container) the kernels execute under ``interpret=True``; on a
real TPU backend they compile to Mosaic. The wrappers do the layout
transposes + padding and the cheap elementwise prep that XLA fuses with
neighbouring ops.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import skewed_bucket as _sb
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Model layout: q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    if interpret is None:
        interpret = _interpret_default()
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              scale=scale, block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return jnp.swapaxes(out, 1, 2)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
             init_state: Optional[jnp.ndarray] = None,
             interpret: Optional[bool] = None,
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Mamba2 SSD scan, same contract as ``ref.ssd_scan_ref``.

    x: (batch, S, H, P); dt: (batch, S, H) (already softplus'd);
    a_log: (H,); B/C: (batch, S, G, N).
    """
    if interpret is None:
        interpret = _interpret_default()
    bsz, s, h, p = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    dta = dt.astype(jnp.float32) * a                   # (b, S, H)
    xdt = x.astype(jnp.float32) * dt[..., None]

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dta = jnp.pad(dta, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, fin = _ssd.ssd_scan(xdt, dta, B, C, chunk=c, interpret=interpret)
    y = y[:, :s]
    if init_state is not None:
        # fold a nonzero initial state in linearly (the scan is linear in
        # the state): y += exp(cumsum dta) C . init ; final += prod-decay*init
        cum = jnp.cumsum(dta[:, :s], axis=1)           # (b,S,H)
        rep = h // B.shape[2]
        Ch = jnp.repeat(C[:, :s], rep, axis=2).astype(jnp.float32)
        y = y + jnp.exp(cum)[..., None] * jnp.einsum(
            "bshn,bhpn->bshp", Ch, init_state.astype(jnp.float32))
        fin = fin + init_state * jnp.exp(cum[:, -1])[..., None, None]
    return y.astype(x.dtype), fin


def skewed_bucket(hashes: jnp.ndarray, capacities: jnp.ndarray, *,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Algorithm 1 bucket map (paper §7). hashes (T,), capacities (E,)."""
    if interpret is None:
        interpret = _interpret_default()
    return _sb.skewed_bucket(hashes, capacities, interpret=interpret)
